"""End-to-end driver: train a ~100M-param LM with the full stack.

Everything is exercised for real: model (qwen3-family blocks), AdamW with
warmup-cosine, MDTP multi-source input pipeline over three throttled
localhost mirrors, async atomic checkpoints with keep-k GC, and
resume-from-latest.

Defaults are CPU-sane (~100M params, short run); pass --steps 300 for the
full few-hundred-step run of the deliverable.

Run:  PYTHONPATH=src python examples/train_100m.py --steps 30
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import run_training
from repro.models.common import ModelConfig
from repro.models.transformer import num_params


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32_000,
        qk_norm=True, mlp_act="swiglu", tie_embeddings=True, remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"model: {cfg.name}, {num_params(cfg) / 1e6:.1f}M params")
    ckpt = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                         "repro_100m_ckpt")
    _, losses = run_training(
        cfg, args.steps, args.batch, args.seq, ckpt_dir=ckpt,
        resume=args.resume, lr=6e-4, log_every=1)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps); checkpoints in {ckpt}")
    if len(losses) >= 10:  # too noisy to assert on a handful of steps
        head = sum(losses[:3]) / 3
        tail = sum(losses[-3:]) / 3
        assert tail < head, f"loss should trend down: {head:.3f}->{tail:.3f}"


if __name__ == "__main__":
    main()
