"""Automatic chunk-size selection — the paper's §VIII-A future work, live.

The on-device simulator (lax.while_loop) evaluates the Table-II grid for
the CURRENTLY OBSERVED mirror throughputs, and the framework adopts the
winner for subsequent transfers.  The paper picked 16/160 MB by hand for
>8 GB files; the autotuner both recovers that choice on the calibrated
testbed and finds better ones when conditions drift.

Chunk geometry is traced data, so the WHOLE (C, L) x seed sweep is one
jit-compiled device call — and the batched API stacks a scenario axis on
top: the second demo tunes a fleet of drifted mirror conditions in a
single fused call (thousands of (scenario, C, L, seed) cells at once).

Run:  PYTHONPATH=src python examples/autotune_chunks.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.autotune import autotune_batch, autotune_chunk_params
from repro.core.scenarios import GB, MBPS, paper_baseline

MB = 1024 * 1024


def main():
    servers = paper_baseline()
    bw = [s.bandwidth for s in servers]
    print("observed mirror throughputs (MiB/s):",
          [round(b / MBPS, 1) for b in bw])

    for size_gb in (2, 32):
        res = autotune_chunk_params(bw, rtt=0.03, file_size=size_gb * GB)
        c, l = res.params.initial_chunk, res.params.large_chunk
        worst = max(res.predicted_times)
        print(f"\n--- {size_gb} GB file ---")
        print(res.as_table())
        print(f"best: C={c // MB} MB, L={l // MB} MB "
              f"-> {res.predicted_time:.1f}s "
              f"(worst grid point {worst:.1f}s, "
              f"{(worst - res.predicted_time) / worst * 100:.0f}% saved)")

    # --- batched: tune many drifted scenarios in ONE fused device call ---
    rng = np.random.default_rng(0)
    drift = rng.uniform(0.3, 1.7, size=(8, len(bw)))
    scenarios = np.asarray(bw)[None, :] * drift
    results = autotune_batch(scenarios, rtt=0.03, file_size=2 * GB)
    print("\n--- 8 drifted scenarios, one fused call (2 GB file) ---")
    print("scenario,winner_C(MB),winner_L(MB),predicted_s")
    for i, r in enumerate(results):
        print(f"{i},{r.params.initial_chunk // MB},"
              f"{r.params.large_chunk // MB},{r.predicted_time:.1f}")


if __name__ == "__main__":
    main()
