"""Automatic chunk-size selection — the paper's §VIII-A future work, live.

The on-device simulator evaluates the Table-II grid for the CURRENTLY
OBSERVED mirror throughputs, and the framework adopts the winner for
subsequent transfers.  The paper picked 16/160 MB by hand for >8 GB
files; the autotuner both recovers that choice on the calibrated testbed
and finds better ones when conditions drift.

Chunk geometry is traced data, so the WHOLE (C, L) x seed sweep is one
jit-compiled device call — and since the sweep runs on the
round-synchronous core (one device step per MDTP round instead of per
chunk) it is another order of magnitude faster than the event-driven
loop.  The batched API stacks a scenario axis on top: the second demo
tunes a fleet of drifted mirror conditions in a single fused call
(thousands of (scenario, C, L, seed) cells at once).  The last demo goes
finer than any grid: ``jax.grad`` through the differentiable scan core
polishes the grid winner in continuous (C, L) space.

Run:  PYTHONPATH=src python examples/autotune_chunks.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.autotune import (
    autotune_batch,
    autotune_chunk_params,
    tune_chunk_params_grad,
)
from repro.core.scenarios import GB, MBPS, paper_baseline

MB = 1024 * 1024


def main():
    servers = paper_baseline()
    bw = [s.bandwidth for s in servers]
    print("observed mirror throughputs (MiB/s):",
          [round(b / MBPS, 1) for b in bw])

    for size_gb in (2, 32):
        res = autotune_chunk_params(bw, rtt=0.03, file_size=size_gb * GB)
        c, l = res.params.initial_chunk, res.params.large_chunk
        worst = max(res.predicted_times)
        print(f"\n--- {size_gb} GB file ---")
        print(res.as_table())
        print(f"best: C={c // MB} MB, L={l // MB} MB "
              f"-> {res.predicted_time:.1f}s "
              f"(worst grid point {worst:.1f}s, "
              f"{(worst - res.predicted_time) / worst * 100:.0f}% saved)")

    # --- batched: tune many drifted scenarios in ONE fused device call ---
    rng = np.random.default_rng(0)
    drift = rng.uniform(0.3, 1.7, size=(8, len(bw)))
    scenarios = np.asarray(bw)[None, :] * drift
    results = autotune_batch(scenarios, rtt=0.03, file_size=2 * GB)
    print("\n--- 8 drifted scenarios, one fused call (2 GB file) ---")
    print("scenario,winner_C(MB),winner_L(MB),predicted_s")
    for i, r in enumerate(results):
        print(f"{i},{r.params.initial_chunk // MB},"
              f"{r.params.large_chunk // MB},{r.predicted_time:.1f}")

    # --- beyond the grid: jax.grad polish on the scan core ---------------
    grid_res = autotune_chunk_params(bw, rtt=0.03, file_size=2 * GB)
    polished = tune_chunk_params_grad(
        bw, rtt=0.03, file_size=2 * GB,
        init=(grid_res.params.initial_chunk, grid_res.params.large_chunk),
        steps=40)
    print("\n--- gradient polish of the grid winner (2 GB file) ---")
    print(f"grid:     C={grid_res.params.initial_chunk / MB:.1f} MB, "
          f"L={grid_res.params.large_chunk / MB:.1f} MB "
          f"-> {grid_res.predicted_time:.2f}s")
    print(f"polished: C={polished.params.initial_chunk / MB:.1f} MB, "
          f"L={polished.params.large_chunk / MB:.1f} MB "
          f"-> {polished.predicted_time:.2f}s "
          f"({polished.steps} Adam steps, "
          f"dT/dL={polished.final_grad[1]:.2e} s/byte)")


if __name__ == "__main__":
    main()
