"""Multi-source checkpoint restore with a mirror failure mid-transfer.

The production scenario this framework exists for: a preempted node (or a
whole re-scaled job) pulls its checkpoint from R replicated stores with
MDTP adaptive chunking — and one store dies while still owing bytes.  The
outstanding range returns to the pool, the surviving mirrors absorb it,
and every byte is still fetched exactly once.

Run:  PYTHONPATH=src python examples/multisource_restore.py
"""

import os
import sys
import tempfile
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.transfer import RangeServer, Replica, Throttle

MB = 1024 * 1024


def main():
    # a ~64 MB "model" state
    state = {
        "params": {f"layer{i}": jax.random.normal(jax.random.PRNGKey(i),
                                                  (1024, 2048))
                   for i in range(8)},
        "step": jnp.int32(1234),
    }
    with tempfile.TemporaryDirectory() as root:
        d = save_checkpoint(root, 1234, state)
        size = os.path.getsize(os.path.join(d, "data.bin"))
        print(f"checkpoint written: {size >> 20} MiB")

        mirrors = []
        for bw in (20 * MB, 40 * MB, 80 * MB):
            s = RangeServer(throttle=Throttle(bytes_per_s=bw)).start()
            base = "/ckpt/step_0000001234"
            s.add_file(base + "/manifest.json",
                       os.path.join(d, "manifest.json"))
            s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
            mirrors.append(s)

        # the slowest mirror dies 200 ms into the restore
        threading.Timer(0.2, mirrors[0].stop).start()

        replicas = [Replica("127.0.0.1", s.port, "/ckpt") for s in mirrors]
        restored, step = restore_checkpoint(root, state, step=1234,
                                            replicas=replicas)
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state),
                            jax.tree.leaves(restored)))
        print(f"restored step {step}; bit-exact: {ok} "
              f"(one mirror was killed mid-transfer)")
        for s in mirrors[1:]:
            s.stop()
        assert ok


if __name__ == "__main__":
    main()
