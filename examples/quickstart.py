"""Quickstart: MDTP in 60 seconds.

1. Simulate the paper's FABRIC testbed and compare MDTP against static
   chunking / Aria2 / BitTorrent on a 4 GB transfer.
2. Do a REAL multi-source transfer over three localhost HTTP mirrors with
   heterogeneous bandwidth and watch the adaptive chunking balance them.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (Aria2Policy, BitTorrentPolicy, MDTPPolicy,
                        StaticChunkingPolicy, simulate)
from repro.core.chunking import ChunkParams
from repro.core.scenarios import GB, bittorrent_seeders, paper_baseline
from repro.transfer import RangeServer, Replica, Throttle, fetch_blob

MB = 1024 * 1024


def simulated_comparison():
    print("=== simulated 4 GB transfer, 6 replicas (paper Fig. 2 setup) ===")
    servers = paper_baseline()
    for policy in (MDTPPolicy(), StaticChunkingPolicy(), Aria2Policy()):
        r = simulate(policy, servers, 4 * GB, seed=0)
        r.check_integrity()
        print(f"  {r.policy:10s} {r.total_time:7.1f}s  "
              f"replicas used: {r.utilization(0.01) * 100:3.0f}%  "
              f"requests/replica: {r.requests_per_server}")
    r = simulate(BitTorrentPolicy(), bittorrent_seeders(), 4 * GB, seed=0)
    print(f"  {r.policy:10s} {r.total_time:7.1f}s  (flapping seeders)")


def real_transfer():
    print("=== real MDTP transfer over 3 localhost mirrors ===")
    blob = np.random.default_rng(0).integers(
        0, 256, size=16 * MB, dtype=np.uint8).tobytes()
    servers = []
    for bw in (25 * MB, 50 * MB, 100 * MB):
        s = RangeServer(throttle=Throttle(bytes_per_s=bw)).start()
        s.add_blob("/blob", blob)
        servers.append(s)
    try:
        replicas = [Replica("127.0.0.1", s.port, "/blob") for s in servers]
        data, report = fetch_blob(
            replicas, len(blob),
            params=ChunkParams(initial_chunk=512 * 1024, large_chunk=2 * MB))
        assert bytes(data) == blob
        print(f"  fetched {len(blob) >> 20} MiB in {report.elapsed:.2f}s "
              f"({report.throughput / MB:.0f} MiB/s aggregate)")
        for name, nbytes in report.bytes_per_replica.items():
            reqs = report.requests_per_replica[name]
            print(f"    mirror {name}: {nbytes >> 20:3d} MiB "
                  f"in {reqs} requests")
    finally:
        for s in servers:
            s.stop()


if __name__ == "__main__":
    simulated_comparison()
    real_transfer()
