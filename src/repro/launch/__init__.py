"""repro.launch"""
