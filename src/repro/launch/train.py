"""End-to-end training driver.

Wires every substrate together: config registry -> model -> AdamW ->
MDTP multi-source data pipeline -> checkpoint manager (async, atomic,
keep-k) -> train loop with resume.  On this CPU container it drives the
``reduced()`` configs (or a custom --dim/--layers ~100M model) against
in-process HTTP mirrors; on a real pod the same driver takes the production
mesh + real mirror URLs.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 20 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --reduced \
      --steps 10 --resume   # picks up the latest checkpoint
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import get_config, list_archs, reduced_config
from repro.data import (MultiSourcePipeline, TokenDatasetSpec,
                        synthetic_tokens, write_token_dataset)
from repro.models.common import init_params
from repro.models.transformer import model_specs
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step
from repro.transfer import RangeServer, Replica, Throttle

__all__ = ["main", "run_training"]


def run_training(cfg, steps: int, batch: int, seq: int, *,
                 ckpt_dir: str | None = None, resume: bool = False,
                 mirrors: int = 3, lr: float = 3e-4, log_every: int = 1,
                 seed: int = 0):
    """Returns (final_state, losses)."""
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                          decay_steps=max(steps, 2))
    params = init_params(jax.random.PRNGKey(seed), model_specs(cfg))
    state = init_train_state(params, opt_cfg)

    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, every_steps=max(steps // 4, 1),
                                keep=2)
        if resume and latest_step(ckpt_dir) is not None:
            state, start_step = restore_checkpoint(ckpt_dir, state)
            print(f"# resumed from step {start_step}")

    # replicated mirrors serving the token stream (MDTP multi-source input)
    tokens = synthetic_tokens(
        max(batch * (seq + 1) * (steps + 4), 65_536), cfg.vocab_size,
        seed=seed)
    blobs = write_token_dataset(None, tokens)
    servers = []
    for i in range(mirrors):
        s = RangeServer(throttle=Throttle(
            bytes_per_s=(i + 1) * 40 * 1024 * 1024)).start()
        for name, data in blobs.items():
            s.add_blob("/ds/" + name, data)
        servers.append(s)
    replicas = [Replica("127.0.0.1", s.port, "/ds") for s in servers]
    spec = TokenDatasetSpec(n_tokens=tokens.size, seq_len=seq,
                            global_batch=batch)
    pipe = MultiSourcePipeline(replicas, spec, depth=2)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    losses = []
    try:
        for step in range(start_step, steps):
            t0 = time.perf_counter()
            toks = pipe.get_batch(step)
            batch_arrs = {"tokens": jnp.asarray(toks[:, :-1].astype(np.int32))}
            state, metrics = step_fn(state, batch_arrs)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"dt {time.perf_counter() - t0:6.2f}s", flush=True)
            if mgr is not None:
                mgr.maybe_save(step + 1, state)
    finally:
        if mgr is not None:
            mgr.wait()
        pipe.close()
        for s in servers:
            s.stop()
    return state, losses


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mirrors", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    _, losses = run_training(
        cfg, args.steps, args.batch, args.seq, ckpt_dir=args.ckpt_dir,
        resume=args.resume, mirrors=args.mirrors, lr=args.lr)
    print(f"# done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
