"""Serving driver: prefill a prompt batch, then batched greedy decode.

CPU-scale demo of the serving path the ``decode_*`` dry-run cells lower at
production shapes.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --prompt-len 32 --gen 16 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduced_config
from repro.models.common import init_params
from repro.models.transformer import init_cache, model_specs
from repro.serve.step import make_serve_step

__all__ = ["main", "generate"]


def generate(cfg, params, prompt: jax.Array, gen: int,
             temperature: float = 0.0, seed: int = 0):
    """prompt [B, S0] -> tokens [B, S0+gen] (greedy or sampled)."""
    B, S0 = prompt.shape
    s_max = S0 + gen
    mem_len = 8 if cfg.family in ("encdec", "vlm") else 0
    cache = init_cache(cfg, B, s_max, mem_len)
    if mem_len:
        cache["memory"] = jnp.zeros((B, mem_len, cfg.d_model), cfg.jdtype)

    serve_step = jax.jit(make_serve_step(cfg, temperature),
                         donate_argnums=(1,))
    rng = jax.random.PRNGKey(seed)
    toks = prompt
    # teacher-forced prefill through the decode path (exact cache build)
    nxt = None
    for t in range(S0):
        rng, sub = jax.random.split(rng)
        nxt, _, cache = serve_step(params, cache, toks[:, t:t + 1],
                                   jnp.int32(t), sub)
    for t in range(S0, S0 + gen):
        toks = jnp.concatenate([toks, nxt], axis=1)
        rng, sub = jax.random.split(rng)
        nxt, _, cache = serve_step(params, cache, toks[:, t:t + 1],
                                   jnp.int32(t), sub)
    return toks


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.perf_counter()
    toks = generate(cfg, params, prompt, args.gen,
                    temperature=args.temperature)
    dt = time.perf_counter() - t0
    n_new = args.batch * args.gen
    print(f"# generated {toks.shape} in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s incl. prefill+compile)")
    print(toks[:, args.prompt_len:])


if __name__ == "__main__":
    main()
