"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while smoke tests must see the
real single-device CPU backend.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis.

    Axis roles: ``pod`` = outer data parallelism over DCN; ``data`` = data
    parallelism (+ZeRO/FSDP storage sharding) over ICI; ``model`` = tensor/
    expert parallelism over ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real local devices (CPU smoke tests, examples)."""
    n = data * model
    devs = jax.devices()[:n]
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(jax.devices())}")
    import numpy as np
    return jax.sharding.Mesh(
        np.array(devs).reshape(data, model), ("data", "model"))
