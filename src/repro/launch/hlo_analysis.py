"""Roofline-term extraction from post-SPMD optimized HLO text.

Why not ``compiled.cost_analysis()`` alone?  It counts a ``while`` body
ONCE, so scan-over-layers models (every model here — the only way 61-81
layer configs compile on one CPU core) would under-report FLOPs by ~L.
This walker recursively costs each computation and multiplies while-loop
bodies by their trip count (XLA's ``known_trip_count`` backend config,
falling back to the canonical scan condition ``compare(iv, constant(N))``).

Per-op accounting (per-device, since post-SPMD shapes are per-device):
  * FLOPs: dot/convolution ops — 2 x result_elems x contraction size.
    (MXU flops; elementwise flops are noise at the roofline.)
  * HBM bytes: operand + result bytes of every top-level op in each
    computation (post-fusion HLO: each fusion reads operands from HBM and
    writes its result — the TPU memory model).
  * Collective bytes: operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, bucketed by type.

TPU dtype normalization (the "f32c contract").  The CPU backend has no
native bf16 compute: XLA's float-normalization pass promotes ALL bf16
math to f32 (``dot(bf16)`` -> ``convert -> dot_f32 -> convert``, same for
elementwise), and the excess-precision simplifier then cancels adjacent
convert pairs, leaving whole f32 regions that would be bf16 on the TPU
target.  Dtypes in optimized CPU HLO therefore do NOT identify intent.
The model declares intent instead: every intentionally-f32 computation is
wrapped in ``jax.named_scope("f32c")`` (norm stats, f32 softmax, loss
path, rope, recurrent cells, router, optimizer update) — op_name
metadata survives fusion.  The walker then:
  (a) costs pure dtype-convert ops at zero (they fuse / don't exist on
      TPU) and resolves references through convert chains and layout ops;
  (b) charges matmuls bf16-in/bf16-out always (the MXU contract; the ssm
      kernels keep their f32 reference math in VMEM, not HBM);
  (c) charges any other f32 compute op without the f32c marker at
      2 bytes/elem (promotion residue), keeping marked ops at f32;
  (d) charges large (>1M elem) f32 collectives at bf16 — the framework
      invariant is that no large f32 tensor is ever communicated;
  (e) does in-place accounting for DUS(-rooted fusions), slice/gather
      reads, and broadcast-of-constant buffer inits.
``elided_bytes`` reports the size of the correction so raw vs normalized
is always visible.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
# computation headers sit at column 0: ``%name (sig...) -> type {`` with
# possibly nested parens in the signature — detect by prefix + trailing '{'
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count.*?"?n"?[=:]"?(\d+)"?')
_CALL_REFS = re.compile(
    r"(?:condition|body|to_apply|calls)=\{?%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
#: ops whose operand/result bytes we do NOT charge to HBM traffic
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "while",
             "conditional", "call", "domain", "opt-barrier"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 1, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)
    table: dict = field(default_factory=dict)   # op name -> type str
    by_name: dict = field(default_factory=dict)  # op name -> _Op


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)   # type -> bytes
    collective_count: int = 0
    unparsed_while: int = 0
    copy_bytes: float = 0.0   # loop-state copies (often elided on TPU)
    elided_bytes: float = 0.0  # CPU bf16-promotion artifacts removed
    collective_bytes_xpod: float = 0.0  # share crossing the pod boundary

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult
        self.collective_count += other.collective_count * int(mult)
        self.unparsed_while += other.unparsed_while
        self.copy_bytes += other.copy_bytes * mult
        self.elided_bytes += other.elided_bytes * mult
        self.collective_bytes_xpod += other.collective_bytes_xpod * mult


def _parse_computations(hlo: str) -> tuple[dict, str]:
    comps: dict[str, _Computation] = {}
    current = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if current is None:
            if line[:1] in ("%", "E") and line.rstrip().endswith("{"):
                m = _COMP_RE.match(line)
                if m:
                    current = _Computation(m.group(1))
                    if line.startswith("ENTRY"):
                        entry = current.name
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _OPLINE_RE.match(line)
        if m:
            name, type_str, opcode = m.group(1), m.group(2), m.group(3)
            o = _Op(name, type_str, opcode, line)
            current.ops.append(o)
            current.table[name] = type_str
            current.by_name[name] = o
    return comps, entry


_FLOAT_DT = ("bf16", "f16", "f32")


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    return (m.group(1), m.group(2)) if m else (None, None)


def _first_operand(op: _Op):
    body = op.line.split(op.opcode + "(", 1)[1]
    names = _OPERAND_RE.findall(body.split(")")[0] + ")")
    return names[0] if names else None


def _is_pure_convert_comp(comp: _Computation) -> bool:
    """Called computation whose only real work is one dtype convert."""
    real = [o for o in comp.ops if o.opcode not in ("parameter", "bitcast")]
    return len(real) == 1 and real[0].opcode == "convert"


def _build_convert_maps(comps: dict) -> dict:
    """comp_name -> {op_name: source_op_name} for pure float converts."""
    maps: dict[str, dict[str, str]] = {}
    for cname, comp in comps.items():
        m: dict[str, str] = {}
        for op in comp.ops:
            src = None
            if op.opcode == "convert":
                src = _first_operand(op)
            elif op.opcode == "fusion":
                for mm in _CALL_REFS.finditer(op.line):
                    called = comps.get(mm.group(1))
                    if called is not None and _is_pure_convert_comp(called):
                        src = _first_operand(op)
                    break
            if src is None:
                continue
            st = comp.table.get(src)
            if st is None:
                continue
            sdt, sdims = _dims_of(st)
            rdt, rdims = _dims_of(op.type_str)
            if (sdt in _FLOAT_DT and rdt in _FLOAT_DT and sdims == rdims):
                m[op.name] = src
        if m:
            maps[cname] = m
    return maps


def _resolve(name: str, comp: _Computation, conv_map: dict) -> str:
    seen = set()
    while name in conv_map and name not in seen:
        seen.add(name)
        name = conv_map[name]
    return name


def _while_trip_count(op: _Op, comps: dict) -> int | None:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    # canonical scan condition: ROOT = compare(iv, const N), direction=LT
    refs = dict(
        (k, v) for k, v in
        ((mm.group(0).split("=")[0], mm.group(1))
         for mm in _CALL_REFS.finditer(op.line)))
    cond_name = None
    for mm in _CALL_REFS.finditer(op.line):
        if mm.group(0).startswith("condition"):
            cond_name = mm.group(1)
    if cond_name and cond_name in comps:
        for o in comps[cond_name].ops:
            if o.opcode == "constant" and o.type_str.startswith("s32"):
                cm = re.search(r"constant\((\d+)\)", o.line)
                if cm:
                    return int(cm.group(1))
    return None


def _dot_flops(op: _Op, comp: _Computation, comps: dict) -> float:
    result_elems, _ = _shape_elems_dims(op.type_str)
    # operand names: first two %refs inside the parens after opcode
    body = op.line.split(op.opcode + "(", 1)[1]
    operands = _OPERAND_RE.findall(body)
    if not operands:
        return 0.0
    lhs_type = comp.table.get(operands[0], "")
    _, lhs_dims = _shape_elems_dims(lhs_type)
    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    k = 1
    if mcd and lhs_dims:
        for idx in mcd.group(1).split(","):
            if idx:
                k *= lhs_dims[int(idx)]
    return 2.0 * result_elems * k


def _build_bf16_dots(comp: _Computation, conv_map: dict) -> set:
    """All float dots/convs: the TPU compute dtype for every matmul in this
    framework is bf16.  Dense-model jax dots ARE bf16 — the f32 forms in
    CPU HLO are float-normalization artifacts (often with the convert pairs
    cancelled by the excess-precision simplifier, so operand dtypes alone
    cannot identify them).  The ssm/xlstm chunked-scan f32 reference
    einsums correspond to bf16-in / f32-accumulate(-in-register) MXU ops
    in their Pallas kernel form.  Reads and writes of these ops are
    charged at 2 bytes/elem."""
    out = set()
    for op in comp.ops:
        if op.opcode in ("dot", "convolution"):
            dt, _ = _dims_of(op.type_str)
            if dt in _FLOAT_DT:
                out.add(op.name)
    return out


#: the model wraps every *intentionally*-f32 computation in
#: ``jax.named_scope("f32c")`` (norm statistics, the f32 softmax path, the
#: loss path, rope, recurrent cells, the optimizer update).  op_name
#: metadata survives XLA fusion, so the marker is visible in optimized
#: HLO.  Any OTHER f32 compute op is float-normalization promotion of
#: compute-dtype (bf16) math — a CPU-backend artifact charged at
#: 2 bytes/elem, matching the TPU target.
_LAYOUT_OPS = {"transpose", "reshape", "copy", "bitcast", "slice",
               "dynamic-slice", "pad", "concatenate", "reverse",
               "broadcast"}
_ORIGIN_UNKNOWN = {"parameter", "get-tuple-element", "constant", "iota",
                   "while", "tuple", "conditional", "call", "domain",
                   "opt-barrier", "custom-call", "rng", "rng-bit-generator"}


def _width_factor(name: str, comp: _Computation, conv_map: dict,
                  half_set: set, depth: int = 8) -> float:
    """0.5 if this f32 tensor would be bf16 on the TPU target, else 1.0."""
    rname = _resolve(name, comp, conv_map)
    t = comp.table.get(rname)
    dt, _ = _dims_of(t) if t else (None, None)
    if dt != "f32":
        return 1.0
    op = comp.by_name.get(rname)
    if op is None or depth == 0:
        return 1.0
    if rname in half_set:
        return 0.5
    oc = op.opcode
    if oc in _LAYOUT_OPS:
        src = _first_operand(op)
        if src:
            return _width_factor(src, comp, conv_map, half_set, depth - 1)
        return 1.0
    if oc in _ORIGIN_UNKNOWN:
        return 1.0                       # conservative: keep shown dtype
    return 1.0 if "f32c" in op.line else 0.5


def _res_factor(op: _Op, comp: _Computation, conv_map: dict,
                half_set: set) -> float:
    """Width factor for an op's own result write."""
    dt, _ = _dims_of(op.type_str)
    if dt != "f32":
        return 1.0
    if op.name in half_set:
        return 0.5
    if op.opcode in _LAYOUT_OPS:
        src = _first_operand(op)
        if src:
            return _width_factor(src, comp, conv_map, half_set)
        return 1.0
    if op.opcode in _ORIGIN_UNKNOWN:
        return 1.0
    return 1.0 if "f32c" in op.line else 0.5


def _eff_bytes(name: str, comp: _Computation, conv_map: dict,
               half_set: set, force_half: bool = False) -> float:
    """HBM bytes of a tensor reference, resolved through pure converts,
    with the f32c-contract width factor.  ``force_half``: reader is a
    matmul — float operands are bf16 on TPU regardless of provenance."""
    rname = _resolve(name, comp, conv_map)
    t = comp.table.get(rname)
    if t is None:
        return 0.0
    b = _shape_bytes(t)
    dt, _ = _dims_of(t)
    if dt == "f32":
        if force_half:
            return b / 2.0
        b *= _width_factor(name, comp, conv_map, half_set)
    return b


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_buffer_adjust(op: _Op, comp: _Computation, comps: dict,
                          ) -> tuple[set, float] | None:
    """In-place / slice accounting for fusions over big loop buffers.

    * DUS-rooted fusion (scan saving per-layer residuals into a stacked
      [L, ...] buffer): XLA aliases the buffer — real traffic is the
      updated slice (write) + its read, not the whole buffer per step.
    * A fusion operand consumed ONLY by dynamic-slice/gather ops inside
      the fused computation (scan reading one layer's params out of a
      stacked buffer): real traffic is the sliced region, not the stack.

    Returns (skip_operand_positions, extra_bytes) or None if no
    adjustment applies.
    """
    called = None
    for mm in _CALL_REFS.finditer(op.line):
        called = comps.get(mm.group(1))
        break
    if called is None:
        return None
    # map parameter index -> (name, uses-opcodes)
    param_names = {}
    for o in called.ops:
        pm = _PARAM_IDX_RE.search(o.line)
        if o.opcode == "parameter" and pm:
            param_names[int(pm.group(1))] = o.name
    if not param_names:
        return None
    # uses with convert/bitcast chains resolved (CPU float normalization
    # wraps the in-place DUS as convert -> DUS_f32 -> convert)
    direct = defaultdict(list)    # operand name -> consumer op names
    for o in called.ops:
        body = o.line.split("(", 1)
        if len(body) < 2:
            continue
        for n in _OPERAND_RE.findall(body[1].split(")")[0] + ")"):
            direct[n].append(o.name)
    uses = defaultdict(set)
    for n in direct:
        stack = list(direct[n])
        seen = set()
        while stack:
            oname = stack.pop()
            if oname in seen:
                continue
            seen.add(oname)
            o = called.by_name.get(oname)
            if o is None:
                continue
            if o.opcode in ("convert", "bitcast"):
                stack.extend(direct.get(oname, ()))
            else:
                uses[n].add(o.opcode)
    _, res_dims = _dims_of(op.type_str)
    skip = set()
    extra = 0.0
    slice_extra_added = False
    for idx, pname in param_names.items():
        pt = called.table.get(pname)
        if pt is None:
            continue
        pdt, pdims = _dims_of(pt)
        u = uses.get(pname, set())
        if pdims == res_dims and u and u <= {"dynamic-update-slice"}:
            # aliased in-place buffer: find the update operand's size
            for o in called.ops:
                if o.opcode == "dynamic-update-slice":
                    b = o.line.split(o.opcode + "(", 1)[1]
                    names = _OPERAND_RE.findall(b.split(")")[0] + ")")
                    if len(names) > 1:
                        extra += 2 * _shape_bytes(called.table.get(names[1], ""))
            skip.add(idx)
        elif u and u <= {"dynamic-slice", "gather", "slice"} and \
                _shape_bytes(pt) > 8 * _shape_bytes(op.type_str):
            # stacked-buffer read: charge the sliced result(s) instead
            # (once, regardless of how many big params feed the slices)
            if not slice_extra_added:
                for o in called.ops:
                    if o.opcode in ("dynamic-slice", "gather", "slice"):
                        extra += _shape_bytes(o.type_str)
                slice_extra_added = True
            skip.add(idx)
    if not skip:
        return None
    return skip, extra


_RG_EXPLICIT = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")
_RG_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _spans_pods(line: str, pod_size: int = 256) -> bool:
    """True if any replica group mixes devices from different pods (the
    512-device two-pod mesh: ids < 256 vs >= 256).  Handles both the
    explicit and iota-tiled replica_groups formats."""
    m = _RG_EXPLICIT.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            pods = {i // pod_size for i in ids}
            if len(pods) > 1:
                return True
        return False
    m = _RG_IOTA.search(line)
    if m:
        import numpy as _np
        n, k = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        arr = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(x) for x in m.group(4).split(",")])
        groups = arr.reshape(n, k)
        return bool((_np.ptp(groups // pod_size, axis=1) > 0).any())
    return False


def _coll_bytes(op: _Op, comp: _Computation, conv_map: dict,
                half_set: set) -> tuple[float, float]:
    """Collective operand bytes with the framework dtype invariant: no
    large f32 tensor is ever communicated (grads, TP activation sums, and
    MoE dispatch are bf16 end-to-end at the jax level; f32 appears only in
    sub-MB stat reductions).  Large f32 collective operands in CPU HLO are
    promotion contamination (the convert that should precede the collective
    was hoisted past it by the excess-precision simplifier) — charge bf16."""
    body = op.line.split(op.opcode + "(", 1)[1]
    total = raw = 0.0
    for name in _OPERAND_RE.findall(body.split(")")[0] + ")"):
        t = comp.table.get(name)
        if not t:
            continue
        ob_raw = _shape_bytes(t)
        raw += ob_raw
        rname = _resolve(name, comp, conv_map)
        rt = comp.table.get(rname, t)
        ob = _shape_bytes(rt)
        dt, _ = _dims_of(rt)
        if dt == "f32":
            elems, _ = _shape_elems_dims(rt)
            if rname in half_set or elems > 1_000_000:
                ob /= 2.0
        total += ob
    return total, raw


def _operand_bytes(op: _Op, comp: _Computation, conv_map: dict = None,
                   half_set: set = frozenset()) -> tuple[float, float]:
    """(TPU-normalized bytes, raw bytes) of the op's operands."""
    body = op.line.split(op.opcode + "(", 1)[1]
    total = raw = 0.0
    is_dot = op.opcode in ("dot", "convolution")
    for name in _OPERAND_RE.findall(body.split(")")[0] + ")"):
        t = comp.table.get(name)
        if not t:
            continue
        raw += _shape_bytes(t)
        if conv_map is not None:
            total += _eff_bytes(name, comp, conv_map, half_set,
                                force_half=is_dot)
        else:
            total += _shape_bytes(t)
    return total, raw


def _comp_ctx(comp: _Computation, conv_maps: dict):
    """(conv_map, half_set) for one computation."""
    conv_map = conv_maps.get(comp.name, {})
    half_set = _build_bf16_dots(comp, conv_map)
    return conv_map, half_set


def _op_hbm_bytes(op: _Op, comp: _Computation, comps: dict, conv_map: dict,
                  half_set: set) -> tuple[float, float, float]:
    """(hbm bytes, elided bytes, copy bytes) for one non-free op.

    Shared by the roofline walker and the per-op breakdown diagnostic so
    the two can never disagree."""
    oc = op.opcode

    def result_bytes():
        return _shape_bytes(op.type_str) * _res_factor(
            op, comp, conv_map, half_set)

    if op.name in conv_map:
        # pure dtype convert: free on TPU (fuses / never exists)
        _, raw = _operand_bytes(op, comp, conv_map, half_set)
        return 0.0, raw + _shape_bytes(op.type_str), 0.0
    if oc in ("broadcast", "fusion"):
        # generated values (broadcast of a constant / iota): never
        # materialized on TPU — they fuse into consumers, and the common
        # case here is the zeros-init of a scan's DUS-accumulated stacked
        # buffer, which buffer-aliasing kills entirely.
        body = op.line.split(oc + "(", 1)[1]
        names = _OPERAND_RE.findall(body.split(")")[0] + ")")
        if all(n.startswith(("constant", "iota")) for n in names):
            return 0.0, _shape_bytes(op.type_str), 0.0
    if oc == "dynamic-update-slice":
        # in-place on TPU: traffic = read update + write region,
        # not the whole buffer
        body = op.line.split(oc + "(", 1)[1]
        names = _OPERAND_RE.findall(body.split(")")[0] + ")")
        upd = _eff_bytes(names[1], comp, conv_map, half_set) if len(
            names) > 1 else 0
        return 2 * upd, 0.0, 0.0
    if oc in ("dynamic-slice", "slice", "gather"):
        # reads only the sliced/gathered region (= result), not the
        # whole operand — charging the operand would bill scanned
        # stacked params [L, ...] at L x their size.
        return 2 * result_bytes(), 0.0, 0.0
    if oc == "scatter":
        body = op.line.split(oc + "(", 1)[1]
        names = _OPERAND_RE.findall(body.split(")")[0] + ")")
        upd = _eff_bytes(names[-1], comp, conv_map, half_set) if names else 0
        return 2 * upd, 0.0, 0.0
    if oc == "copy":
        b, raw = _operand_bytes(op, comp, conv_map, half_set)
        b += _shape_bytes(op.type_str)
        return b, 0.0, b
    adj = _fusion_buffer_adjust(op, comp, comps) if oc == "fusion" else None
    if adj:
        skip, extra = adj
        body2 = op.line.split(oc + "(", 1)[1]
        names = _OPERAND_RE.findall(body2.split(")")[0] + ")")
        total = raw = 0.0
        res_aliased = False
        _, rdims = _dims_of(op.type_str)
        for i, name in enumerate(names):
            t = comp.table.get(name)
            if not t:
                continue
            raw += _shape_bytes(t)
            if i in skip:
                _, pdims = _dims_of(t)
                if pdims == rdims:
                    res_aliased = True
                continue
            total += _eff_bytes(name, comp, conv_map, half_set)
        rb = 0.0 if res_aliased else result_bytes()
        return (total + extra + rb,
                max(raw - total - extra, 0.0) + (_shape_bytes(op.type_str) - rb),
                0.0)
    b, raw = _operand_bytes(op, comp, conv_map, half_set)
    return (b + result_bytes(), (raw - b) + (
        _shape_bytes(op.type_str) - result_bytes()), 0.0)


def _cost_computation(comp_name: str, comps: dict, memo: dict,
                      conv_maps: dict) -> HloCost:
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    cost = HloCost()
    if comp is None:
        memo[comp_name] = cost
        return cost
    memo[comp_name] = cost  # break cycles defensively
    conv_map, half_set = _comp_ctx(comp, conv_maps)

    for op in comp.ops:
        oc = op.opcode
        if oc.endswith("-done"):
            continue  # async pair: accounted at the -start op
        if oc == "while":
            trip = _while_trip_count(op, comps)
            if trip is None:
                trip = 1
                cost.unparsed_while += 1
            for mm in _CALL_REFS.finditer(op.line):
                sub = _cost_computation(mm.group(1), comps, memo, conv_maps)
                cost.add(sub, mult=trip)
            continue
        if oc in ("call", "conditional", "fusion", "reduce", "sort", "scatter",
                  "map", "reduce-window", "select-and-scatter",
                  "async-start", "custom-call"):
            for mm in _CALL_REFS.finditer(op.line):
                sub = _cost_computation(mm.group(1), comps, memo, conv_maps)
                # called computations of fusions/reduces are elementwise
                # bodies — only their dot flops (and any collectives) matter
                inner = HloCost(flops=sub.flops,
                                collective_bytes=sub.collective_bytes,
                                collectives=dict(sub.collectives),
                                collective_count=sub.collective_count)
                cost.add(inner)
        if oc == "dot" or oc == "convolution":
            cost.flops += _dot_flops(op, comp, comps)
        is_coll = any(oc.startswith(c) for c in _COLLECTIVES)
        if is_coll:
            # psum_invariant lowers to an all-reduce whose reducer is a
            # COPY: a vma bookkeeping no-op (every participant already
            # holds the identical value) - it moves no new data on TPU.
            called_root_copy = False
            for mm in _CALL_REFS.finditer(op.line):
                called = comps.get(mm.group(1))
                if called is not None and called.ops and \
                        called.ops[-1].opcode == "copy":
                    called_root_copy = True
                break
            if called_root_copy:
                _, raw = _operand_bytes(op, comp, conv_map, half_set)
                cost.elided_bytes += raw
                continue
            b, raw = _coll_bytes(op, comp, conv_map, half_set)
            base = next(c for c in _COLLECTIVES if oc.startswith(c))
            cost.collectives[base] = cost.collectives.get(base, 0.0) + b
            cost.collective_bytes += b
            cost.collective_count += 1
            cost.elided_bytes += raw - b
            if _spans_pods(op.line):
                cost.collective_bytes_xpod += b
        if oc not in _FREE_OPS:
            b, el, cp = _op_hbm_bytes(op, comp, comps, conv_map, half_set)
            cost.bytes_accessed += b
            cost.elided_bytes += el
            cost.copy_bytes += cp
    memo[comp_name] = cost
    return cost


def analyze_hlo(hlo_text: str) -> HloCost:
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return HloCost()
    conv_maps = _build_convert_maps(comps)
    return _cost_computation(entry, comps, {}, conv_maps)
