import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod, or 2x16x16 multi-pod),
  2. resolves the arch's sharding rules and materializes ShapeDtypeStruct
     stand-ins for params / optimizer state / batch / caches (NO device
     allocation anywhere),
  3. ``jax.jit(step).lower(...)`` then ``.compile()`` — any sharding
     mismatch, non-divisible axis, or unsupported collective fails here,
  4. prints ``memory_analysis()`` (per-device bytes: proves what fits) and
     ``cost_analysis()``, walks the optimized HLO for trip-count-correct
     FLOPs / HBM bytes / collective bytes, and derives the three roofline
     terms against v5e constants,
  5. appends a JSON record to the results file (resumable across runs).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --all            # every applicable cell
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse      # noqa: E402
import gc            # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, list_archs            # noqa: E402
from repro.configs.shapes import (                          # noqa: E402
    SHAPES, applicable, serve_inputs, train_inputs,
)
from repro.distributed.context import (                     # noqa: E402
    ShardingRules, activate,
)
from repro.launch.hlo_analysis import analyze_hlo           # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models.common import (                           # noqa: E402
    ModelConfig, abstract_params,
)
from repro.models.transformer import (                      # noqa: E402
    active_params, model_specs, num_params,
)
from repro.optim.adamw import AdamWConfig, opt_state_specs  # noqa: E402
from repro.serve.step import make_prefill_step, make_serve_step  # noqa: E402
from repro.train.step import make_train_step                # noqa: E402

# ------------------------------------------------- hardware constants (v5e)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link
HBM_PER_CHIP = 16e9

DEFAULT_RESULTS = "results/dryrun.jsonl"


#: archs whose attention heads don't tile the 16-way model axis (40H, 20H,
#: or big replicated wk/wv) — their params take FSDP storage over 'data'
#: via the embed dim instead (gathered per layer by SPMD; overlappable).
_FSDP_ARCHS = ("qwen2.5-14b", "whisper-large-v3", "kimi-k2-1t-a32b")


def rules_for(cfg: ModelConfig, multi_pod: bool, fsdp_scope: str = "all",
              pp: bool = False):
    """(compute_rules, storage_rules) per arch.

    Compute rules steer ``constrain`` hints inside the model (intermediates
    may be padded by GSPMD, so non-divisible axes are fine there).  Storage
    rules resolve jit INPUT shardings, which must tile evenly — divisibility
    masking in ``ShardingCtx.spec`` drops what doesn't fit, and FSDP archs
    shard the d dims over the data axes instead.  ``fsdp_scope``:
    "all" (embed + attention + mlp d dims) or "attn" (attention weights
    only — the MLP keeps pure-TP storage; §Perf lever).
    """
    rules = ShardingRules()
    # with pipeline parallelism the pod axis holds STAGES, not data
    data_axes = ("data", "pod") if (multi_pod and not pp) else ("data",)
    if pp:
        rules = rules.override(layers="pod")
    if getattr(cfg, "seq_shard_norms", 0):
        rules = rules.override(seq_sp="model")
    if cfg.family == "moe":
        # expert weights: FSDP storage over data axes, gathered inside the
        # MoE shard_map (its AD transpose reduce-scatters the grads).
        rules = rules.override(expert_mlp=data_axes)
    if cfg.name.startswith("gemma3") or cfg.name.startswith("xlstm"):
        # 4 q-heads / <=4 kv-heads cannot shard 16-way; attention stays
        # replicated over 'model' and the MLP carries the TP.
        rules = rules.override(qheads=None, kv_heads=None)
    storage = rules
    if cfg.name in _FSDP_ARCHS:
        fsdp = dict(attn_in=data_axes, attn_out_d=data_axes)
        if fsdp_scope == "all":
            fsdp["embed"] = data_axes
        storage = rules.override(**fsdp)
    return rules, storage


def opt_rules_for(storage: ShardingRules, multi_pod: bool) -> ShardingRules:
    """ZeRO-1: moments additionally sharded over the data axes via the
    d dims (divisible by 32 for every assigned arch)."""
    data_axes = ("data", "pod") if multi_pod else ("data",)
    return storage.override(embed=data_axes, attn_in=data_axes,
                            attn_out_d=data_axes)


def decode_rules(cfg: ModelConfig, rules: ShardingRules,
                 batch: int, model_axis: int = 16) -> ShardingRules:
    """Decode-cache sharding strategy.

    * batch==1 (long_500k): seq-shard the cache over 'data' (batch can't
      shard; masking would otherwise leave the 500k cache replicated).
    * kv-heads divide the model axis: keep head-sharded caches.
    * otherwise (GQA kv=8 vs model=16): seq-shard the cache over 'model' —
      attention reduces over the sharded seq axis via GSPMD collectives.
    """
    if batch <= 8:
        if cfg.n_kv_heads % model_axis == 0:
            return rules.override(cache_seq="data")
        return rules.override(cache_seq=("data", "model"), kv_heads=None)
    if cfg.n_kv_heads % model_axis != 0:
        return rules.override(cache_seq="model", kv_heads=None)
    return rules


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: str | None = None, overrides: dict | None = None,
             fsdp_scope: str = "all", tag: str | None = None,
             pp: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}

    if pp and cfg.name in _FSDP_ARCHS:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped",
                "reason": "pp unsupported with FSDP storage (see "
                          "repro.distributed.pipeline docstring)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rules, storage_rules = rules_for(cfg, multi_pod, fsdp_scope=fsdp_scope,
                                     pp=pp)
    if shape.kind == "decode":
        rules = decode_rules(cfg, rules, shape.batch,
                             model_axis=mesh.shape["model"])
    opt_cfg = AdamWConfig(
        moment_dtype="bfloat16" if cfg.name.startswith("kimi") else "float32")

    t0 = time.time()
    specs = model_specs(cfg)
    with activate(mesh, storage_rules):
        params = abstract_params(specs, dtype=jnp.bfloat16)
    with activate(mesh, rules):
        if shape.kind == "train":
            with activate(mesh, opt_rules_for(storage_rules, multi_pod)):
                opt_specs = opt_state_specs(specs, opt_cfg)
                m = abstract_params(opt_specs["m"],
                                    dtype=jnp.dtype(opt_cfg.moment_dtype))
                v = abstract_params(opt_specs["v"],
                                    dtype=jnp.dtype(opt_cfg.moment_dtype))
            state = {"params": params,
                     "opt": {"m": m, "v": v,
                             "step": jax.ShapeDtypeStruct((), jnp.float32)},
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
            batch = train_inputs(cfg, shape)
            if pp:
                from repro.distributed.pipeline import (
                    make_pp_forward, pp_lm_loss)
                from repro.optim.adamw import adamw_apply
                fwd = make_pp_forward(cfg, mesh,
                                      n_microbatches=max(cfg.microbatches, 4))

                def step_fn(st, b):
                    loss, grads = jax.value_and_grad(
                        lambda p: pp_lm_loss(p, cfg, b, fwd))(st["params"])
                    new_p, new_opt, om = adamw_apply(
                        grads, st["opt"], st["params"], opt_cfg)
                    return ({"params": new_p, "opt": new_opt,
                             "step": st["step"] + 1}, {"loss": loss, **om})
            else:
                step_fn = make_train_step(cfg, opt_cfg)
            lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(state, batch)
        elif shape.kind == "prefill":
            batch = train_inputs(cfg, shape)
            step_fn = make_prefill_step(cfg)
            lowered = jax.jit(step_fn).lower(params, batch)
        else:  # decode
            cache, token, pos = serve_inputs(cfg, shape)
            step_fn = make_serve_step(cfg)
            lowered = jax.jit(step_fn, donate_argnums=(1,)).lower(
                params, cache, token, pos)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    print(ma)
    from repro.compat import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})

    hlo = compiled.as_text()
    # always archive the optimized HLO (gzipped) so the roofline walker can
    # be refined without recompiling 66 cells on one CPU core
    import gzip
    hlo_dir = os.path.join(os.path.dirname(DEFAULT_RESULTS) or ".", "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    hlo_path = os.path.join(
        hlo_dir, f"{arch}_{shape_name}_{'2x16x16' if multi_pod else '16x16'}"
        f"{suffix}.hlo.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    cost = analyze_hlo(hlo)

    # roofline terms (per device; post-SPMD HLO shapes are per-device)
    t_compute = cost.flops / PEAK_FLOPS
    t_memory = cost.bytes_accessed / HBM_BW
    t_coll = cost.collective_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)

    n_total = num_params(cfg)
    n_active = active_params(cfg)
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    mult = 3 if shape.kind == "train" else 1  # fwd+bwd
    model_flops = 2.0 * n_active * tokens * mult          # global
    model_flops_per_chip = model_flops / n_chips
    useful_ratio = (model_flops_per_chip / cost.flops) if cost.flops else 0.0

    arg_bytes = int(ma.argument_size_in_bytes) if ma else None
    temp_bytes = int(ma.temp_size_in_bytes) if ma else None
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        **({"variant": tag} if tag else {}),
        **({"overrides": {k: str(v) for k, v in overrides.items()}}
           if overrides else {}),
        "status": "ok",
        "n_chips": n_chips,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_dev": arg_bytes,
            "temp_bytes_per_dev": temp_bytes,
            "output_bytes_per_dev": int(ma.output_size_in_bytes) if ma else None,
            "fits_16gb": (arg_bytes + temp_bytes) < HBM_PER_CHIP
            if ma else None,
        },
        "xla_cost_analysis": {
            "flops_body_once": ca.get("flops"),
            "bytes_body_once": ca.get("bytes accessed"),
        },
        "hlo_walk": {
            "flops_per_dev": cost.flops,
            "hbm_bytes_per_dev": cost.bytes_accessed,
            "collective_bytes_per_dev": cost.collective_bytes,
            "collectives": {k: int(v) for k, v in cost.collectives.items()},
            "collective_count": cost.collective_count,
            "unparsed_while": cost.unparsed_while,
            "copy_bytes_per_dev": cost.copy_bytes,
            "elided_bytes_per_dev": cost.elided_bytes,
        },
        "roofline": {
            **{k: round(v, 6) for k, v in terms.items()},
            "bottleneck": bottleneck.replace("_s", ""),
            "model_flops_global": model_flops,
            "useful_flops_ratio": round(useful_ratio, 4),
            "params_total": n_total,
            "params_active": n_active,
        },
    }
    del compiled, lowered
    gc.collect()
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all applicable (arch x shape) cells for this mesh")
    ap.add_argument("--out", default=DEFAULT_RESULTS)
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already recorded in --out")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="config override key=value (perf variants), "
                         "e.g. --set remat=dots --set microbatches=2")
    ap.add_argument("--fsdp-scope", default="all", choices=("all", "attn"))
    ap.add_argument("--tag", default=None,
                    help="variant label recorded with the results")
    ap.add_argument("--pp", action="store_true",
                    help="pipeline the pod axis (multi-pod train cells): "
                         "stages over 'pod' via shard_map+ppermute")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.overrides:
        k, _, v = kv.partition("=")
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    failures = 0
    for arch, shape in cells:
        if (arch, shape, mesh_name) in done:
            print(f"# skip (done): {arch} {shape} {mesh_name}", flush=True)
            continue
        print(f"# === {arch} x {shape} @ {mesh_name}"
              f"{' [' + args.tag + ']' if args.tag else ''} ===", flush=True)
        try:
            rec = run_cell(arch, shape, args.multi_pod,
                           save_hlo=args.save_hlo, overrides=overrides,
                           fsdp_scope=args.fsdp_scope, tag=args.tag,
                           pp=args.pp)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        print(json.dumps(rec.get("roofline", rec), indent=None), flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
