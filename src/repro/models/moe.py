"""Mixture-of-Experts block with expert parallelism.

Two dispatch paths, chosen statically per call site:

* **a2a path** (training / prefill): tokens are sequence-sharded over the
  ``model`` mesh axis; each device routes its own tokens, buckets them by
  destination expert shard with a capacity limit, and exchanges buckets via
  ``jax.lax.all_to_all`` inside a ``shard_map``.  Expert weights are sharded
  over ``model`` (expert dim) — classic expert parallelism with explicit,
  inspectable collectives (the roofline's all-to-all bytes come straight
  from here).  Optional FSDP storage sharding of the expert weights over the
  data axes all-gathers them inside the block (and its AD transpose
  reduce-scatters the grads — ``check_vma`` keeps this correct).

* **one-hot path** (decode, tiny token counts, or no mesh): the classic
  Switch-style dispatch einsum.  Its FLOPs are O(T·E·cap·d), catastrophic at
  training token counts but optimal for a 128-token decode step, and it
  needs no divisibility constraints.

Capacity overflows drop tokens (they ride the residual), standard practice;
an auxiliary load-balance loss (Switch-style) is returned for training.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.distributed.context import active_ctx, constrain
from repro.models.common import ModelConfig, ParamSpec

__all__ = ["moe_specs", "moe_block"]


def moe_specs(cfg: ModelConfig) -> dict:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    specs = {
        "router": ParamSpec((d, E), ("embed", None), "normal", s_in),
        "wi": ParamSpec((E, d, f), ("expert", "expert_mlp", None), "normal", s_in),
        "wg": ParamSpec((E, d, f), ("expert", "expert_mlp", None), "normal", s_in),
        "wo": ParamSpec((E, f, d), ("expert", "expert_mlp", None), "normal", s_out),
    }
    if cfg.mlp_act != "swiglu":
        del specs["wg"]
    return specs


def _gates(cfg: ModelConfig, xt: jax.Array, router: jax.Array):
    """Router: returns (weights [T,k] f32, indices [T,k] i32, lb_loss)."""
    with jax.named_scope("f32c"):
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
        )
        # Switch-style load-balance loss: E * sum_e f_e * P_e
        E = cfg.n_experts
        me = jnp.mean(probs, axis=0)                        # [E] mean prob
        one_hot_top1 = jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32)
        ce = jnp.mean(one_hot_top1, axis=0)                 # [E] top1 fraction
        lb_loss = E * jnp.sum(me * ce)
    return gate_vals, gate_idx, lb_loss


def _expert_mlp(cfg: ModelConfig, xs: jax.Array, wi, wg, wo) -> jax.Array:
    """xs [E_loc, C, d] -> [E_loc, C, d] through each local expert."""
    h = jnp.einsum("ecd,edf->ecf", xs, wi)
    if wg is not None:
        g = jnp.einsum("ecd,edf->ecf", xs, wg)
        h = jax.nn.silu(g) * h
    else:
        h = jnp.square(jax.nn.relu(h)) if cfg.mlp_act == "relu2" else jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ------------------------------------------------------------ one-hot path

def _moe_onehot(cfg: ModelConfig, xt, gate_vals, gate_idx, wi, wg, wo):
    """Switch dispatch-einsum; T must be small (decode) for sane FLOPs."""
    T = xt.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    cap = max(int(math.ceil(T * k / E * cfg.capacity_factor)), 1)

    flat_e = gate_idx.reshape(-1)                           # [T*k]
    onehot_e = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # [T*k, E]
    pos = jnp.cumsum(onehot_e, axis=0) - 1                  # position in expert
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    # dispatch tensor [T*k, E, cap]
    disp = (jax.nn.one_hot(flat_e, E, dtype=xt.dtype)[:, :, None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=xt.dtype)[:, None, :cap])
    xe = jnp.einsum("sec,sd->ecd", disp, jnp.repeat(xt, k, axis=0))
    ye = _expert_mlp(cfg, xe, wi, wg, wo)
    weights = gate_vals.reshape(-1).astype(xt.dtype)        # [T*k]
    out_sel = jnp.einsum("sec,ecd->sd", disp, ye) * weights[:, None]
    return out_sel.reshape(T, k, -1).sum(axis=1)


# --------------------------------------------------------------- a2a path

def _moe_a2a_local(cfg: ModelConfig, xt, gate_vals, gate_idx, wi, wg, wo,
                   *, n_shards: int, fsdp_axes: tuple):
    """Per-device body (inside shard_map).  xt [T_loc, d] are THIS device's
    tokens; wi/wg/wo [E_loc, ...] are THIS device's experts (possibly
    FSDP-sharded on dim 1 over ``fsdp_axes``)."""
    if fsdp_axes:
        wi = jax.lax.all_gather(wi, fsdp_axes, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, fsdp_axes, axis=1, tiled=True)
        if wg is not None:
            wg = jax.lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)

    T_loc, d = xt.shape
    E, k, M = cfg.n_experts, cfg.top_k, n_shards
    E_loc = E // M
    cap = max(int(math.ceil(T_loc * k / M * cfg.capacity_factor)), 1)

    flat_e = gate_idx.reshape(-1)                       # [T_loc*k] global ids
    dest = flat_e // E_loc                              # destination shard
    local_e = flat_e - dest * E_loc                     # id on that shard

    # position within destination bucket
    onehot_d = jax.nn.one_hot(dest, M, dtype=jnp.int32)
    pos = jnp.cumsum(onehot_d, axis=0) - 1
    pos = jnp.take_along_axis(pos, dest[:, None], axis=1)[:, 0]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                   # cap = drop slot

    token_of = jnp.arange(T_loc * k) // k
    send_x = jnp.zeros((M, cap, d), xt.dtype).at[dest, pos_c].set(
        xt[token_of], mode="drop")
    send_e = jnp.full((M, cap), E_loc, jnp.int32).at[dest, pos_c].set(
        local_e, mode="drop")                           # E_loc = empty slot

    recv_x = jax.lax.all_to_all(send_x, "model", split_axis=0, concat_axis=0)
    recv_e = jax.lax.all_to_all(send_e, "model", split_axis=0, concat_axis=0)

    R = M * cap
    rx, re = recv_x.reshape(R, d), recv_e.reshape(R)
    cap2 = max(int(math.ceil(R / E_loc * cfg.capacity_factor)), 1)
    onehot_e = jax.nn.one_hot(re, E_loc, dtype=jnp.int32)   # empty rows: all 0
    pos2 = jnp.cumsum(onehot_e, axis=0) - 1
    pos2 = jnp.take_along_axis(
        pos2, jnp.minimum(re, E_loc - 1)[:, None], axis=1)[:, 0]
    keep2 = (re < E_loc) & (pos2 < cap2)
    e_c = jnp.where(keep2, re, 0)
    p_c = jnp.where(keep2, pos2, cap2)

    buf = jnp.zeros((E_loc, cap2, d), xt.dtype).at[e_c, p_c].set(
        jnp.where(keep2[:, None], rx, 0), mode="drop")
    yb = _expert_mlp(cfg, buf, wi, wg, wo)              # [E_loc, cap2, d]

    y_rows = yb[e_c, jnp.where(keep2, pos2, 0)] * keep2[:, None].astype(xt.dtype)
    back = jax.lax.all_to_all(
        y_rows.reshape(M, cap, d), "model", split_axis=0, concat_axis=0)

    sel = back[dest, jnp.where(keep, pos, 0)] * keep[:, None].astype(xt.dtype)
    weights = gate_vals.reshape(-1).astype(xt.dtype)
    out = (sel * weights[:, None]).reshape(T_loc, k, d).sum(axis=1)
    return out


# ----------------------------------------------------------------- entry

def moe_block(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [B, S, d] -> (y [B, S, d], lb_loss scalar)."""
    B, S, d = x.shape
    ctx = active_ctx()
    wi, wo = p["wi"], p["wo"]
    wg = p.get("wg")

    use_a2a = False
    if ctx is not None:
        M = ctx.axis_size("model")
        # a2a path needs the sequence divisible across expert shards and
        # enough tokens to be worth it (decode steps use the one-hot path).
        use_a2a = S % max(M, 1) == 0 and B * S >= 4 * M

    if not use_a2a:
        xt = x.reshape(B * S, d)
        gate_vals, gate_idx, lb = _gates(cfg, xt, p["router"])
        y = _moe_onehot(cfg, xt, gate_vals.astype(x.dtype), gate_idx,
                        wi, wg, wo)
        return y.reshape(B, S, d), lb

    # ---- a2a path: reshard activations seq-wise over 'model' ----
    x = constrain(x, "batch", "moe_seq", "embed")
    xt = x.reshape(B * S, d)
    gate_vals, gate_idx, lb = _gates(cfg, xt, p["router"])
    gate_vals = gate_vals.astype(x.dtype)

    mesh = ctx.mesh
    batch_axes = ctx.batch_axes()
    fsdp = ctx.rules.rules.get("expert_mlp")
    if isinstance(fsdp, str):
        fsdp = (fsdp,)
    fsdp_axes = tuple(a for a in (fsdp or ()) if a in mesh.axis_names)

    x_spec = P((*batch_axes, "model"))
    w_spec = P("model", fsdp_axes if fsdp_axes else None, None)

    local = lambda xt_, gv_, gi_, wi_, wg_, wo_: _moe_a2a_local(
        cfg, xt_, gv_, gi_, wi_, wg_, wo_,
        n_shards=ctx.axis_size("model"), fsdp_axes=fsdp_axes,
    )
    if wg is None:
        fn = shard_map(
            lambda xt_, gv_, gi_, wi_, wo_: local(xt_, gv_, gi_, wi_, None, wo_),
            mesh=mesh,
            in_specs=(P((*batch_axes, "model"), None), P((*batch_axes, "model"), None),
                      P((*batch_axes, "model"), None), w_spec, w_spec),
            out_specs=P((*batch_axes, "model"), None),
        )
        yt = fn(xt, gate_vals, gate_idx, wi, wo)
    else:
        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(P((*batch_axes, "model"), None), P((*batch_axes, "model"), None),
                      P((*batch_axes, "model"), None), w_spec, w_spec, w_spec),
            out_specs=P((*batch_axes, "model"), None),
        )
        yt = fn(xt, gate_vals, gate_idx, wi, wg, wo)

    y = yt.reshape(B, S, d)
    y = constrain(y, "batch", "seq", "embed")
    return y, lb
