"""Model assembly: every assigned architecture as a scan-of-super-blocks.

An architecture compiles to a *program*:

    program = (group_def, n_groups, remainder_def)

where ``group_def`` is a tuple of block kinds (e.g. gemma3's
``("attn_local", ..., "attn_global")``; zamba2's five mamba blocks plus the
*shared* attention block).  The group's parameters are stacked with a
leading ``n_groups`` dim and the stack is consumed by ``jax.lax.scan`` —
which is what keeps 61-81-layer configs lowerable/compilable on one CPU
core and the HLO size independent of depth.  Remainder layers (depth not
divisible by the pattern) are unrolled with their own params.

Block kinds:
  attn / attn_local / attn_global / attn_bidir  -> attention + MLP
  moe                                           -> attention + MoE FFN
  xattn                                         -> cross-attn + MLP (VLM)
  dec_attn                                      -> self + cross + MLP (whisper dec)
  mamba / mlstm / slstm                         -> recurrent blocks (no FFN)

Caches mirror the program structure so decode scans over (params, cache)
pairs in lockstep.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import ssm
from repro.models.common import ModelConfig, ParamSpec
from repro.models.layers import (
    apply_norm,
    attention,
    attention_from_cache,
    attention_specs,
    mlp,
    mlp_specs,
    norm_spec,
)
from repro.models.moe import moe_block, moe_specs

__all__ = [
    "program_for",
    "model_specs",
    "forward",
    "lm_loss",
    "cache_specs",
    "init_cache",
    "prefill",
    "decode_step",
    "num_params",
    "active_params",
]


# ------------------------------------------------------------------ programs

def program_for(cfg: ModelConfig) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
    """(group_def, n_groups, remainder_def) for the decoder stack."""
    L = cfg.n_layers
    if cfg.family == "moe":
        return ("moe",), L, ()
    if cfg.family == "hybrid":
        per = cfg.hybrid_period
        grp = ("mamba",) * per + ("shared_attn",)
        return grp, L // per, ("mamba",) * (L % per)
    if cfg.family == "ssm":
        per = cfg.slstm_every
        grp = ("mlstm",) * (per - 1) + ("slstm",)
        return grp, L // per, ("mlstm",) * (L % per)
    if cfg.family == "vlm":
        per = cfg.cross_attn_period
        grp = ("attn",) * (per - 1) + ("xattn",)
        return grp, L // per, ("attn",) * (L % per)
    if cfg.family == "encdec":
        return ("dec_attn",), L, ()
    # dense
    if cfg.local_global_pattern:
        per = cfg.local_global_pattern + 1
        grp = ("attn_local",) * cfg.local_global_pattern + ("attn_global",)
        return grp, L // per, ("attn_local",) * (L % per)
    return ("attn",), L, ()


def _block_specs(cfg: ModelConfig, kind: str) -> dict:
    n = lambda: norm_spec(cfg)
    if kind in ("attn", "attn_local", "attn_global", "attn_bidir", "shared_attn"):
        return {"ln1": n(), "attn": attention_specs(cfg), "ln2": n(),
                "mlp": mlp_specs(cfg)}
    if kind == "moe":
        return {"ln1": n(), "attn": attention_specs(cfg), "ln2": n(),
                "moe": moe_specs(cfg)}
    if kind == "xattn":
        return {"ln1": n(), "xattn": attention_specs(cfg, cross=True),
                "gate": ParamSpec((1,), (None,), "zeros"),
                "ln2": n(), "mlp": mlp_specs(cfg)}
    if kind == "dec_attn":
        return {"ln1": n(), "attn": attention_specs(cfg),
                "ln_x": n(), "xattn": attention_specs(cfg, cross=True),
                "ln2": n(), "mlp": mlp_specs(cfg)}
    if kind == "mamba":
        return {"ln1": n(), "mamba": ssm.mamba2_specs(cfg)}
    if kind == "mlstm":
        return {"ln1": n(), "mlstm": ssm.mlstm_specs(cfg)}
    if kind == "slstm":
        specs = {"ln1": n(), "slstm": ssm.slstm_specs(cfg)}
        if cfg.d_ff > 0:
            specs["ln2"] = n()
            specs["mlp"] = mlp_specs(cfg)
        return specs
    raise ValueError(kind)


def _stack(specs: Any, n: int) -> Any:
    """Prepend a stacked 'layers' dim to every ParamSpec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.logical),
                            s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _group_specs(cfg: ModelConfig, group_def: tuple[str, ...]) -> dict:
    out = {}
    for i, kind in enumerate(group_def):
        if kind == "shared_attn":
            continue  # shared params live outside the stack
        out[f"b{i}_{kind}"] = _block_specs(cfg, kind)
    return out


def model_specs(cfg: ModelConfig) -> dict:
    grp, n_groups, rem = program_for(cfg)
    d = cfg.d_model
    specs: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"), "normal",
                           1.0 / math.sqrt(d)),
        "final_norm": norm_spec(cfg),
        "blocks": _stack(_group_specs(cfg, grp), n_groups),
        "tail": {f"t{i}_{k}": _block_specs(cfg, k) for i, k in enumerate(rem)},
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, cfg.vocab_size), ("embed", "vocab"),
                                     "normal", 1.0 / math.sqrt(d))
    if "shared_attn" in grp:
        specs["shared_attn"] = _block_specs(cfg, "attn")
    if cfg.family == "encdec":
        specs["encoder"] = {
            "blocks": _stack(_group_specs(cfg, ("attn_bidir",)),
                             cfg.n_encoder_layers),
            "final_norm": norm_spec(cfg),
        }
    if cfg.frontend_dim:
        specs["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, d), ("frames", "embed"), "normal",
            1.0 / math.sqrt(cfg.frontend_dim))
    return specs


# ------------------------------------------------------------------ blocks

def _apply_block(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                 memory: Optional[jax.Array], aux: jax.Array,
                 shared: Optional[dict]) -> tuple[jax.Array, jax.Array]:
    """One block, full-sequence mode.  memory = encoder/vision stream."""
    eps, nk = cfg.norm_eps, cfg.norm
    # Megatron-style sequence parallelism (§Perf lever ``seq_shard_norms``):
    # the residual stream is sharded over 'model' along seq for the
    # norm/elementwise segments; GSPMD inserts the all-gather before the
    # TP matmuls and the reduce-scatter after them (replacing the TP
    # all-reduce), cutting [B,S,D] elementwise HBM traffic model-axis-fold.
    if cfg.seq_shard_norms:
        sp = lambda t: constrain(t, "batch", "seq_sp", "embed")  # noqa: E731
    else:
        sp = lambda t: t  # noqa: E731
    if kind in ("attn", "attn_local", "attn_global", "attn_bidir", "shared_attn"):
        pp = shared if kind == "shared_attn" else p
        window = cfg.attn_window if kind == "attn_local" else None
        causal = kind != "attn_bidir"
        use_rope = cfg.family != "encdec"
        x = sp(x)
        h = apply_norm(pp["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        x = sp(x + attention(pp["attn"], cfg, h, causal=causal, window=window,
                             use_rope=use_rope))
        h = apply_norm(pp["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        x = sp(x + mlp(pp["mlp"], cfg, h))
        return x, aux
    if kind == "moe":
        x = sp(x)
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        x = sp(x + attention(p["attn"], cfg, h, causal=True))
        h = apply_norm(p["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        y, lb = moe_block(p["moe"], cfg, h)
        return sp(x + y), aux + lb
    if kind == "xattn":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        y = attention(p["xattn"], cfg, h, kv_x=memory, causal=False,
                      use_rope=False)
        x = x + jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * y
        h = apply_norm(p["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        return x + mlp(p["mlp"], cfg, h), aux
    if kind == "dec_attn":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        x = x + attention(p["attn"], cfg, h, causal=True, use_rope=False)
        h = apply_norm(p["ln_x"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        x = x + attention(p["xattn"], cfg, h, kv_x=memory, causal=False,
                          use_rope=False)
        h = apply_norm(p["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        return x + mlp(p["mlp"], cfg, h), aux
    if kind == "mamba":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        return x + ssm.mamba2_forward(p["mamba"], cfg, h), aux
    if kind == "mlstm":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        return x + ssm.mlstm_forward(p["mlstm"], cfg, h), aux
    if kind == "slstm":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        x = x + ssm.slstm_forward(p["slstm"], cfg, h)
        if cfg.d_ff > 0:
            h = apply_norm(p["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
            x = x + mlp(p["mlp"], cfg, h)
        return x, aux
    raise ValueError(kind)


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "full": save nothing


# ------------------------------------------------------------------ forward

def _positions_embed(cfg: ModelConfig, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.jdtype)
    if cfg.embed_scale != 1.0:
        x = x * jnp.asarray(cfg.embed_scale, cfg.jdtype)
    x = constrain(x, "batch", "seq", "embed")
    return x


def _encoder_forward(cfg: ModelConfig, params, frames):
    """Whisper encoder over stub frame embeddings [B, S_enc, F]."""
    x = jnp.einsum("bsf,fd->bsd", frames.astype(cfg.jdtype),
                   params["frontend_proj"])
    enc = params["encoder"]

    def body(carry, layer_params):
        x, aux = carry
        fn = _remat_wrap(
            cfg, lambda q, lp: _apply_block(cfg, "attn_bidir", lp["b0_attn_bidir"],
                                            q, None, jnp.float32(0.0), None)[0])
        return (fn(x, layer_params), aux), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), enc["blocks"])
    return apply_norm(enc["final_norm"], x, cfg.norm_eps, cfg.norm, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))


def forward(params: dict, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits [B,S,V], aux_loss scalar).

    batch: tokens [B,S] (+ frames [B,S_enc,F] for encdec, patches [B,P,F]
    for vlm).
    """
    tokens = batch["tokens"]
    x = _positions_embed(cfg, params, tokens)

    memory = None
    if cfg.family == "encdec":
        memory = _encoder_forward(cfg, params, batch["frames"])
    elif cfg.family == "vlm":
        memory = jnp.einsum("bpf,fd->bpd", batch["patches"].astype(cfg.jdtype),
                            params["frontend_proj"])

    grp, n_groups, rem = program_for(cfg)
    shared = params.get("shared_attn")

    def group_body(carry, gp):
        x, aux = carry
        for i, kind in enumerate(grp):
            p = None if kind == "shared_attn" else gp[f"b{i}_{kind}"]
            x, aux = _apply_block(cfg, kind, p, x, memory, aux, shared)
        return (x, aux), None

    body = _remat_wrap(cfg, lambda c, gp: group_body(c, gp)[0])
    (x, aux), _ = jax.lax.scan(lambda c, gp: (body(c, gp), None),
                               (x, jnp.float32(0.0)), params["blocks"])

    for i, kind in enumerate(rem):
        x, aux = _apply_block(cfg, kind, params["tail"][f"t{i}_{kind}"], x,
                              memory, aux, shared)

    x = apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.norm, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, aux


def lm_loss(params: dict, cfg: ModelConfig, batch: dict,
            aux_weight: float = 0.01) -> jax.Array:
    """Next-token cross-entropy (vocab-sharded-safe: no prob materialization)."""
    logits, aux = forward(params, cfg, batch)
    targets = batch["tokens"][:, 1:]
    if cfg.loss_dtype == "compute":
        # §Perf lever: lse in f32 (stable) but no f32 [B,S,V] logits copy
        # and a gather instead of the one-hot contraction.
        logits = logits[:, :-1]
        with jax.named_scope("f32c"):
            lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        label_logit = jnp.take_along_axis(
            logits, targets[..., None], axis=-1)[..., 0].astype(jnp.float32)
        nll = jnp.mean(lse - label_logit)
        return nll + aux_weight * aux
    with jax.named_scope("f32c"):
        logits = logits.astype(jnp.float32)
        logits = logits[:, :-1]
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(targets, cfg.vocab_size, dtype=jnp.float32)
        label_logit = jnp.sum(logits * onehot, axis=-1)
        nll = jnp.mean(lse - label_logit)
    return nll + aux_weight * aux


# ------------------------------------------------------------------- decode

_ATTN_KINDS = ("attn", "attn_local", "attn_global", "shared_attn", "moe",
               "dec_attn")


def _block_cache_specs(cfg: ModelConfig, kind: str, batch: int, s_max: int,
                       mem_len: int) -> dict:
    KV, hd = cfg.n_kv_heads, cfg.hd
    kv = lambda: {
        "k": ParamSpec((batch, s_max, KV, hd),
                       ("batch", "cache_seq", "kv_heads", "head_dim"), "zeros"),
        "v": ParamSpec((batch, s_max, KV, hd),
                       ("batch", "cache_seq", "kv_heads", "head_dim"), "zeros"),
    }
    if kind in ("attn", "attn_local", "attn_global", "shared_attn", "moe"):
        return kv()
    if kind == "dec_attn":
        return {**kv()}
    if kind == "xattn":
        return {}
    if kind == "mamba":
        d_inner, nheads, headdim = ssm._mamba_dims(cfg)
        return {
            "h": ParamSpec((batch, nheads, headdim, cfg.ssm_state),
                           ("batch", "qheads", None, "state"), "zeros"),
            "conv": ParamSpec((batch, cfg.ssm_conv - 1, d_inner),
                              ("batch", None, "mlp"), "zeros"),
        }
    if kind == "mlstm":
        H, hdm, _ = ssm._mlstm_dims(cfg)
        return {
            "C": ParamSpec((batch, H, hdm, hdm),
                           ("batch", "qheads", "head_dim", None), "zeros"),
            "n": ParamSpec((batch, H, hdm), ("batch", "qheads", "head_dim"),
                           "zeros"),
            "m": ParamSpec((batch, H), ("batch", "qheads"), "zeros"),
        }
    if kind == "slstm":
        H, hdm = ssm._slstm_dims(cfg)
        leaf = lambda: ParamSpec((batch, H, hdm),
                                 ("batch", "qheads", "head_dim"), "zeros")
        return {"c": leaf(), "n": leaf(), "h": leaf(), "m": leaf()}
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, s_max: int,
                mem_len: int = 0) -> dict:
    """Spec tree for the decode cache (float32 recurrent states are declared
    via their ParamSpec dtype at init/abstract time)."""
    grp, n_groups, rem = program_for(cfg)
    specs: dict[str, Any] = {
        "blocks": _stack(
            {f"b{i}_{k}": _block_cache_specs(cfg, k, batch, s_max, mem_len)
             for i, k in enumerate(grp) if k != "shared_attn"}, n_groups),
        "tail": {f"t{i}_{k}": _block_cache_specs(cfg, k, batch, s_max, mem_len)
                 for i, k in enumerate(rem)},
    }
    if "shared_attn" in grp:
        specs["shared"] = _stack(
            {"attn": _block_cache_specs(cfg, "shared_attn", batch, s_max,
                                        mem_len)}, n_groups)
    if cfg.family in ("encdec", "vlm"):
        specs["memory"] = ParamSpec((batch, mem_len, cfg.d_model),
                                    ("batch", "frames", "embed"), "zeros")
    return specs


_CACHE_F32 = ("h", "C", "n", "m", "c")  # recurrent states kept in f32


def _cache_dtype(path_leaf: str, default):
    return jnp.float32 if path_leaf in _CACHE_F32 else default


def init_cache(cfg: ModelConfig, batch: int, s_max: int, mem_len: int = 0):
    specs = cache_specs(cfg, batch, s_max, mem_len)

    def mk(path, s):
        leaf_name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        return jnp.zeros(s.shape, _cache_dtype(leaf_name, cfg.jdtype))

    return jax.tree_util.tree_map_with_path(
        mk, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _decode_block(cfg, kind, p, x, cache, pos, memory, shared):
    eps, nk = cfg.norm_eps, cfg.norm
    if kind in ("attn", "attn_local", "attn_global", "shared_attn", "moe"):
        pp = shared if kind == "shared_attn" else p
        window = cfg.attn_window if kind == "attn_local" else None
        h = apply_norm(pp["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        use_rope = cfg.family != "encdec"
        y, k_new, v_new = attention_from_cache(
            pp["attn"], cfg, h, cache["k"], cache["v"], pos, window=window,
            use_rope=use_rope)
        x = x + y
        h = apply_norm(pp["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        if kind == "moe":
            y, _ = moe_block(p["moe"], cfg, h)
        else:
            y = mlp(pp["mlp"], cfg, h)
        return x + y, {"k": k_new, "v": v_new}
    if kind == "dec_attn":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        y, k_new, v_new = attention_from_cache(
            p["attn"], cfg, h, cache["k"], cache["v"], pos, use_rope=False)
        x = x + y
        h = apply_norm(p["ln_x"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        x = x + attention(p["xattn"], cfg, h, kv_x=memory, causal=False,
                          use_rope=False)
        h = apply_norm(p["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        return x + mlp(p["mlp"], cfg, h), {"k": k_new, "v": v_new}
    if kind == "xattn":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        y = attention(p["xattn"], cfg, h, kv_x=memory, causal=False,
                      use_rope=False)
        x = x + jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * y
        h = apply_norm(p["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        return x + mlp(p["mlp"], cfg, h), {}
    if kind == "mamba":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        st = ssm.MambaState(h=cache["h"], conv=cache["conv"])
        y, st = ssm.mamba2_decode(p["mamba"], cfg, h, st)
        return x + y, {"h": st.h, "conv": st.conv}
    if kind == "mlstm":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        st = ssm.MLSTMState(C=cache["C"], n=cache["n"], m=cache["m"])
        y, st = ssm.mlstm_decode(p["mlstm"], cfg, h, st)
        return x + y, {"C": st.C, "n": st.n, "m": st.m}
    if kind == "slstm":
        h = apply_norm(p["ln1"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
        st = ssm.SLSTMState(c=cache["c"], n=cache["n"], h=cache["h"],
                            m=cache["m"])
        y, st = ssm.slstm_decode(p["slstm"], cfg, h, st)
        x = x + y
        if cfg.d_ff > 0:
            h = apply_norm(p["ln2"], x, eps, nk, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
            x = x + mlp(p["mlp"], cfg, h)
        return x, {"c": st.c, "n": st.n, "h": st.h, "m": st.m}
    raise ValueError(kind)


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                token: jax.Array, pos: jax.Array):
    """One decode step.  token [B,1] int32, pos scalar int32.

    Returns (logits [B,V], new_cache)."""
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.jdtype)
    if cfg.embed_scale != 1.0:
        x = x * jnp.asarray(cfg.embed_scale, cfg.jdtype)
    grp, n_groups, rem = program_for(cfg)
    shared = params.get("shared_attn")
    memory = cache.get("memory")

    def group_body(x, gp_and_cache):
        gp, gc = gp_and_cache
        new_gc = {}
        for i, kind in enumerate(grp):
            if kind == "shared_attn":
                continue
            key = f"b{i}_{kind}"
            x, new_gc[key] = _decode_block(cfg, kind, gp[key], x, gc[key],
                                           pos, memory, shared)
        return x, new_gc

    if "shared_attn" in grp:
        # shared-attn caches are per-group: scan over (params-stack, caches)
        def body(x, inp):
            gp, gc, sc = inp
            new_gc = {}
            for i, kind in enumerate(grp):
                if kind == "shared_attn":
                    x, new_s = _decode_block(cfg, kind, None, x, sc["attn"],
                                             pos, memory, shared)
                    continue
                key = f"b{i}_{kind}"
                x, new_gc[key] = _decode_block(cfg, kind, gp[key], x, gc[key],
                                               pos, memory, shared)
            return x, (new_gc, {"attn": new_s})

        x, (new_blocks, new_shared) = jax.lax.scan(
            body, x, (params["blocks"], cache["blocks"], cache["shared"]))
        new_cache = {**cache, "blocks": new_blocks, "shared": new_shared}
    else:
        x, new_blocks = jax.lax.scan(group_body, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache = {**cache, "blocks": new_blocks}

    new_tail = {}
    for i, kind in enumerate(rem):
        key = f"t{i}_{kind}"
        x, new_tail[key] = _decode_block(cfg, kind, params["tail"][key], x,
                                         cache["tail"][key], pos, memory,
                                         shared)
    new_cache["tail"] = new_tail

    x = apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.norm, cfg.norm_mult_dtype == "float32",
                   custom_bwd=bool(cfg.norm_custom_bwd))
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return logits[:, 0], new_cache


def prefill(params: dict, cfg: ModelConfig, batch: dict):
    """Prefill = full forward returning last-position logits.

    The returned logits feed decode; cache population during prefill is a
    serving-path optimization (hillclimb candidate) — the dry-run's prefill
    cell measures the forward cost, which dominates."""
    logits, _ = forward(params, cfg, batch)
    return logits[:, -1]


# ------------------------------------------------------------------- counts

def num_params(cfg: ModelConfig) -> int:
    from repro.models.common import spec_tree_num_params
    return spec_tree_num_params(model_specs(cfg))


def active_params(cfg: ModelConfig) -> int:
    """MoE: params touched per token (for MODEL_FLOPS = 6*N_active*D)."""
    total = num_params(cfg)
    if cfg.family != "moe":
        return total
    grp, n_groups, rem = program_for(cfg)
    e_params = 0
    per_expert_per_layer = 0
    specs = model_specs(cfg)
    moe = specs["blocks"]["b0_moe"]["moe"]
    import numpy as np
    for name in ("wi", "wg", "wo"):
        if name in moe:
            # stacked shape = (n_groups, E, ...)
            e_params += int(np.prod(moe[name].shape))
            per_expert_per_layer += int(np.prod(moe[name].shape)) // (
                cfg.n_experts * n_groups)
    return total - e_params + n_groups * cfg.top_k * per_expert_per_layer
