"""Model configuration + parameter-spec system.

Params are plain pytrees (nested dicts of jax.Array).  Every leaf is
declared as a ``ParamSpec`` carrying shape, init scale, and *logical* axis
names; from one spec tree we derive:

* materialized params (``init_params``) for smoke tests / real training,
* abstract params (``abstract_params``) for the dry-run (no allocation),
* the sharding tree (``sharding_tree``) for pjit in/out shardings.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.context import active_ctx

__all__ = [
    "ModelConfig",
    "ParamSpec",
    "init_params",
    "abstract_params",
    "sharding_tree",
    "spec_tree_num_params",
]


@dataclass(frozen=True)
class ModelConfig:
    """One dataclass covers the whole assigned-architecture pool; families
    ignore the fields they don't use."""

    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads

    # attention variants
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: Optional[int] = None   # sliding-window width (local layers)
    local_global_pattern: int = 0       # N local layers per 1 global (gemma3: 5)
    rope_theta: float = 10_000.0
    attn_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # mlp variants
    mlp_act: str = "swiglu"             # swiglu | relu2 | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0                  # mamba2 value heads
    ssm_conv: int = 4
    ssm_expand: int = 2
    hybrid_period: int = 0              # zamba2: shared attn every N mamba blocks
    slstm_every: int = 0                # xlstm: sLSTM every N blocks
    mlstm_proj_factor: float = 0.0      # xlstm: mLSTM pre-up-projection
                                        # (paper: 2.0; 0 = cell at d_model)

    # encoder-decoder / VLM
    n_encoder_layers: int = 0
    cross_attn_period: int = 0          # llama-vision: 1 cross layer per N
    frontend_dim: int = 0               # stub frame/patch embedding dim

    tie_embeddings: bool = True
    embed_scale: float = 1.0            # gemma: sqrt(d_model)
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # training-time behavior
    remat: str = "full"                 # full | dots | none
    microbatches: int = 1               # gradient-accumulation splits
    # §Perf levers (hillclimbed via dryrun --set; defaults = baseline)
    norm_mult_dtype: str = "float32"    # "compute": f32 stats, bf16 multiply
    norm_custom_bwd: int = 0            # 1: hand-written bf16 rmsnorm VJP
    attn_probs_dtype: str = "float32"   # "compute": flash-style bf16 probs
    seq_shard_norms: int = 0            # 1: Megatron-SP norm/residual segs
    attn_block_remat: int = 0           # 1: checkpoint each q-block's attn
    loss_dtype: str = "float32"         # "compute": bf16 lse/onehot path

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_causal_lm(self) -> bool:
        return self.family in ("dense", "moe", "hybrid", "ssm", "vlm")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]  # one logical name per dim
    init: str = "normal"                # normal | zeros | ones | scaled
    scale: float = 1.0                  # stddev (normal) / fan-in exponent

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _init_leaf(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "scaled":
        # fan-in scaled normal (truncated not needed for smoke-scale)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dtype)


def init_params(key: jax.Array, specs: Any, dtype=jnp.bfloat16) -> Any:
    """Materialize a spec tree into arrays (host-order deterministic)."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs: Any, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree with shardings attached when a ctx is active —
    the dry-run path: no device allocation ever happens.  Storage shardings
    are divisibility-masked against each leaf's shape."""
    ctx = active_ctx()

    def leaf(s: ParamSpec):
        sharding = ctx.sharding(s.logical, s.shape) if ctx else None
        return jax.ShapeDtypeStruct(s.shape, dtype, sharding=sharding)

    return jax.tree.map(leaf, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def sharding_tree(specs: Any) -> Any:
    """NamedSharding tree (requires an active ctx; divisibility-masked)."""
    ctx = active_ctx()
    assert ctx is not None, "sharding_tree needs an active sharding context"
    return jax.tree.map(lambda s: ctx.sharding(s.logical, s.shape), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_tree_num_params(specs: Any) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(np.prod(s.shape) for s in leaves))
