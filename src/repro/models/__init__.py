"""repro.models"""
