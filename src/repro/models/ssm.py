"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Mamba2 uses the chunked SSD form: intra-chunk attention-like einsums plus an
inter-chunk recurrent state carried through a ``lax.scan`` — sequence length
enters compute linearly, which is what makes zamba2/xlstm the designated
``long_500k`` architectures.  Decode is the O(1) recurrent update on a
cached state.

xLSTM: mLSTM is a matrix-memory recurrence (chunkwise-parallel here, like a
gated linear attention); sLSTM has a true hidden-to-hidden recurrence and is
inherently sequential (``lax.scan`` over time).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.common import ModelConfig, ParamSpec

__all__ = [
    "mamba2_specs", "mamba2_forward", "mamba2_decode", "mamba2_init_state",
    "mlstm_specs", "mlstm_forward", "mlstm_decode", "mlstm_init_state",
    "slstm_specs", "slstm_forward", "slstm_decode", "slstm_init_state",
]


def _chunked_time_scan(step, carry, xs, seq_len: int, chunk: int = 64):
    """scan(step) over time with two-level checkpointing.

    A flat ``lax.scan`` over S steps saves every per-step carry for the
    backward pass — for mLSTM's matrix memory that is S x [B,H,hd,hd] f32
    (hundreds of GB at 4k x batch).  Nesting the scan (outer over chunks,
    inner over steps, ``jax.checkpoint`` on the chunk body) stores only
    chunk-boundary states and recomputes inside a chunk: sqrt-style memory
    at 2x step compute.

    ``xs`` leaves are time-major ([S, ...]).
    """
    chunk = min(chunk, seq_len)
    if seq_len % chunk != 0:
        # fall back to the flat scan for ragged tiny sequences (smoke tests)
        return jax.lax.scan(step, carry, xs)

    nc = seq_len // chunk

    def chunk_body(c, xs_chunk):
        return jax.lax.scan(step, c, xs_chunk)

    chunk_body = jax.checkpoint(chunk_body)
    xs_c = jax.tree.map(lambda a: a.reshape(nc, chunk, *a.shape[1:]), xs)
    carry, ys = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(seq_len, *a.shape[2:]), ys)
    return carry, ys


# =============================================================== Mamba2 (SSD)

def _mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    headdim = 64
    nheads = cfg.ssm_heads or d_inner // headdim
    headdim = d_inner // nheads
    return d_inner, nheads, headdim


def mamba2_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, nheads, headdim = _mamba_dims(cfg)
    N = cfg.ssm_state
    s = 1.0 / math.sqrt(d)
    return {
        # fused input projection -> [z | x | B | C | dt]
        "w_in": ParamSpec((d, 2 * d_inner + 2 * N + nheads),
                          ("embed", "mlp"), "normal", s),
        "conv_w": ParamSpec((cfg.ssm_conv, d_inner), ("conv", "mlp"), "normal", 0.2),
        "A_log": ParamSpec((nheads,), (None,), "zeros"),
        "D": ParamSpec((nheads,), (None,), "ones"),
        "dt_bias": ParamSpec((nheads,), (None,), "zeros"),
        "norm_scale": ParamSpec((d_inner,), ("mlp",), "ones"),
        "w_out": ParamSpec((d_inner, d), ("mlp", "embed"), "normal",
                           1.0 / math.sqrt(d_inner)),
    }


def _mamba_proj(p, cfg, x):
    """x [B,S,d] -> z, xs, Bs, Cs, dt   (pre-conv)."""
    d_inner, nheads, headdim = _mamba_dims(cfg)
    N = cfg.ssm_state
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xs, Bs, Cs, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    with jax.named_scope("f32c"):
        dt = jax.nn.softplus(dt.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
    return z, xs, Bs, Cs, dt


def _causal_conv(xs: jax.Array, conv_w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv over time.  xs [B,S,D], conv_w [K,D]."""
    K = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((xs.shape[0], K - 1, xs.shape[2]), xs.dtype)
    else:
        pad = state.astype(xs.dtype)
    xp = jnp.concatenate([pad, xs], axis=1)  # [B, S+K-1, D]
    out = sum(
        xp[:, i : i + xs.shape[1], :] * conv_w[i][None, None, :]
        for i in range(K)
    )
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return jax.nn.silu(out), new_state


def _ssd_chunked(xh, dt, A, Bs, Cs, chunk: int, h0=None):
    """Structured state-space duality, chunked.

    xh [B,S,H,P]; dt [B,S,H] f32; A [H] (negative); Bs/Cs [B,S,N].
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    B, S, H, P = xh.shape
    N = Bs.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    # per-step log decay
    dA = dt * A[None, None, :]                     # [B,S,H]  (<= 0)
    xc = xh.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    dAc = dA.reshape(B, nc, chunk, H)
    Bc = Bs.reshape(B, nc, chunk, N)
    Cc = Cs.reshape(B, nc, chunk, N)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def scan_body(h, inp):
        """One chunk: intra-chunk quadratic + inter-chunk state carry.
        Scanning keeps the [Q,Q,H] tensors chunk-local (memory) and the HLO
        size independent of sequence length.  The whole chunk runs under
        the f32c dtype-contract scope: the SSD reference math is genuinely
        f32 (the Pallas ssm kernel keeps it f32 in VMEM)."""
        xq, dtq, dAq, Bq, Cq = inp                  # [B,Q,...]
        cum = jnp.cumsum(dAq, axis=1)               # [B,Q,H]
        total = cum[:, -1:, :]                      # [B,1,H]
        li = cum[:, :, None, :] - cum[:, None, :, :]       # [B,Q,Q,H]
        L = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
        scores = jnp.einsum("bqn,bkn->bqk", Cq.astype(jnp.float32),
                            Bq.astype(jnp.float32))
        xdt = xq.astype(jnp.float32) * dtq[..., None]      # [B,Q,H,P]
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, L, xdt)
        # output contribution of the carried-in state
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp",
                             Cq.astype(jnp.float32), jnp.exp(cum), h)
        # state update for the next chunk
        decay_in = jnp.exp(total - cum)              # [B,Q,H]
        upd = jnp.einsum("bkn,bkh,bkhp->bhpn", Bq.astype(jnp.float32),
                         decay_in, xdt)
        h_new = h * jnp.exp(total[:, 0, :])[:, :, None, None] + upd
        return h_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    # f32c: the SSD reference math is genuinely f32 (the Pallas ssm kernel
    # keeps it f32 in VMEM; only its HBM I/O is bf16)
    with jax.named_scope("f32c"):
        h_final, ys = jax.lax.scan(
            scan_body, h0,
            (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
             dAc.transpose(1, 0, 2, 3), Bc.transpose(1, 0, 2, 3),
             Cc.transpose(1, 0, 2, 3)),
        )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, h_final


def mamba2_forward(p: dict, cfg: ModelConfig, x: jax.Array,
                   chunk: int = 128) -> jax.Array:
    """Full-sequence Mamba2 block (training/prefill).  x [B,S,d]."""
    B, S, _ = x.shape
    d_inner, nheads, headdim = _mamba_dims(cfg)
    z, xs, Bs, Cs, dt = _mamba_proj(p, cfg, x)
    xs, _ = _causal_conv(xs, p["conv_w"])
    xh = xs.reshape(B, S, nheads, headdim)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    chunk = min(chunk, S)
    y, _ = _ssd_chunked(xh, dt, A, Bs, Cs, chunk)
    with jax.named_scope("f32c"):
        y = y + xh.astype(jnp.float32) * p["D"].astype(
            jnp.float32)[None, None, :, None]
        y = y.reshape(B, S, d_inner)
        # gated RMSNorm then output projection
        ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
        y = y * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_scale"].astype(
            jnp.float32)
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return constrain(out, "batch", "seq", "embed")


class MambaState(NamedTuple):
    h: jax.Array          # [B, H, P, N] f32
    conv: jax.Array       # [B, K-1, d_inner]


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d_inner, nheads, headdim = _mamba_dims(cfg)
    return MambaState(
        h=jnp.zeros((batch, nheads, headdim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype),
    )


def mamba2_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                  state: MambaState) -> tuple[jax.Array, MambaState]:
    """One-token recurrent update.  x [B,1,d]."""
    B = x.shape[0]
    d_inner, nheads, headdim = _mamba_dims(cfg)
    z, xs, Bs, Cs, dt = _mamba_proj(p, cfg, x)
    xs, conv_state = _causal_conv(xs, p["conv_w"], state=state.conv)
    with jax.named_scope("f32c"):
        xh = xs.reshape(B, nheads, headdim).astype(jnp.float32)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        dt1 = dt[:, 0, :]                                # [B,H]
        dec = jnp.exp(dt1 * A[None, :])                  # [B,H]
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bs[:, 0].astype(jnp.float32),
                         dt1, xh)
        h = state.h * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cs[:, 0].astype(jnp.float32), h)
        y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
        y = y.reshape(B, 1, d_inner)
        ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
        y = y * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_scale"].astype(
            jnp.float32)
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, MambaState(h=h, conv=conv_state)


# ================================================================== mLSTM

def _mlstm_dims(cfg: ModelConfig):
    """(heads, head_dim, d_in): the cell runs at d_in = proj_factor * d_model
    (xLSTM paper uses 2.0); with proj_factor 0 the cell runs at d_model."""
    H = cfg.n_heads
    d_in = int(cfg.mlstm_proj_factor * cfg.d_model) or cfg.d_model
    return H, d_in // H, d_in


def _slstm_dims(cfg: ModelConfig):
    """sLSTM always runs at d_model (no up-projection in the paper)."""
    H = cfg.n_heads
    return H, cfg.d_model // H


def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, hd, d_in = _mlstm_dims(cfg)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(d_in)
    specs = {
        "wq": ParamSpec((d_in, H, hd), ("mlp", "qheads", "head_dim"), "normal", si),
        "wk": ParamSpec((d_in, H, hd), ("mlp", "qheads", "head_dim"), "normal", si),
        "wv": ParamSpec((d_in, H, hd), ("mlp", "qheads", "head_dim"), "normal", si),
        "w_if": ParamSpec((d_in, 2 * H), ("mlp", None), "normal", si),
        "b_if": ParamSpec((2 * H,), (None,), "zeros"),
        "o_norm": ParamSpec((H, hd), ("qheads", "head_dim"), "ones"),
        "wo": ParamSpec((H, hd, d), ("qheads", "head_dim", "embed"), "normal",
                        si),
    }
    if cfg.mlstm_proj_factor:
        # pre-up-projection + swish output gate (xLSTM paper Fig 10 block)
        specs["w_up"] = ParamSpec((d, d_in), ("embed", "mlp"), "normal", s)
        specs["w_gate"] = ParamSpec((d, d_in), ("embed", "mlp"), "normal", s)
    return specs


def _mlstm_in(p, cfg, x):
    """Block input -> (cell input u, output gate z or None)."""
    if cfg.mlstm_proj_factor:
        u = jnp.einsum("bsd,de->bse", x, p["w_up"])
        z = jnp.einsum("bsd,de->bse", x, p["w_gate"])
        return u, z
    return x, None


def _mlstm_gates(p, x):
    gates = jnp.einsum("bsd,dg->bsg", x, p["w_if"]) + p["b_if"]
    H = gates.shape[-1] // 2
    i_g = gates[..., :H].astype(jnp.float32)            # input (log-space)
    f_g = gates[..., H:].astype(jnp.float32)            # forget
    logf = jax.nn.log_sigmoid(f_g)
    return i_g, logf


def mlstm_forward(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Stabilized mLSTM, recurrent form via scan over time.  x [B,S,d]."""
    B, S, d = x.shape
    H, hd, _ = _mlstm_dims(cfg)
    u, z_gate = _mlstm_in(p, cfg, x)
    q = jnp.einsum("bsd,dnh->bsnh", u, p["wq"]) / math.sqrt(hd)
    k = jnp.einsum("bsd,dnh->bsnh", u, p["wk"]) / math.sqrt(hd)
    v = jnp.einsum("bsd,dnh->bsnh", u, p["wv"])
    i_g, logf = _mlstm_gates(p, u)

    def step(carry, inp):
        C, n, m = carry                                  # [B,H,hd,hd],[B,H,hd],[B,H]
        qt, kt, vt, it, lft = inp
        m_new = jnp.maximum(lft + m, it)                 # stabilizer
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(lft + m - m_new)
        kf = kt.astype(jnp.float32)
        vf = vt.astype(jnp.float32)
        C = C * f_s[..., None, None] + i_s[..., None, None] * (
            kf[..., :, None] * vf[..., None, :])
        n = n * f_s[..., None] + i_s[..., None] * kf
        qf = qt.astype(jnp.float32)
        num = jnp.einsum("bhk,bhkv->bhv", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), (num / den)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_g.transpose(1, 0, 2),
          logf.transpose(1, 0, 2))
    with jax.named_scope("f32c"):
        _, ys = _chunked_time_scan(step, (C0, n0, m0), xs, S)
        y = ys.transpose(1, 0, 2, 3)                     # [B,S,H,hd]
        y = y * p["o_norm"].astype(jnp.float32)[None, None]
        if z_gate is not None:
            y = y * jax.nn.silu(
                z_gate.astype(jnp.float32)).reshape(B, S, H, hd)
        y = y.astype(x.dtype)
    return jnp.einsum("bsnh,nhd->bsd", y, p["wo"])


class MLSTMState(NamedTuple):
    C: jax.Array
    n: jax.Array
    m: jax.Array


def mlstm_init_state(cfg: ModelConfig, batch: int):
    H, hd, _ = _mlstm_dims(cfg)
    return MLSTMState(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        m=jnp.zeros((batch, H), jnp.float32),
    )


def mlstm_decode(p, cfg, x, state: MLSTMState):
    """x [B,1,d] one-step; same math as one scan step."""
    B = x.shape[0]
    H, hd, _ = _mlstm_dims(cfg)
    u, z_gate = _mlstm_in(p, cfg, x)
    q = jnp.einsum("bsd,dnh->bsnh", u, p["wq"])[:, 0] / math.sqrt(hd)
    k = jnp.einsum("bsd,dnh->bsnh", u, p["wk"])[:, 0] / math.sqrt(hd)
    v = jnp.einsum("bsd,dnh->bsnh", u, p["wv"])[:, 0]
    i_g, logf = _mlstm_gates(p, u)
    it, lft = i_g[:, 0], logf[:, 0]
    C, n, m = state
    with jax.named_scope("f32c"):
        m_new = jnp.maximum(lft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(lft + m - m_new)
        kf, vf, qf = (a.astype(jnp.float32) for a in (k, v, q))
        C = C * f_s[..., None, None] + i_s[..., None, None] * (
            kf[..., :, None] * vf[..., None, :])
        n = n * f_s[..., None] + i_s[..., None] * kf
        num = jnp.einsum("bhk,bhkv->bhv", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                          jnp.exp(-m_new))[..., None]
        y = (num / den) * p["o_norm"].astype(jnp.float32)[None]
        if z_gate is not None:
            y = y * jax.nn.silu(
                z_gate.astype(jnp.float32)).reshape(B, H, hd)
        y = y.astype(x.dtype)[:, None]
    out = jnp.einsum("bsnh,nhd->bsd", y, p["wo"])
    return out, MLSTMState(C=C, n=n, m=m_new)


# ================================================================== sLSTM

def slstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, hd = _slstm_dims(cfg)
    s = 1.0 / math.sqrt(d)
    return {
        # 4 gates (i, f, z, o) input + block-diag recurrent weights
        "w_x": ParamSpec((d, 4, H, hd), ("embed", None, "qheads", "head_dim"),
                         "normal", s),
        "w_r": ParamSpec((4, H, hd, hd), (None, "qheads", "head_dim", None),
                         "normal", 1.0 / math.sqrt(hd)),
        "b": ParamSpec((4, H, hd), (None, "qheads", "head_dim"), "zeros"),
        "wo": ParamSpec((H, hd, d), ("qheads", "head_dim", "embed"), "normal",
                        1.0 / math.sqrt(d)),
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # [B,H,hd]
    n: jax.Array
    h: jax.Array
    m: jax.Array


def slstm_init_state(cfg: ModelConfig, batch: int):
    H, hd = _slstm_dims(cfg)
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.zeros((batch, H, hd), jnp.float32))


def _slstm_step(p, state: SLSTMState, xg):
    """xg [B,4,H,hd] pre-activations from the input; recurrence added here.
    Genuinely f32 (exp-gated scalar memory) — under the f32c contract."""
    c, n, h, m = state
    rec = jnp.einsum("bhk,ghkv->bghv", h, p["w_r"].astype(jnp.float32))
    g = xg.astype(jnp.float32) + rec + p["b"].astype(jnp.float32)[None]
    i_t, f_t, z_t, o_t = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + m, i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(jax.nn.log_sigmoid(f_t) + m - m_new)
    c = f_s * c + i_s * jnp.tanh(z_t)
    n = f_s * n + i_s
    h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, h=h, m=m_new), h


def slstm_forward(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    H, hd = _slstm_dims(cfg)
    xg = jnp.einsum("bsd,dghk->bsghk", x, p["w_x"])      # [B,S,4,H,hd]

    def step(st, xgt):
        return _slstm_step(p, st, xgt)

    st0 = slstm_init_state(cfg, B)
    with jax.named_scope("f32c"):
        _, hs = _chunked_time_scan(step, st0,
                                   xg.transpose(1, 0, 2, 3, 4), S)
    y = hs.transpose(1, 0, 2, 3).astype(x.dtype)         # [B,S,H,hd]
    return jnp.einsum("bsnh,nhd->bsd", y, p["wo"])


def slstm_decode(p, cfg, x, state: SLSTMState):
    xg = jnp.einsum("bsd,dghk->bsghk", x, p["w_x"])[:, 0]
    with jax.named_scope("f32c"):
        st, h = _slstm_step(p, state, xg)
    y = h.astype(x.dtype)[:, None]
    return jnp.einsum("bsnh,nhd->bsd", y, p["wo"]), st
