"""Shared model layers: norms, RoPE, attention (GQA/window/qk-norm), MLPs.

Everything is a pure function over explicit param dicts; logical-axis
sharding hints come from ``repro.distributed.context.constrain`` and are
no-ops without an active mesh context.

Attention uses a query-chunked exact implementation (full keys per query
block, softmax in f32) for long sequences so XLA never materializes the
[S, S] score matrix for the whole sequence at once — the HLO stays a
``scan``, which is also what keeps 61-81 layer configs compilable on one
CPU core.  The Pallas flash kernel (``repro.kernels.flash_attention``) is a
drop-in for the inner block on real TPUs (``attn_impl="pallas"``).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.common import ModelConfig, ParamSpec

__all__ = [
    "norm_spec", "apply_norm", "rope_sin_cos", "apply_rope",
    "attention_specs", "attention", "attention_from_cache",
    "mlp_specs", "mlp",
]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------- norms

def norm_spec(cfg: ModelConfig) -> dict:
    d = {"scale": ParamSpec((cfg.d_model,), ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    return d


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_bf16_bwd(x: jax.Array, scale: jax.Array, eps: float):
    """RMSNorm with f32 statistics and a hand-written backward that keeps
    every activation-sized tensor in the compute dtype (§Perf lever
    ``norm_mode="bf16_bwd"``).

    jax.grad of the straightforward f32-stat norm drags f32 [B,S,D]
    cotangents through the whole mean-square chain (the dominant HBM term
    the roofline walker flags on dense trainers).  Here only the row
    statistics ([B,S,1]) are f32; dx/dscale math runs in bf16 — standard
    practice (MaxText/Megatron fused norms do the same in-kernel).
    """
    y, _ = _rmsnorm_bf16_fwd(x, scale, eps)
    return y


def _row_sq_mean(x: jax.Array) -> jax.Array:
    """mean(x^2) over the last dim as a CONTRACTION (bf16 reads, f32
    accumulate) — the einsum form never materializes an f32 [B,S,D]
    square, matching what a fused TPU norm reads/writes."""
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    return (ms / x.shape[-1])[..., None]


def _rmsnorm_bf16_fwd(x, scale, eps):
    with jax.named_scope("f32c"):
        ms = _row_sq_mean(x)
        inv = jax.lax.rsqrt(ms + eps)                   # [B,S,1] f32
    y = x * inv.astype(x.dtype) * scale.astype(x.dtype)
    return y, (x, inv, scale)


def _rmsnorm_bf16_rev(eps, res, dy):
    x, inv, scale = res
    inv_c = inv.astype(x.dtype)
    xhat = x * inv_c
    with jax.named_scope("f32c"):
        dscale = jnp.einsum("...d,...d->d", dy, xhat,
                            preferred_element_type=jnp.float32
                            ).astype(scale.dtype)
    dxhat = dy * scale.astype(dy.dtype)
    with jax.named_scope("f32c"):
        # row term in f32 (a [B,S,1] statistic, like the forward)
        row = jnp.einsum("...d,...d->...", dxhat, xhat,
                         preferred_element_type=jnp.float32
                         )[..., None] / x.shape[-1]
    dx = inv_c * (dxhat - xhat * row.astype(x.dtype))
    return dx, dscale


_rmsnorm_bf16_bwd.defvjp(_rmsnorm_bf16_fwd, _rmsnorm_bf16_rev)


def apply_norm(p: dict, x: jax.Array, eps: float, kind: str,
               f32_mult: bool = True, custom_bwd: bool = False) -> jax.Array:
    """Normalization with f32 statistics.

    ``f32_mult=False`` keeps the *multiplies* in the compute dtype (stats
    still f32) — the MaxText-style pattern that removes the f32
    activation-sized elementwise chains the roofline walker flags as the
    dominant HBM term on dense trainers (§Perf lever ``norm_mult_dtype``).
    ``custom_bwd=True`` (rmsnorm only) additionally replaces jax.grad's
    backward with a bf16 hand-written VJP (§Perf lever ``norm_mode``).
    """
    if custom_bwd and kind != "layernorm":
        return _rmsnorm_bf16_bwd(x, p["scale"], eps)
    if kind == "layernorm":
        with jax.named_scope("f32c"):
            xf = x.astype(jnp.float32)
            mu = jnp.mean(xf, axis=-1, keepdims=True)
            var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
            if f32_mult:
                y = (xf - mu) * jax.lax.rsqrt(var + eps)
                y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(
                    jnp.float32)
                return y.astype(x.dtype)
            inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
            mu_c = mu.astype(x.dtype)
        return ((x - mu_c) * inv * p["scale"] + p["bias"]).astype(x.dtype)
    # rmsnorm
    with jax.named_scope("f32c"):
        xf = x.astype(jnp.float32)
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        if f32_mult:
            y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
            return y.astype(x.dtype)
        inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


def _rms_head(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """qk-norm: RMS over head_dim with a learned per-dim scale."""
    with jax.named_scope("f32c"):
        xf = x.astype(jnp.float32)
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        return (xf * jax.lax.rsqrt(ms + eps)
                * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- RoPE

def rope_sin_cos(positions: jax.Array, head_dim: int, theta: float):
    """positions [..., S] int32 -> (sin, cos) [..., S, head_dim/2] f32."""
    with jax.named_scope("f32c"):
        half = head_dim // 2
        freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
        ang = positions.astype(jnp.float32)[..., None] * freqs
        return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [B, S, N, hd]; sin/cos [S, hd/2] or [B, S, hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        s, c = sin[None, :, None, :], cos[None, :, None, :]
    else:
        s, c = sin[:, :, None, :], cos[:, :, None, :]
    with jax.named_scope("f32c"):
        xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
        return jnp.concatenate(
            [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1
        ).astype(x.dtype)


# ---------------------------------------------------------------- attention

def attention_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = 1.0 / math.sqrt(d)
    # the d dims carry their own logical names ("attn_in"/"attn_out_d") so
    # storage rules can FSDP-shard attention weights independently of the
    # MLP (a §Perf lever); both default to replicated like "embed".
    specs = {
        "wq": ParamSpec((d, H, hd), ("attn_in", "qheads", "head_dim"), "normal", s),
        "wk": ParamSpec((d, KV, hd), ("attn_in", "kv_heads", "head_dim"), "normal", s),
        "wv": ParamSpec((d, KV, hd), ("attn_in", "kv_heads", "head_dim"), "normal", s),
        "wo": ParamSpec((H, hd, d), ("qheads", "head_dim", "attn_out_d"), "normal",
                        1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = ParamSpec((H, hd), ("qheads", "head_dim"), "zeros")
        specs["bk"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bv"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), "ones")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), "ones")
    return specs


def _qkv(p: dict, cfg: ModelConfig, x: jax.Array, kv_x: jax.Array,
         positions, kv_positions, use_rope: bool):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", kv_x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", kv_x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = _rms_head(q, p["q_norm"], cfg.norm_eps)
        k = _rms_head(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        sin_q, cos_q = rope_sin_cos(positions, cfg.hd, cfg.rope_theta)
        sin_k, cos_k = rope_sin_cos(kv_positions, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, sin_q, cos_q)
        k = apply_rope(k, sin_k, cos_k)
    q = constrain(q, "batch", "seq", "qheads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """[..., Sq, Sk] additive bias from positional validity."""
    with jax.named_scope("f32c"):
        valid = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], dtype=bool)
        if causal:
            valid &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            valid &= k_pos[None, :] > (q_pos[:, None] - window)
        return jnp.where(valid, 0.0, _NEG_INF)


def _sdpa(q, k, v, bias, scale, probs_dtype: str = "float32"):
    """q [B,Sq,KV,G,hd], k/v [B,Sk,KV,hd], bias [Sq,Sk] -> [B,Sq,KV,G,hd].

    ``probs_dtype="compute"`` (§Perf lever ``attn_probs_dtype``) keeps the
    whole score chain in the compute dtype with only row statistics in
    f32, and normalizes AFTER the PV product (linearity) — the flash-
    attention dtype policy, one full f32 score materialization cheaper.
    Row max is exact (max of bf16 values is bf16); exp in bf16 costs
    ~0.4% relative error on probs, standard for bf16 flash kernels.
    """
    if probs_dtype != "compute":
        with jax.named_scope("f32c"):
            scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(
                jnp.float32) * scale
            scores = scores + bias[None, None, None]
            probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * jnp.asarray(scale, q.dtype)
    s = s + bias[None, None, None].astype(q.dtype)     # [B,KV,G,Sq,Sk]
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m)                                  # bf16
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)           # unnormalized
    with jax.named_scope("f32c"):
        denom = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32)
        # denom [B,KV,G,Sq,1] -> [B,Sq,KV,G,1]
        inv = (1.0 / jnp.maximum(denom, 1e-30)).transpose(0, 3, 1, 2, 4)
    return o * inv.astype(o.dtype)


def attention(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    kv_x: Optional[jax.Array] = None,
    causal: bool = True,
    window: Optional[int] = None,
    use_rope: bool = True,
    positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    q_block: int = 1024,
) -> jax.Array:
    """Full-sequence attention (training / prefill).

    ``kv_x`` switches to cross-attention (keys/values from the encoder or
    vision stream; no causal mask, no rope on keys by default).
    """
    B, Sq, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    Sk = kv_x.shape[1]
    if positions is None:
        positions = jnp.arange(Sq, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.arange(Sk, dtype=jnp.int32)

    q, k, v = _qkv(p, cfg, x, kv_x, positions, kv_positions, use_rope)
    KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(B, Sq, KV, G, cfg.hd)
    scale = cfg.attn_scale or 1.0 / math.sqrt(cfg.hd)

    if Sq <= q_block:
        bias = _mask_bias(positions, kv_positions, causal, window)
        out = _sdpa(q, k, v, bias, scale, cfg.attn_probs_dtype)
    else:
        # exact query-chunked attention: scan over q blocks.
        assert Sq % q_block == 0, (Sq, q_block)
        nblk = Sq // q_block
        qb = q.reshape(B, nblk, q_block, KV, G, cfg.hd).transpose(1, 0, 2, 3, 4, 5)
        pb = positions.reshape(nblk, q_block)

        # sliding-window causal layers SKIP out-of-window keys instead of
        # masking them: each q block only ever reaches kv_span =
        # window-1+q_block keys, so slice that (static-size) range out of
        # k/v per block — S/(window+blk)-fold fewer score FLOPs AND bytes
        # (gemma3's 5:1 local layers at 32k: ~16x).  Mirrors the Pallas
        # kernel's block-skipping; exactness is asserted in tests.
        windowed = (window is not None and causal and kv_x is x
                    and Sk == Sq and window + q_block < Sk)
        if windowed:
            kv_span = window - 1 + q_block

            def block_attn(qi, pi):
                q0 = pi[0]
                start = jnp.clip(q0 - (window - 1), 0, Sk - kv_span)
                kb = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
                vb = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
                kv_pos = start + jnp.arange(kv_span, dtype=jnp.int32)
                bias = _mask_bias(pi, kv_pos, causal, window)
                return _sdpa(qi, kb, vb, bias, scale, cfg.attn_probs_dtype)
        else:
            def block_attn(qi, pi):
                bias = _mask_bias(pi, kv_positions, causal, window)
                return _sdpa(qi, k, v, bias, scale, cfg.attn_probs_dtype)

        if cfg.attn_block_remat:
            # without this, the scan's AD residuals stack the f32 probs of
            # EVERY q-block ([nblk, B, KV, G, blk, Sk] f32) — rematting the
            # block recomputes them from (q, k) in the backward instead.
            block_attn = jax.checkpoint(block_attn)

        def body(_, blk):
            qi, pi = blk
            return None, block_attn(qi, pi)

        _, ob = jax.lax.scan(body, None, (qb, pb))
        out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, cfg.hd)

    out = out.reshape(B, Sq, cfg.n_heads, cfg.hd)
    out = constrain(out, "batch", "seq", "qheads", "head_dim")
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    return constrain(y, "batch", "seq", "embed")


def attention_from_cache(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: Optional[int] = None,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: x [B, 1, d]; caches [B, S_max, KV, hd].

    Returns (attn_out [B,1,d], new_k_cache, new_v_cache).  The caches may be
    sequence-sharded (``cache_seq`` logical axis) for 500k contexts; the
    masked softmax reduces over the sharded axis via GSPMD collectives.
    """
    B, _, _ = x.shape
    S_max = k_cache.shape[1]
    positions = pos[None] if pos.ndim == 0 else pos
    q, k_new, v_new = _qkv(p, cfg, x, x, positions, positions, use_rope)

    k_cache = jax.lax.dynamic_update_index_in_dim(
        k_cache, k_new[:, 0].astype(k_cache.dtype), pos.astype(jnp.int32), axis=1
    )
    v_cache = jax.lax.dynamic_update_index_in_dim(
        v_cache, v_new[:, 0].astype(v_cache.dtype), pos.astype(jnp.int32), axis=1
    )
    k_cache = constrain(k_cache, "batch", "cache_seq", "kv_heads", "head_dim")
    v_cache = constrain(v_cache, "batch", "cache_seq", "kv_heads", "head_dim")

    KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(B, 1, KV, G, cfg.hd)
    scale = cfg.attn_scale or 1.0 / math.sqrt(cfg.hd)

    k_pos = jnp.arange(S_max, dtype=jnp.int32)
    valid = k_pos <= pos
    if window is not None:
        valid &= k_pos > (pos - window)
    bias = jnp.where(valid, 0.0, _NEG_INF)  # [S_max]

    with jax.named_scope("f32c"):
        scores = jnp.einsum("bqkgh,bskh->bkgqs", q,
                            k_cache).astype(jnp.float32)
        scores = scores * scale + bias[None, None, None, None, :]
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_cache)
    out = out.reshape(B, 1, cfg.n_heads, cfg.hd)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    return y, k_cache, v_cache


# ---------------------------------------------------------------- MLP

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    specs = {
        "wi": ParamSpec((d, f), ("embed", "mlp"), "normal", s_in),
        "wo": ParamSpec((f, d), ("mlp", "embed"), "normal", s_out),
    }
    if cfg.mlp_act == "swiglu":
        specs["wg"] = ParamSpec((d, f), ("embed", "mlp"), "normal", s_in)
    return specs


def mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jax.nn.silu(g) * h
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.mlp_act)
    h = constrain(h, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    return constrain(y, "batch", "seq", "embed")
