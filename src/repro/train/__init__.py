"""repro.train"""
