"""Training step: loss -> grads (with microbatch accumulation) -> AdamW.

``make_train_step`` builds a pure function suitable for ``jax.jit`` with
explicit in/out shardings (the launcher provides those from the spec
trees).  Gradient accumulation is a ``lax.scan`` over microbatches —
required at kimi-k2 scale where the MoE dispatch buffers cap the live
tokens per device (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.transformer import lm_loss
from repro.optim.adamw import AdamWConfig, adamw_apply, adamw_init

__all__ = ["TrainState", "init_train_state", "make_train_step"]

TrainState = dict  # {"params": ..., "opt": ..., "step": int32}


def init_train_state(params: Any, opt_cfg: AdamWConfig) -> TrainState:
    return {"params": params, "opt": adamw_init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        B = x.shape[0]
        assert B % n == 0, f"batch {B} not divisible by microbatches {n}"
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    accum_dtype: Optional[str] = None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    n_mb = max(cfg.microbatches, 1)
    acc_dt = jnp.dtype(accum_dtype) if accum_dtype else (
        jnp.bfloat16 if cfg.family == "moe" and cfg.microbatches > 1
        else jnp.float32)

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)

    def grads_of(params, batch):
        if n_mb == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mb = _split_microbatches(batch, n_mb)

        def body(carry, mbatch):
            loss_acc, gacc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mbatch)
            gacc = jax.tree.map(
                lambda a, b: a + b.astype(acc_dt) / n_mb, gacc, g)
            return (loss_acc + l / n_mb, gacc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), mb)
        return loss, grads

    def train_step(state: TrainState, batch: dict):
        loss, grads = grads_of(state["params"], batch)
        new_params, new_opt, om = adamw_apply(
            grads, state["opt"], state["params"], opt_cfg)
        metrics = {"loss": loss, **om}
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return train_step
