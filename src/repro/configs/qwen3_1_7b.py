"""Qwen3-1.7B: dense, qk-norm, GQA [hf:Qwen/Qwen3].

28L d_model=2048 16H (kv=8) d_ff=6144 vocab=151936, head_dim=128.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_act="swiglu",
    tie_embeddings=True,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, remat="none",
    )
