"""Nemotron-4-15B: dense, GQA, squared-ReLU MLP [arXiv:2402.16819].

32L d_model=6144 48H (kv=8) d_ff=24576 vocab=256000.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="relu2",
    tie_embeddings=False,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="nemotron-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab_size=512, remat="none",
    )
