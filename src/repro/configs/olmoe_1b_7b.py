"""OLMoE-1B-7B: 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    capacity_factor=1.25,
    mlp_act="swiglu",
    qk_norm=True,
    tie_embeddings=False,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="olmoe-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab_size=512, n_experts=8, top_k=2, remat="none",
    )
