"""Zamba2-7B: Mamba2 backbone + shared attention block [arXiv:2411.15242].

81 mamba2 blocks (d_model=3584, ssm_state=64) with a SHARED
attention+MLP block (32H kv=32, d_ff=14336) applied every 6 blocks —
13 invocations of the same weights, scanned as super-blocks of
(6 mamba + shared attn) with a 3-mamba tail.  Sub-quadratic: runs the
``long_500k`` cell.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    hybrid_period=6,
    mlp_act="gelu",
    tie_embeddings=True,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-reduced", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512, ssm_state=16,
        hybrid_period=2, remat="none",
    )
