"""Qwen2.5-14B: dense, GQA kv=8, QKV bias [hf:Qwen/Qwen2.5].

48L d_model=5120 40H (kv=8) d_ff=13824 vocab=152064.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_act="swiglu",
    tie_embeddings=False,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2.5-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512, remat="none",
    )
