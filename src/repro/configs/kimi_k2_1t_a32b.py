"""Kimi K2: trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840.
~1.03T total params, ~32B active.  Trains with full remat, FSDP expert
storage (see sharding overrides in launch/dryrun.py) and gradient
accumulation — the dispatch buffers at 1M-token global batch demand
microbatching (DESIGN.md §4).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    capacity_factor=1.25,
    mlp_act="swiglu",
    tie_embeddings=True,
    remat="full",
    microbatches=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="kimi-k2-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab_size=512, n_experts=8, top_k=2,
        microbatches=1, remat="none",
    )
