"""Whisper-large-v3 BACKBONE: enc-dec transformer [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model=1280 20H (kv=20) d_ff=5120
vocab=51866, LayerNorm + GELU, no RoPE.  The conv/mel frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings [B, S, 1280]
(assignment note).  Decode cells exercise the decoder's self-attn cache
mechanically beyond whisper's semantic 448-token max (DESIGN.md §4).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_act="gelu",
    norm="layernorm",
    norm_eps=1e-5,
    frontend_dim=1280,
    tie_embeddings=True,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-reduced", n_layers=2, n_encoder_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512, frontend_dim=64,
        remat="none",
    )
