"""Llama-3.2-11B-Vision BACKBONE: cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (kv=8) d_ff=14336 vocab=128256; every 5th layer is a
gated cross-attention layer over vision patch embeddings.  The vision tower
is a STUB: ``input_specs()`` supplies precomputed patch embeddings.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_period=5,
    frontend_dim=1280,
    rope_theta=500_000.0,
    mlp_act="swiglu",
    tie_embeddings=False,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama-vision-reduced", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512, frontend_dim=32,
        remat="none",
    )
