"""xLSTM-125M: alternating mLSTM / sLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H vocab=50304, d_ff=0 (the recurrent blocks carry their
own projections).  O(1)-state recurrence: runs the ``long_500k`` cell.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=2,
    mlstm_proj_factor=2.0,   # paper block: up-proj 2x, swish output gate
    tie_embeddings=True,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-reduced", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        vocab_size=512, remat="none",
    )
