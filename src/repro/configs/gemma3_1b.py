"""Gemma3-1B: 5:1 local:global attention, kv=1, 128k ctx [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (kv=1) d_ff=6912 vocab=262144, head_dim=256,
sliding window 512 on local layers, qk-norm, sqrt(d) embedding scale.
The 5:1 sliding-window majority is why gemma3 runs the ``long_500k`` cell
(DESIGN.md §4).
"""

import math

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    qk_norm=True,
    attn_window=512,
    local_global_pattern=5,
    rope_theta=1_000_000.0,
    mlp_act="gelu",
    tie_embeddings=True,
    embed_scale=math.sqrt(1152.0),
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma3-reduced", n_layers=8, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=512, head_dim=16, attn_window=16,
        embed_scale=8.0, remat="none",
    )
