"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full (paper-table) config; every module
also exposes ``reduced()`` — a family-preserving miniature for CPU smoke
tests (same block pattern, tiny widths).
"""

from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

ARCH_IDS = [
    "kimi_k2_1t_a32b",
    "olmoe_1b_7b",
    "qwen2_5_14b",
    "qwen3_1_7b",
    "nemotron_4_15b",
    "gemma3_1b",
    "whisper_large_v3",
    "zamba2_7b",
    "llama3_2_vision_11b",
    "xlstm_125m",
]

#: CLI names (--arch) -> module names
ALIASES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen3-1.7b": "qwen3_1_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "gemma3-1b": "gemma3_1b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-7b": "zamba2_7b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "xlstm-125m": "xlstm_125m",
}


def _module(name: str):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def reduced_config(name: str) -> ModelConfig:
    return _module(name).reduced()


def list_archs() -> list[str]:
    return list(ALIASES)
