"""Assigned input-shape cells and per-arch applicability.

Four cells per LM arch (assignment):
  train_4k     seq=4096   global_batch=256   (train_step)
  prefill_32k  seq=32768  global_batch=32    (prefill forward)
  decode_32k   seq=32768  global_batch=128   (serve_step: 1 token, 32k cache)
  long_500k    seq=524288 global_batch=1     (serve_step; sub-quadratic only)

``long_500k`` is skipped for pure full-attention archs (a 500k dense cache/
prefill is the quadratic case the cell excludes) and runs for the
SSM/hybrid/sliding-window archs: zamba2, xlstm, gemma3.  DESIGN.md §4.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.distributed.context import active_ctx
from repro.models.common import ModelConfig, ParamSpec
from repro.models.transformer import cache_specs

__all__ = ["ShapeCell", "SHAPES", "applicable", "train_inputs",
           "serve_inputs", "WHISPER_MEMORY_LEN", "VLM_PATCHES"]

WHISPER_MEMORY_LEN = 1500   # whisper's native 30 s encoder grid
VLM_PATCHES = 1024          # stub patch count for the vision stream


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

#: archs allowed to run long_500k (sub-quadratic serving path)
_LONG_OK_FAMILIES = ("hybrid", "ssm")


def applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    if shape.name == "long_500k":
        if cfg.family in _LONG_OK_FAMILIES:
            return True, "sub-quadratic (SSM/hybrid)"
        if cfg.local_global_pattern:
            return True, "sliding-window majority (5:1 local:global)"
        return False, ("skipped: pure full-attention arch; 500k dense "
                       "attention is the quadratic case this cell excludes")
    return True, "ok"


def _sds(shape, dtype, logical):
    ctx = active_ctx()
    sharding = ctx.sharding(logical, shape) if ctx else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def train_inputs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for one global batch (train or prefill)."""
    B, S = shape.batch, shape.seq
    batch = {"tokens": _sds((B, S), jnp.int32, ("batch", "seq"))}
    if cfg.family == "encdec":
        # seq applies to the encoder's frame axis (the long dim in audio);
        # decoder tokens cap at whisper's semantic max.
        batch["frames"] = _sds((B, S, cfg.frontend_dim), jnp.bfloat16,
                               ("batch", "seq", None))
        batch["tokens"] = _sds((B, min(S, 448)), jnp.int32, ("batch", None))
    elif cfg.family == "vlm":
        batch["patches"] = _sds((B, VLM_PATCHES, cfg.frontend_dim),
                                jnp.bfloat16, ("batch", "frames", None))
    return batch


def serve_inputs(cfg: ModelConfig, shape: ShapeCell):
    """(cache, token, pos) stand-ins for one decode step."""
    B, S = shape.batch, shape.seq
    mem_len = 0
    if cfg.family == "encdec":
        mem_len = WHISPER_MEMORY_LEN
    elif cfg.family == "vlm":
        mem_len = VLM_PATCHES
    specs = cache_specs(cfg, B, S, mem_len)

    from repro.models.transformer import _CACHE_F32  # single source of truth

    def mk(path, s: ParamSpec):
        leaf = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        dtype = jnp.float32 if leaf in _CACHE_F32 else cfg.jdtype
        return _sds(s.shape, dtype, s.logical)

    cache = jax.tree_util.tree_map_with_path(
        mk, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    token = _sds((B, 1), jnp.int32, ("batch", None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, pos
