"""repro.serve"""
