"""Serving steps: prefill and batched one-token decode with KV caches.

``make_serve_step`` returns the function the ``decode_32k`` / ``long_500k``
dry-run cells lower: one new token against a seq_len-deep cache, greedy or
temperature sampling on-device.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.transformer import decode_step, forward

__all__ = ["make_serve_step", "make_prefill_step"]


def make_prefill_step(cfg: ModelConfig):
    """prefill(params, batch) -> last-position logits [B, V]."""

    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch)
        with jax.named_scope("f32c"):
            return logits[:, -1].astype(jnp.float32)

    return prefill_step


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """serve_step(params, cache, token, pos, rng) ->
    (next_token [B,1], logits [B,V], new_cache)."""

    def serve_step(params, cache, token, pos, rng: Optional[jax.Array] = None):
        logits, new_cache = decode_step(params, cfg, cache, token, pos)
        with jax.named_scope("f32c"):
            logits = logits.astype(jnp.float32)
        if temperature > 0.0 and rng is not None:
            next_tok = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok[:, None].astype(jnp.int32), logits, new_cache

    return serve_step
