"""Fleet-level multi-transfer scheduling (``TransferManager``).

The rest of the transfer stack moves ONE blob at a time: an
``MDTPClient`` owns its replicas, sizes chunks from its own throughput
estimators, and tunes (C, L) as if it were alone on the fleet.  A
production transfer service (the regime Globus-style managed transfer
operates in — see PAPERS.md) is the opposite: many concurrent transfers
contend for the same mirrors, and a client that plans against the *full*
fleet bandwidth over-asks the shared paths, queues behind its peers, and
re-learns the same conditions its neighbors just measured.

``TransferManager`` closes that gap with three mechanisms:

1. **A shared fleet model** (:class:`FleetModel`): per-replica
   exponentially-decayed capacity and RTT, aggregated across every active
   transfer's per-chunk observations (each sample RTT-bias-corrected via
   :func:`repro.core.throughput.rtt_corrected_bandwidth`).  One
   transfer's measurements warm every other transfer's planning.

2. **Residual-capacity bin packing**: the MDTP allocator (paper §IV) packs
   each round into per-server capacity bins.  Managed clients override
   :meth:`MDTPClient._allocation_throughputs` so the bin sizes are the
   *residual* capacity — fleet bandwidth minus what the OTHER active
   transfers are currently consuming, floored at a fair share so nobody
   is starved — plus **per-replica in-flight caps** (an asyncio semaphore
   per mirror) so K transfers cannot stack K deep request queues on the
   fastest path.

3. **Cross-transfer tuner persistence**: the manager owns one online
   tuner (``repro.core.online`` contract) and one adopted ``ChunkParams``;
   every transfer feeds the same tuner (through a thread-safe,
   residual-aware proxy) and the geometry a transfer adopts warm-starts
   the next one — a ``BanditTuner``'s arms / reward statistics and an
   ``MCGradTuner``'s iterate survive across transfers instead of being
   re-learned from scratch (the ROADMAP PR-3 follow-on).

4. **Replica probation** (:class:`FleetModel`): a mirror that trips a
   corruption, retry, or gray-slowness threshold stops anchoring large
   chunks — its allocation weight is pinned at a probe floor so the
   packer keeps sending it single min-sized chunks, and a mirror that
   proves itself clean again re-enters through multiplicative slow-start
   instead of instantly reclaiming full share (no fast/dead oscillation,
   the paper's "bandwidth decrease to the fastest server" case).

5. **Admission control** (:class:`_AdmissionGate` + :class:`_ByteBudget`):
   a max-active-transfers gate with an SRPT (smallest-residual-first,
   starvation-aged) wait queue, a per-fleet in-flight byte budget, and a
   shed mode that serves flash-crowd overflow a bounded trickle instead
   of queueing it into timeout.

The manager is jax-free at import time (like the rest of
``repro.transfer``); tuners and the contention planner pull in jax lazily.
"""

from __future__ import annotations

import asyncio
import collections
import contextlib
import dataclasses
import itertools
import threading
import time
import weakref
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

from repro.core.chunking import ChunkParams
from repro.core.throughput import rtt_corrected_bandwidth

from .client import DEFAULT_PIPELINE_DEPTH, MDTPClient, Replica, _Conn
from .sched import defaults as sched_defaults

__all__ = ["FleetModel", "TransferJob", "TransferManager"]


@dataclass
class _ReplicaState:
    """Fleet model entry for one mirror (keyed by ``host:port``)."""

    #: EWMA of the replica's TOTAL observed concurrent throughput
    #: (bytes/s, summed across active transfers) — the capacity bin.
    capacity: float = 0.0
    #: EWMA of measured request RTT (s); 0 = no sample yet.
    rtt: float = 0.0
    #: per-transfer EWMA delivery rate (bytes/s), RTT-bias corrected.
    rates: dict = field(default_factory=dict)
    #: completed chunks observed (diagnostics).
    chunks: int = 0
    #: checksum-mismatched ranges served by this mirror (all transfers).
    corruptions: int = 0
    #: multiplicative trust factor in (0, 1]: decays on every corruption,
    #: recovers slowly on clean chunks.  Scales the allocation view, so a
    #: chronically corrupt replica is deprioritized exactly like a slow
    #: one — it still gets probing-sized requests (re-fetch overhead is
    #: bounded) but stops anchoring large chunks.
    health: float = 1.0
    #: connection-level retries charged since the last probation reset.
    retries: int = 0
    #: probation: the mirror tripped a corruption/retry/slowness
    #: threshold; its allocation weight is pinned at the probe floor
    #: until it serves a clean streak at restored health.
    probation: bool = False
    #: times this mirror has been placed on probation (witness).
    probations: int = 0
    #: consecutive clean chunks since the last bad event.
    clean_streak: int = 0
    #: consecutive chunks served far below the best trusted peer — the
    #: fast path onto probation for a gray (silently degraded) mirror:
    #: per-chunk rates betray the degradation many EWMA steps before the
    #: capacity estimate converges down to it.
    slow_strikes: int = 0
    #: slow-start readmission factor in (0, 1]: starts small when a
    #: mirror leaves probation and doubles per clean chunk, so a
    #: recovered mirror ramps back instead of instantly reclaiming (and
    #: possibly re-losing) its full allocation share.
    readmit: float = 1.0


class FleetModel:
    """Shared per-replica capacity/telemetry model.

    Thread-safe: observations arrive on the event loop, while tuner
    proxies read from thread-pool executor workers.  All state is keyed
    by replica NAME (``host:port``) so the same mirror serving different
    blob paths (a manifest and its data.bin, two different checkpoints)
    aggregates into one capacity estimate.
    """

    def __init__(self, max_inflight_per_replica: int = 2,
                 alpha: float = 0.3, rtt_alpha: float = 0.3,
                 probation: bool = True,
                 probation_health: float = sched_defaults.PROBATION_HEALTH,
                 probation_retry_limit: int =
                 sched_defaults.PROBATION_RETRY_LIMIT,
                 probation_slow_frac: float =
                 sched_defaults.PROBATION_SLOW_FRAC,
                 probation_strikes: int = sched_defaults.PROBATION_STRIKES,
                 probation_clean_streak: int =
                 sched_defaults.PROBATION_CLEAN_STREAK,
                 probation_floor: float = sched_defaults.PROBATION_FLOOR,
                 readmit_init: float = sched_defaults.READMIT_INIT):
        if max_inflight_per_replica < 1:
            raise ValueError("max_inflight_per_replica must be >= 1")
        self.max_inflight_per_replica = max_inflight_per_replica
        self.alpha = alpha
        self.rtt_alpha = rtt_alpha
        #: probation knobs (see :class:`_ReplicaState`): trip when trust
        #: decays below ``probation_health``, when ``probation_retry_limit``
        #: connection retries accumulate, or when the mirror serves
        #: ``probation_slow_frac``x slower than the best trusted peer;
        #: readmit after ``probation_clean_streak`` clean chunks at
        #: restored health, ramping back via slow-start from
        #: ``readmit_init``.
        self.probation_enabled = probation
        self.probation_health = probation_health
        self.probation_retry_limit = probation_retry_limit
        self.probation_slow_frac = probation_slow_frac
        self.probation_strikes = probation_strikes
        self.probation_clean_streak = probation_clean_streak
        self.probation_floor = probation_floor
        self.readmit_init = readmit_init
        self._lock = threading.Lock()
        self._reps: dict[str, _ReplicaState] = {}
        self._active: set = set()
        # per-(event-loop, replica) request slots: semaphores bind to the
        # loop they first wait on, and a manager may serve several
        # sequential asyncio.run() loops (one per restore).  Keyed on the
        # LIVE loop object (weakly, so dead loops drop their slots) — an
        # id()-based key could hand a recycled loop a semaphore bound to
        # its dead predecessor.
        self._slots: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

    # -- registration ------------------------------------------------------

    def register(self, tid) -> None:
        with self._lock:
            self._active.add(tid)

    def forget(self, tid) -> None:
        """Drop a finished transfer: its consumption leaves the residual
        immediately (capacity memory is kept — the EWMA remembers what
        the mirror could serve while it was contended)."""
        with self._lock:
            self._active.discard(tid)
            for st in self._reps.values():
                st.rates.pop(tid, None)

    @property
    def active_transfers(self) -> int:
        with self._lock:
            return len(self._active)

    # -- request slots (per-replica in-flight caps) ------------------------

    def slot(self, name: str) -> asyncio.Semaphore:
        """The request slot for one mirror on the CURRENT event loop.

        The cap is global across every transfer sharing a loop (the
        ``TransferManager.run`` batch path).  Workloads driven from
        separate threads each run their own loop and therefore their own
        semaphore — the capacity/residual model is still shared, but the
        in-flight cap is per loop, not per process.
        """
        loop = asyncio.get_running_loop()
        with self._lock:
            per_loop = self._slots.get(loop)
            if per_loop is None:
                per_loop = self._slots[loop] = {}
            sem = per_loop.get(name)
            if sem is None:
                sem = per_loop[name] = asyncio.Semaphore(
                    self.max_inflight_per_replica)
            return sem

    # -- observations ------------------------------------------------------

    def observe_chunk(self, tid, name: str, nbytes: int,
                      elapsed: float, rtt_included: bool = True) -> None:
        """Fold one completed range request into the model.  A serial
        (idle-pipe) reading spans the request round trip, so the fleet's
        RTT estimate inverts the bias; a pipelined reading already
        measures pure body-streaming time (``rtt_included=False``) and
        enters as-is — double-correcting it would overstate capacity."""
        if elapsed <= 0.0 or nbytes <= 0:
            return
        with self._lock:
            st = self._reps.setdefault(name, _ReplicaState())
            rate = nbytes / elapsed
            if rtt_included:
                rate = rtt_corrected_bandwidth(rate, st.rtt, float(nbytes))
            prev = st.rates.get(tid)
            st.rates[tid] = (rate if prev is None
                             else self.alpha * rate
                             + (1.0 - self.alpha) * prev)
            total = sum(st.rates.values())
            st.capacity = (total if st.capacity <= 0.0
                           else self.alpha * total
                           + (1.0 - self.alpha) * st.capacity)
            st.chunks += 1
            # clean evidence slowly rebuilds trust (asymmetric on purpose:
            # one corruption costs more than one clean chunk repays)
            st.health += 0.05 * (1.0 - st.health)
            if not self.probation_enabled:
                return
            # per-chunk slowness strike: this very chunk was served far
            # below the best trusted peer's capacity — the instantaneous
            # signal a gray mirror gives off while its capacity EWMA is
            # still coasting on its healthy past
            best = self._best_trusted(name)
            struck = (best > 0.0 and st.chunks >= 4
                      and rate < self.probation_slow_frac * best)
            st.slow_strikes = st.slow_strikes + 1 if struck else 0
            if st.probation:
                st.clean_streak += 1
                if (st.clean_streak >= self.probation_clean_streak
                        and st.health >= self.probation_health
                        and not struck
                        and not self._slow_vs_fleet(name, st)):
                    # readmit via multiplicative slow-start: the mirror
                    # re-enters at a fraction of its fair share and earns
                    # the rest back one clean chunk at a time.  A mirror
                    # whose probe chunks still crawl stays parked — clean
                    # is necessary but not sufficient.
                    st.probation = False
                    st.clean_streak = 0
                    st.retries = 0
                    st.readmit = self.readmit_init
            else:
                if st.readmit < 1.0:
                    st.readmit = min(1.0, st.readmit * 2.0)
                if (st.slow_strikes >= self.probation_strikes
                        or self._slow_vs_fleet(name, st)):
                    self._trip(st)

    def _trip(self, st: _ReplicaState) -> None:
        """Place one mirror on probation (caller holds the lock)."""
        st.probation = True
        st.probations += 1
        st.clean_streak = 0
        st.slow_strikes = 0
        st.retries = 0

    def _best_trusted(self, name: str) -> float:
        """Best capacity among the OTHER non-probation mirrors (caller
        holds the lock); 0 when there is no trusted peer — a
        single-replica fleet can never be slow relative to itself."""
        return max((o.capacity for nm, o in self._reps.items()
                    if nm != name and not o.probation), default=0.0)

    def _slow_vs_fleet(self, name: str, st: _ReplicaState) -> bool:
        """Gray-slowness trigger: the mirror has enough samples and is
        serving ``probation_slow_frac``x slower than the best trusted
        peer (caller holds the lock).  Single-replica fleets never trip
        — there is nothing faster to shift allocation toward."""
        if st.chunks < 4 or st.capacity <= 0.0:
            return False
        best = self._best_trusted(name)
        return best > 0.0 and st.capacity < self.probation_slow_frac * best

    def observe_corruption(self, name: str) -> None:
        """One checksum-mismatched range from this mirror: count it and
        decay the mirror's trust factor (floored so it can recover)."""
        with self._lock:
            st = self._reps.setdefault(name, _ReplicaState())
            st.corruptions += 1
            st.health = max(st.health * 0.7, 0.05)
            if self.probation_enabled:
                st.clean_streak = 0
                if not st.probation and st.health < self.probation_health:
                    self._trip(st)

    def observe_retry(self, name: str) -> None:
        """One connection-level retry (reconnect after failure) against
        this mirror: enough of them in a row trips probation even when no
        chunk ever completes (the silently-blackholed mirror case)."""
        with self._lock:
            st = self._reps.setdefault(name, _ReplicaState())
            st.retries += 1
            if self.probation_enabled:
                st.clean_streak = 0
                if (not st.probation
                        and st.retries >= self.probation_retry_limit):
                    self._trip(st)

    @property
    def probations(self) -> int:
        """Total probation trips across the fleet (witness)."""
        with self._lock:
            return sum(st.probations for st in self._reps.values())

    def observe_rtt(self, name: str, sample: float) -> None:
        if sample <= 0.0:
            return
        with self._lock:
            st = self._reps.setdefault(name, _ReplicaState())
            st.rtt = (sample if st.rtt <= 0.0
                      else self.rtt_alpha * sample
                      + (1.0 - self.rtt_alpha) * st.rtt)

    # -- views -------------------------------------------------------------

    def allocation_view(self, tid, replicas: Sequence[Replica],
                        est_values: Sequence[float]) -> list:
        """The throughput vector transfer ``tid``'s allocator should pack
        against: per replica, the residual capacity (fleet capacity minus
        other active transfers' consumption), floored at a fair-share
        fraction so a late arrival is never starved out of the bin.
        Falls back to the transfer's own estimate where the fleet has no
        capacity observation, and keeps unprobed replicas at ``<= 0`` so
        the client still issues its uniform probing chunk.

        A mirror on probation is pinned at the probe floor — a tiny
        positive weight, so the packer keeps sending it single min-sized
        chunks (periodic probes) without anchoring real work on it; a
        readmitted mirror's weight is additionally scaled by its
        slow-start ``readmit`` factor.
        """
        with self._lock:
            n_active = max(len(self._active), 1)
            out = []
            for i, r in enumerate(replicas):
                own = float(est_values[i])
                st = self._reps.get(r.name)
                if st is not None and st.probation:
                    ref = st.capacity if st.capacity > 0.0 else own
                    if ref > 0.0:
                        out.append(ref * self.probation_floor)
                    else:
                        out.append(own)
                    continue
                trust = 1.0 if st is None else st.health * st.readmit
                if own <= 0.0 or st is None or st.capacity <= 0.0:
                    out.append(own if st is None else own * trust)
                    continue
                foreign = sum(v for u, v in st.rates.items() if u != tid)
                floor = st.capacity / (2.0 * n_active)
                out.append(max(st.capacity - foreign, floor) * trust)
            return out

    def fleet_telemetry(self, tid, replicas: Sequence[Replica], telemetry):
        """Rewrite a client-local ``Telemetry`` snapshot into the fleet
        view a SHARED tuner should plan from: bandwidth = residual
        capacity for this transfer (what it can actually get), RTT = the
        fleet's aggregated estimate.  Slots the fleet knows nothing about
        keep the client's local reading.  Pure ``dataclasses.replace`` —
        no jax import on this path."""
        bw = self.allocation_view(tid, replicas, telemetry.bandwidth)
        with self._lock:
            rtt = []
            for i, r in enumerate(replicas):
                st = self._reps.get(r.name)
                rtt.append(st.rtt if st is not None and st.rtt > 0.0
                           else float(telemetry.rtt[i]))
        return dataclasses.replace(
            telemetry, bandwidth=tuple(bw), rtt=tuple(rtt))

    def snapshot(self) -> dict:
        """Diagnostic copy: ``{name: {capacity, rtt, rates, chunks}}``."""
        with self._lock:
            return {
                name: {
                    "capacity": st.capacity,
                    "rtt": st.rtt,
                    "rates": dict(st.rates),
                    "chunks": st.chunks,
                    "corruptions": st.corruptions,
                    "health": st.health,
                    "retries": st.retries,
                    "probation": st.probation,
                    "probations": st.probations,
                    "readmit": st.readmit,
                }
                for name, st in self._reps.items()
            }


class _AdmissionGate:
    """Per-event-loop admission state for one manager.

    A ``max_active`` gate with an SRPT wait queue: when a slot frees,
    the waiter with the smallest aged residual wins —
    ``size - aging_bytes_per_s * wait`` — smallest-remaining-first for
    mean response time, with wall-clock aging so a large transfer cannot
    starve behind an endless stream of small ones.  Arrivals past
    ``shed_queue_depth`` are shed into degraded (trickle) service
    instead of queueing toward timeout; shed transfers are promoted to
    full service (SRPT order again) when a slot frees with no queue
    left.
    """

    def __init__(self, max_active: Optional[int],
                 aging_bytes_per_s: float,
                 shed_queue_depth: Optional[int]):
        self.max_active = max_active
        self.aging = float(aging_bytes_per_s)
        self.shed_depth = shed_queue_depth
        self.active = 0
        #: SRPT wait queue entries: ``[size, enqueued_at, Event]``.
        self.waiting: list = []
        #: shed transfers currently in trickle service: tid -> (size, t).
        self.degraded: dict = {}
        #: tids currently holding a full-service slot.
        self.full: set = set()

    def _aged(self, size, since, now) -> float:
        return float(size) - self.aging * (now - since)

    async def acquire(self, size: int):
        """Admit one transfer.  Returns ``(mode, waited_seconds)`` where
        mode is ``"full"`` (slot held) or ``"shed"`` (trickle service,
        no slot)."""
        if self.max_active is None or self.active < self.max_active:
            self.active += 1
            return "full", 0.0
        if (self.shed_depth is not None
                and len(self.waiting) >= self.shed_depth):
            return "shed", 0.0
        entry = [int(size), time.monotonic(), asyncio.Event()]
        self.waiting.append(entry)
        try:
            await entry[2].wait()
        except asyncio.CancelledError:
            if entry in self.waiting:
                self.waiting.remove(entry)
            else:
                # the slot was handed to us between grant and resume —
                # pass it along instead of leaking it
                self._release_slot()
            raise
        return "full", time.monotonic() - entry[1]

    def bind(self, tid, mode: str, size: int) -> None:
        """Associate the admitted transfer's tid with its service mode
        (tids are assigned by the session after admission)."""
        if mode == "full":
            self.full.add(tid)
        else:
            self.degraded[tid] = (int(size), time.monotonic())

    def is_degraded(self, tid) -> bool:
        return tid in self.degraded

    def finish(self, tid):
        """Transfer done: free its slot (promoting the best waiter, else
        the best shed transfer) or drop its degraded registration.
        Returns the tid promoted from shed to full service, if any."""
        if tid in self.full:
            self.full.discard(tid)
            return self._release_slot()
        self.degraded.pop(tid, None)
        return None

    def _release_slot(self):
        now = time.monotonic()
        if self.waiting:
            best = min(self.waiting,
                       key=lambda e: self._aged(e[0], e[1], now))
            self.waiting.remove(best)
            best[2].set()  # slot hands off; active count unchanged
            return None
        if self.degraded:
            tid = min(self.degraded.items(),
                      key=lambda kv: self._aged(kv[1][0], kv[1][1], now))[0]
            del self.degraded[tid]
            self.full.add(tid)  # promoted in place; active unchanged
            return tid
        self.active -= 1
        return None


class _ByteBudget:
    """Per-event-loop cap on total in-flight request bytes across every
    managed transfer — the fleet's bandwidth-delay budget.  Each range
    request holds its length in credits for its wire lifetime; requests
    larger than the whole budget are clamped so they can still proceed
    (serially).  Grants are FIFO, so one huge request cannot be starved
    by a stream of small ones slipping past it."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self.available = int(capacity)
        self._waiters: collections.deque = collections.deque()

    async def acquire(self, n: int) -> int:
        n = min(int(n), self.capacity)
        if self.available >= n and not self._waiters:
            self.available -= n
            return n
        fut = asyncio.get_running_loop().create_future()
        self._waiters.append((n, fut))
        try:
            await fut
        except asyncio.CancelledError:
            if fut.done() and not fut.cancelled():
                # credit was granted but the task is bailing: hand it back
                self.available += n
                self._grant()
            else:
                with contextlib.suppress(ValueError):
                    self._waiters.remove((n, fut))
            raise
        return n

    def release(self, n: int) -> None:
        self.available += int(n)
        self._grant()

    def _grant(self) -> None:
        while self._waiters and self._waiters[0][0] <= self.available:
            need, fut = self._waiters.popleft()
            if fut.done():
                continue
            self.available -= need
            fut.set_result(None)


class _ManagedConn(_Conn):
    """A client connection that (a) respects the fleet's per-replica
    in-flight cap and the manager's in-flight byte budget, (b) paces
    shed (degraded-admission) transfers to the trickle rate, and
    (c) feeds every completed range request into the shared fleet
    model."""

    def __init__(self, replica: Replica, fleet: FleetModel, tid,
                 manager: Optional["TransferManager"] = None, **conn_kw):
        super().__init__(replica, **conn_kw)
        self._fleet = fleet
        self._tid = tid
        self._mgr = manager

    async def fetch_range(self, start: int, end: int, into=None,
                          progress=None):
        length = end - start + 1
        budget = None
        if self._mgr is not None:
            pace = self._mgr._shed_pace(self._tid, length)
            if pace > 0.0:
                await asyncio.sleep(pace)
            budget = self._mgr._byte_budget()
        held = 0
        if budget is not None:
            held = await budget.acquire(length)
        try:
            # the slot is held for the request's whole pipelined lifetime
            # (send → queued behind predecessors → body), so the cap bounds
            # wire-level outstanding requests per mirror across transfers
            async with self._fleet.slot(self.replica.name):
                reply = await super().fetch_range(start, end, into=into,
                                                  progress=progress)
                # wire bytes, not decoded: the fleet model's bandwidth
                # estimates must not credit the codec's savings as wire
                # capacity on compressed paths
                self._fleet.observe_chunk(self._tid, self.replica.name,
                                          reply.wire_bytes, reply.elapsed,
                                          rtt_included=reply.rtt_included)
                # peek (don't drain — the owning client min-aggregates
                # these into its own report) at the freshest RTT samples
                if self._rtt_samples:
                    self._fleet.observe_rtt(self.replica.name,
                                            min(self._rtt_samples))
                return reply
        finally:
            if budget is not None:
                budget.release(held)


class _SharedTuner:
    """Per-transfer proxy in front of the manager's single tuner.

    Serializes ``update`` calls across transfers (they run on executor
    threads) and substitutes the fleet's residual view for the client's
    local estimator snapshot, so a ``BanditTuner``'s drift detector and
    an ``MCGradTuner``'s descent both plan against what THIS transfer can
    actually get from the shared mirrors.
    """

    def __init__(self, manager: "TransferManager", tid,
                 replicas: Sequence[Replica]):
        self._manager = manager
        self._tid = tid
        self._replicas = list(replicas)

    def update(self, telemetry):
        fleet_tel = self._manager.fleet.fleet_telemetry(
            self._tid, self._replicas, telemetry)
        with self._manager._tuner_lock:
            return self._manager.tuner.update(fleet_tel)


class _ManagedClient(MDTPClient):
    """An ``MDTPClient`` wired into a manager's fleet model."""

    def __init__(self, replicas: Sequence[Replica],
                 manager: "TransferManager", tid, **kw):
        super().__init__(replicas, **kw)
        self._manager = manager
        self._tid = tid

    def _make_conn(self, replica: Replica) -> _Conn:
        return _ManagedConn(replica, self._manager.fleet, self._tid,
                            manager=self._manager,
                            request_latency=self.request_latency,
                            read_timeout=self.read_timeout)

    def _allocation_throughputs(self, est_values: list) -> list:
        return self._manager.fleet.allocation_view(
            self._tid, self.replicas, est_values)

    def _on_corruption(self, name: str) -> None:
        self._manager.fleet.observe_corruption(name)

    def _on_retry(self, name: str) -> None:
        self._manager.fleet.observe_retry(name)


@dataclass
class TransferJob:
    """One transfer in a :meth:`TransferManager.run` batch."""

    size: int
    #: blob path on every mirror (None = the fleet replicas' own paths).
    path: Optional[str] = None
    offset: int = 0
    #: seconds after batch start before this transfer begins (staggered
    #: arrivals).
    start_delay: float = 0.0
    #: destination (``repro.transfer.Sink`` or legacy callable); None =
    #: assemble in memory.
    sink: Optional[Any] = None
    tune_interval_bytes: Optional[int] = None
    #: frontier rotation hint ``(k, n)`` — see ``MDTPClient.fetch``.
    stripe: Optional[tuple] = None


class TransferManager:
    """Run N concurrent MDTP transfers against one shared replica fleet.

    Args:
      replicas: the fleet — every transfer draws from these mirrors
        (per-transfer ``path``/``replicas`` overrides re-point the blob,
        not the fleet: the capacity model is keyed by ``host:port``).
      params: initial chunk geometry; whatever a transfer adopts (via its
        tuner or ``retune``) replaces it, warm-starting the next transfer.
      tuner: a shared online tuner (``repro.core.online`` policy).  State
        persists across transfers — bandit arms keep their discounted
        rewards, the MC-gradient tuner keeps its iterate.
      max_inflight_per_replica: per-mirror cap on simultaneously
        outstanding range requests ACROSS all transfers.
      contention_ladder: optional ``{active_count: ChunkParams}`` map
        (see :meth:`plan_contention`) consulted at transfer start, so a
        transfer that arrives while k others run starts from geometry
        tuned for a (k+1)-way split instead of the solo optimum.
      max_active_transfers: admission gate — at most this many transfers
        run at full service per event loop; the rest wait in an SRPT
        (smallest-residual-first, starvation-aged) queue.  ``None``
        disables admission control.
      max_inflight_bytes: per-fleet budget on total in-flight request
        bytes across every transfer on a loop.  ``None`` = unbounded.
      shed_queue_depth: arrivals finding this many transfers already
        queued are shed into degraded (trickle) service instead of
        waiting — bounded progress instead of a timeout.  ``None``
        disables shedding (everyone queues).
      shed_trickle_bytes_per_s: pacing rate for shed transfers.
      aging_bytes_per_s: SRPT starvation aging — each second in the
        queue shrinks a waiter's effective residual by this much.
      probation: enable replica probation in the fleet model (default
        on; see :class:`FleetModel`).
      hedge_quantile: endgame hedging quantile handed to every managed
        client (default 0.95 = the paper-motivated p95 straggler cut;
        0 disables hedging).  An explicit ``hedge_quantile`` in
        ``client_kw`` wins.
    """

    def __init__(
        self,
        replicas: Sequence[Replica],
        params: Optional[ChunkParams] = None,
        tuner=None,
        max_inflight_per_replica: int = 2,
        estimator: str = "ewma",
        ewma_alpha: float = 0.5,
        fleet_alpha: float = 0.3,
        contention_ladder: Optional[dict] = None,
        max_active_transfers: Optional[int] = None,
        max_inflight_bytes: Optional[int] = None,
        shed_queue_depth: Optional[int] = None,
        shed_trickle_bytes_per_s: float = 4.0 * 1024 * 1024,
        aging_bytes_per_s: float = 16.0 * 1024 * 1024,
        probation: bool = True,
        hedge_quantile: float = sched_defaults.HEDGE_QUANTILE,
        **client_kw,
    ):
        self.replicas = list(replicas)
        self.params = params
        self.tuner = tuner
        self.contention_ladder = dict(contention_ladder or {})
        self.fleet = FleetModel(
            max_inflight_per_replica=max_inflight_per_replica,
            alpha=fleet_alpha, probation=probation)
        self._estimator = estimator
        self._ewma_alpha = ewma_alpha
        self._client_kw = dict(client_kw)
        self._client_kw.setdefault("hedge_quantile", hedge_quantile)
        self.max_active_transfers = max_active_transfers
        self.max_inflight_bytes = max_inflight_bytes
        self.shed_queue_depth = shed_queue_depth
        self.shed_trickle_bytes_per_s = float(shed_trickle_bytes_per_s)
        self.aging_bytes_per_s = float(aging_bytes_per_s)
        # per-event-loop admission/budget state (same weak-keying
        # rationale as FleetModel._slots)
        self._gates: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
        self._budgets: "weakref.WeakKeyDictionary" = (
            weakref.WeakKeyDictionary())
        #: admission witnesses, cumulative across loops: transfers
        #: admitted / queued (with total queue seconds) / shed to
        #: trickle service / promoted from shed to full service.
        self.admission = {"admitted": 0, "queued": 0, "wait_seconds": 0.0,
                          "shed": 0, "promoted": 0}
        self._tuner_lock = threading.Lock()
        self._tids = itertools.count(1)
        #: reports of completed transfers, in completion order.
        self.reports: list = []

    # -- admission ---------------------------------------------------------

    def _gate(self) -> _AdmissionGate:
        loop = asyncio.get_running_loop()
        gate = self._gates.get(loop)
        if gate is None:
            gate = self._gates[loop] = _AdmissionGate(
                self.max_active_transfers, self.aging_bytes_per_s,
                self.shed_queue_depth)
        return gate

    def _byte_budget(self) -> Optional[_ByteBudget]:
        if self.max_inflight_bytes is None:
            return None
        loop = asyncio.get_running_loop()
        budget = self._budgets.get(loop)
        if budget is None:
            budget = self._budgets[loop] = _ByteBudget(
                self.max_inflight_bytes)
        return budget

    def _shed_pace(self, tid, length: int) -> float:
        """Trickle pacing delay for one range request of a shed
        (degraded-admission) transfer; 0 for full-service transfers."""
        try:
            gate = self._gates.get(asyncio.get_running_loop())
        except RuntimeError:
            return 0.0
        if gate is None or not gate.is_degraded(tid):
            return 0.0
        return float(length) / self.shed_trickle_bytes_per_s

    # -- client lifecycle --------------------------------------------------

    def _job_replicas(self, replicas: Optional[Sequence[Replica]],
                      path: Optional[str]) -> list:
        reps = list(replicas) if replicas is not None else list(self.replicas)
        if path is not None:
            reps = [Replica(r.host, r.port, path, mirror=r.mirror)
                    for r in reps]
        return reps

    def _warm_params(self, n_active: int) -> Optional[ChunkParams]:
        """Geometry a new transfer starts from: the contention ladder for
        the current active count if planned, else the last adopted
        params, else whatever the shared tuner has converged to."""
        ladder = self.contention_ladder.get(n_active)
        if ladder is not None:
            return ladder
        if self.params is not None:
            return self.params
        return getattr(self.tuner, "params", None)

    @contextlib.asynccontextmanager
    async def session(self, replicas: Optional[Sequence[Replica]] = None,
                      path: Optional[str] = None, **client_kw):
        """Register a managed client for a multi-fetch workflow (the
        checkpoint-restore wave loop).  On exit the transfer leaves the
        fleet's residual accounting and its adopted geometry persists on
        the manager."""
        tid = next(self._tids)
        reps = self._job_replicas(replicas, path)
        self.fleet.register(tid)
        kw = {**self._client_kw, **client_kw}
        if "tuner" not in kw:
            # the shared tuner rides along by default; callers running
            # their own wave-boundary updates pass tuner=None to keep the
            # in-fetch hook quiet (reward attribution stays single-source)
            kw["tuner"] = (_SharedTuner(self, tid, reps)
                           if self.tuner is not None else None)
        warm = self._warm_params(self.fleet.active_transfers)
        client = _ManagedClient(
            reps, self, tid, params=warm,
            estimator=self._estimator, ewma_alpha=self._ewma_alpha,
            **kw)
        try:
            yield client
        finally:
            self.fleet.forget(tid)
            # persist only geometry this transfer actually LEARNED (tuner
            # adoption / retune): a transfer that just rode its
            # construction-time warm params must not clobber what a
            # concurrent peer adopted in the meantime (last-writer-wins
            # on stale state)
            if (client._params_arg is not None
                    and client._params_arg != warm):
                self.params = client._params_arg

    # -- transfers ---------------------------------------------------------

    async def fetch(self, size: int, *, path: Optional[str] = None,
                    replicas: Optional[Sequence[Replica]] = None,
                    sink=None, offset: int = 0,
                    tune_interval_bytes: Optional[int] = None,
                    start_delay: float = 0.0,
                    stripe: Optional[tuple] = None):
        """One managed transfer (awaitable; gather several for a fleet).

        Same contract as ``MDTPClient.fetch`` plus ``path``/``replicas``
        re-pointing and ``start_delay`` for staggered arrivals (and
        ``stripe``/peer-mirror replicas pass straight through — a swarm
        is N managed transfers whose replica lists include each other's
        ``PeerMirror.replica``).  Passes through the admission gate
        first: may wait in the SRPT queue (or run at trickle service)
        when ``max_active_transfers`` is set.
        """
        if start_delay > 0.0:
            await asyncio.sleep(start_delay)
        gate = self._gate()
        mode, waited = await gate.acquire(size)
        self.admission["admitted"] += 1
        if waited > 0.0:
            self.admission["queued"] += 1
            self.admission["wait_seconds"] += waited
        if mode == "shed":
            self.admission["shed"] += 1
        tid = None
        try:
            async with self.session(replicas=replicas, path=path) as client:
                tid = client._tid
                gate.bind(tid, mode, size)
                buf, report = await client.fetch(
                    size, sink=sink, offset=offset,
                    tune_interval_bytes=tune_interval_bytes,
                    stripe=stripe)
                self.reports.append(report)
                return buf, report
        finally:
            if tid is not None:
                promoted = gate.finish(tid)
            elif mode == "full":
                # admission slot acquired but the session never bound a
                # transfer (construction failed): free the slot directly
                promoted = gate._release_slot()
            else:
                promoted = None
            if promoted is not None:
                self.admission["promoted"] += 1

    def run(self, jobs: Sequence[TransferJob]):
        """Synchronous batch entry: run every job concurrently on one
        event loop, respecting per-job start delays.  Returns the
        ``(buffer, report)`` pairs in JOB order."""

        async def go():
            return await asyncio.gather(*(
                self.fetch(j.size, path=j.path, sink=j.sink,
                           offset=j.offset,
                           tune_interval_bytes=j.tune_interval_bytes,
                           start_delay=j.start_delay, stripe=j.stripe)
                for j in jobs))

        return asyncio.run(go())

    # -- contention planning ----------------------------------------------

    def plan_contention(self, file_size: int, max_transfers: int = 4,
                        bandwidth: Optional[Sequence[float]] = None,
                        rtt: Optional[Sequence[float]] = None,
                        **sweep_kw) -> dict:
        """Precompute the contention ladder: per active-transfer count k,
        the (C, L) tuned for a fair k-way split of the fleet — one fused
        vmapped sweep (``repro.core.autotune.contention_sweep``) covering
        every (k, C, L) cell.  Uses the fleet model's capacities when no
        explicit bandwidth is given (requires at least one observed
        transfer in that case).  Stores and returns ``{k: ChunkParams}``.
        """
        from repro.core.autotune import contention_sweep

        if bandwidth is None:
            snap = self.snapshot()
            bandwidth, rtt_model = [], []
            for r in self.replicas:
                st = snap.get(r.name)
                if st is not None and st["capacity"] > 0.0:
                    bandwidth.append(st["capacity"])
                    rtt_model.append(st["rtt"] if st["rtt"] > 0.0
                                     else MDTPClient.DEFAULT_RTT)
            if not bandwidth:
                raise ValueError(
                    "no fleet capacity observations to plan from — pass "
                    "bandwidth= explicitly or run a transfer first")
            if rtt is None:
                rtt = rtt_model
        if rtt is None:
            rtt = MDTPClient.DEFAULT_RTT
        # plan for the data plane the managed clients actually run: the
        # ladder must model the same request pipelining (client_kw may
        # override the depth; mirror that here)
        sweep_kw.setdefault(
            "pipeline_depth",
            self._client_kw.get("pipeline_depth", DEFAULT_PIPELINE_DEPTH))
        results = contention_sweep(bandwidth, rtt, int(file_size),
                                   max_transfers=max_transfers, **sweep_kw)
        self.contention_ladder = {
            k: res.params for k, res in results.items()}
        return self.contention_ladder

    def snapshot(self) -> dict:
        """Fleet model diagnostics (see :meth:`FleetModel.snapshot`)."""
        return self.fleet.snapshot()
