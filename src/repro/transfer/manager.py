"""Fleet-level multi-transfer scheduling (``TransferManager``).

The rest of the transfer stack moves ONE blob at a time: an
``MDTPClient`` owns its replicas, sizes chunks from its own throughput
estimators, and tunes (C, L) as if it were alone on the fleet.  A
production transfer service (the regime Globus-style managed transfer
operates in — see PAPERS.md) is the opposite: many concurrent transfers
contend for the same mirrors, and a client that plans against the *full*
fleet bandwidth over-asks the shared paths, queues behind its peers, and
re-learns the same conditions its neighbors just measured.

``TransferManager`` closes that gap with three mechanisms:

1. **A shared fleet model** (:class:`FleetModel`): per-replica
   exponentially-decayed capacity and RTT, aggregated across every active
   transfer's per-chunk observations (each sample RTT-bias-corrected via
   :func:`repro.core.throughput.rtt_corrected_bandwidth`).  One
   transfer's measurements warm every other transfer's planning.

2. **Residual-capacity bin packing**: the MDTP allocator (paper §IV) packs
   each round into per-server capacity bins.  Managed clients override
   :meth:`MDTPClient._allocation_throughputs` so the bin sizes are the
   *residual* capacity — fleet bandwidth minus what the OTHER active
   transfers are currently consuming, floored at a fair share so nobody
   is starved — plus **per-replica in-flight caps** (an asyncio semaphore
   per mirror) so K transfers cannot stack K deep request queues on the
   fastest path.

3. **Cross-transfer tuner persistence**: the manager owns one online
   tuner (``repro.core.online`` contract) and one adopted ``ChunkParams``;
   every transfer feeds the same tuner (through a thread-safe,
   residual-aware proxy) and the geometry a transfer adopts warm-starts
   the next one — a ``BanditTuner``'s arms / reward statistics and an
   ``MCGradTuner``'s iterate survive across transfers instead of being
   re-learned from scratch (the ROADMAP PR-3 follow-on).

The manager is jax-free at import time (like the rest of
``repro.transfer``); tuners and the contention planner pull in jax lazily.
"""

from __future__ import annotations

import asyncio
import contextlib
import dataclasses
import itertools
import threading
import weakref
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

from repro.core.chunking import ChunkParams
from repro.core.throughput import rtt_corrected_bandwidth

from .client import DEFAULT_PIPELINE_DEPTH, MDTPClient, Replica, _Conn

__all__ = ["FleetModel", "TransferJob", "TransferManager"]


@dataclass
class _ReplicaState:
    """Fleet model entry for one mirror (keyed by ``host:port``)."""

    #: EWMA of the replica's TOTAL observed concurrent throughput
    #: (bytes/s, summed across active transfers) — the capacity bin.
    capacity: float = 0.0
    #: EWMA of measured request RTT (s); 0 = no sample yet.
    rtt: float = 0.0
    #: per-transfer EWMA delivery rate (bytes/s), RTT-bias corrected.
    rates: dict = field(default_factory=dict)
    #: completed chunks observed (diagnostics).
    chunks: int = 0
    #: checksum-mismatched ranges served by this mirror (all transfers).
    corruptions: int = 0
    #: multiplicative trust factor in (0, 1]: decays on every corruption,
    #: recovers slowly on clean chunks.  Scales the allocation view, so a
    #: chronically corrupt replica is deprioritized exactly like a slow
    #: one — it still gets probing-sized requests (re-fetch overhead is
    #: bounded) but stops anchoring large chunks.
    health: float = 1.0


class FleetModel:
    """Shared per-replica capacity/telemetry model.

    Thread-safe: observations arrive on the event loop, while tuner
    proxies read from thread-pool executor workers.  All state is keyed
    by replica NAME (``host:port``) so the same mirror serving different
    blob paths (a manifest and its data.bin, two different checkpoints)
    aggregates into one capacity estimate.
    """

    def __init__(self, max_inflight_per_replica: int = 2,
                 alpha: float = 0.3, rtt_alpha: float = 0.3):
        if max_inflight_per_replica < 1:
            raise ValueError("max_inflight_per_replica must be >= 1")
        self.max_inflight_per_replica = max_inflight_per_replica
        self.alpha = alpha
        self.rtt_alpha = rtt_alpha
        self._lock = threading.Lock()
        self._reps: dict[str, _ReplicaState] = {}
        self._active: set = set()
        # per-(event-loop, replica) request slots: semaphores bind to the
        # loop they first wait on, and a manager may serve several
        # sequential asyncio.run() loops (one per restore).  Keyed on the
        # LIVE loop object (weakly, so dead loops drop their slots) — an
        # id()-based key could hand a recycled loop a semaphore bound to
        # its dead predecessor.
        self._slots: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

    # -- registration ------------------------------------------------------

    def register(self, tid) -> None:
        with self._lock:
            self._active.add(tid)

    def forget(self, tid) -> None:
        """Drop a finished transfer: its consumption leaves the residual
        immediately (capacity memory is kept — the EWMA remembers what
        the mirror could serve while it was contended)."""
        with self._lock:
            self._active.discard(tid)
            for st in self._reps.values():
                st.rates.pop(tid, None)

    @property
    def active_transfers(self) -> int:
        with self._lock:
            return len(self._active)

    # -- request slots (per-replica in-flight caps) ------------------------

    def slot(self, name: str) -> asyncio.Semaphore:
        """The request slot for one mirror on the CURRENT event loop.

        The cap is global across every transfer sharing a loop (the
        ``TransferManager.run`` batch path).  Workloads driven from
        separate threads each run their own loop and therefore their own
        semaphore — the capacity/residual model is still shared, but the
        in-flight cap is per loop, not per process.
        """
        loop = asyncio.get_running_loop()
        with self._lock:
            per_loop = self._slots.get(loop)
            if per_loop is None:
                per_loop = self._slots[loop] = {}
            sem = per_loop.get(name)
            if sem is None:
                sem = per_loop[name] = asyncio.Semaphore(
                    self.max_inflight_per_replica)
            return sem

    # -- observations ------------------------------------------------------

    def observe_chunk(self, tid, name: str, nbytes: int,
                      elapsed: float, rtt_included: bool = True) -> None:
        """Fold one completed range request into the model.  A serial
        (idle-pipe) reading spans the request round trip, so the fleet's
        RTT estimate inverts the bias; a pipelined reading already
        measures pure body-streaming time (``rtt_included=False``) and
        enters as-is — double-correcting it would overstate capacity."""
        if elapsed <= 0.0 or nbytes <= 0:
            return
        with self._lock:
            st = self._reps.setdefault(name, _ReplicaState())
            rate = nbytes / elapsed
            if rtt_included:
                rate = rtt_corrected_bandwidth(rate, st.rtt, float(nbytes))
            prev = st.rates.get(tid)
            st.rates[tid] = (rate if prev is None
                             else self.alpha * rate
                             + (1.0 - self.alpha) * prev)
            total = sum(st.rates.values())
            st.capacity = (total if st.capacity <= 0.0
                           else self.alpha * total
                           + (1.0 - self.alpha) * st.capacity)
            st.chunks += 1
            # clean evidence slowly rebuilds trust (asymmetric on purpose:
            # one corruption costs more than one clean chunk repays)
            st.health += 0.05 * (1.0 - st.health)

    def observe_corruption(self, name: str) -> None:
        """One checksum-mismatched range from this mirror: count it and
        decay the mirror's trust factor (floored so it can recover)."""
        with self._lock:
            st = self._reps.setdefault(name, _ReplicaState())
            st.corruptions += 1
            st.health = max(st.health * 0.7, 0.05)

    def observe_rtt(self, name: str, sample: float) -> None:
        if sample <= 0.0:
            return
        with self._lock:
            st = self._reps.setdefault(name, _ReplicaState())
            st.rtt = (sample if st.rtt <= 0.0
                      else self.rtt_alpha * sample
                      + (1.0 - self.rtt_alpha) * st.rtt)

    # -- views -------------------------------------------------------------

    def allocation_view(self, tid, replicas: Sequence[Replica],
                        est_values: Sequence[float]) -> list:
        """The throughput vector transfer ``tid``'s allocator should pack
        against: per replica, the residual capacity (fleet capacity minus
        other active transfers' consumption), floored at a fair-share
        fraction so a late arrival is never starved out of the bin.
        Falls back to the transfer's own estimate where the fleet has no
        capacity observation, and keeps unprobed replicas at ``<= 0`` so
        the client still issues its uniform probing chunk.
        """
        with self._lock:
            n_active = max(len(self._active), 1)
            out = []
            for i, r in enumerate(replicas):
                own = float(est_values[i])
                st = self._reps.get(r.name)
                if own <= 0.0 or st is None or st.capacity <= 0.0:
                    out.append(own if st is None else own * st.health)
                    continue
                foreign = sum(v for u, v in st.rates.items() if u != tid)
                floor = st.capacity / (2.0 * n_active)
                out.append(max(st.capacity - foreign, floor) * st.health)
            return out

    def fleet_telemetry(self, tid, replicas: Sequence[Replica], telemetry):
        """Rewrite a client-local ``Telemetry`` snapshot into the fleet
        view a SHARED tuner should plan from: bandwidth = residual
        capacity for this transfer (what it can actually get), RTT = the
        fleet's aggregated estimate.  Slots the fleet knows nothing about
        keep the client's local reading.  Pure ``dataclasses.replace`` —
        no jax import on this path."""
        bw = self.allocation_view(tid, replicas, telemetry.bandwidth)
        with self._lock:
            rtt = []
            for i, r in enumerate(replicas):
                st = self._reps.get(r.name)
                rtt.append(st.rtt if st is not None and st.rtt > 0.0
                           else float(telemetry.rtt[i]))
        return dataclasses.replace(
            telemetry, bandwidth=tuple(bw), rtt=tuple(rtt))

    def snapshot(self) -> dict:
        """Diagnostic copy: ``{name: {capacity, rtt, rates, chunks}}``."""
        with self._lock:
            return {
                name: {
                    "capacity": st.capacity,
                    "rtt": st.rtt,
                    "rates": dict(st.rates),
                    "chunks": st.chunks,
                    "corruptions": st.corruptions,
                    "health": st.health,
                }
                for name, st in self._reps.items()
            }


class _ManagedConn(_Conn):
    """A client connection that (a) respects the fleet's per-replica
    in-flight cap and (b) feeds every completed range request into the
    shared fleet model."""

    def __init__(self, replica: Replica, fleet: FleetModel, tid, **conn_kw):
        super().__init__(replica, **conn_kw)
        self._fleet = fleet
        self._tid = tid

    async def fetch_range(self, start: int, end: int, into=None):
        # the slot is held for the request's whole pipelined lifetime
        # (send → queued behind predecessors → body), so the cap bounds
        # wire-level outstanding requests per mirror across transfers
        async with self._fleet.slot(self.replica.name):
            reply = await super().fetch_range(start, end, into=into)
            self._fleet.observe_chunk(self._tid, self.replica.name,
                                      reply.nbytes, reply.elapsed,
                                      rtt_included=reply.rtt_included)
            # peek (don't drain — the owning client min-aggregates these
            # into its own report) at the freshest RTT samples
            if self._rtt_samples:
                self._fleet.observe_rtt(self.replica.name,
                                        min(self._rtt_samples))
            return reply


class _SharedTuner:
    """Per-transfer proxy in front of the manager's single tuner.

    Serializes ``update`` calls across transfers (they run on executor
    threads) and substitutes the fleet's residual view for the client's
    local estimator snapshot, so a ``BanditTuner``'s drift detector and
    an ``MCGradTuner``'s descent both plan against what THIS transfer can
    actually get from the shared mirrors.
    """

    def __init__(self, manager: "TransferManager", tid,
                 replicas: Sequence[Replica]):
        self._manager = manager
        self._tid = tid
        self._replicas = list(replicas)

    def update(self, telemetry):
        fleet_tel = self._manager.fleet.fleet_telemetry(
            self._tid, self._replicas, telemetry)
        with self._manager._tuner_lock:
            return self._manager.tuner.update(fleet_tel)


class _ManagedClient(MDTPClient):
    """An ``MDTPClient`` wired into a manager's fleet model."""

    def __init__(self, replicas: Sequence[Replica],
                 manager: "TransferManager", tid, **kw):
        super().__init__(replicas, **kw)
        self._manager = manager
        self._tid = tid

    def _make_conn(self, replica: Replica) -> _Conn:
        return _ManagedConn(replica, self._manager.fleet, self._tid,
                            request_latency=self.request_latency,
                            read_timeout=self.read_timeout)

    def _allocation_throughputs(self, est_values: list) -> list:
        return self._manager.fleet.allocation_view(
            self._tid, self.replicas, est_values)

    def _on_corruption(self, name: str) -> None:
        self._manager.fleet.observe_corruption(name)


@dataclass
class TransferJob:
    """One transfer in a :meth:`TransferManager.run` batch."""

    size: int
    #: blob path on every mirror (None = the fleet replicas' own paths).
    path: Optional[str] = None
    offset: int = 0
    #: seconds after batch start before this transfer begins (staggered
    #: arrivals).
    start_delay: float = 0.0
    sink: Optional[Any] = None
    tune_interval_bytes: Optional[int] = None


class TransferManager:
    """Run N concurrent MDTP transfers against one shared replica fleet.

    Args:
      replicas: the fleet — every transfer draws from these mirrors
        (per-transfer ``path``/``replicas`` overrides re-point the blob,
        not the fleet: the capacity model is keyed by ``host:port``).
      params: initial chunk geometry; whatever a transfer adopts (via its
        tuner or ``retune``) replaces it, warm-starting the next transfer.
      tuner: a shared online tuner (``repro.core.online`` policy).  State
        persists across transfers — bandit arms keep their discounted
        rewards, the MC-gradient tuner keeps its iterate.
      max_inflight_per_replica: per-mirror cap on simultaneously
        outstanding range requests ACROSS all transfers.
      contention_ladder: optional ``{active_count: ChunkParams}`` map
        (see :meth:`plan_contention`) consulted at transfer start, so a
        transfer that arrives while k others run starts from geometry
        tuned for a (k+1)-way split instead of the solo optimum.
    """

    def __init__(
        self,
        replicas: Sequence[Replica],
        params: Optional[ChunkParams] = None,
        tuner=None,
        max_inflight_per_replica: int = 2,
        estimator: str = "ewma",
        ewma_alpha: float = 0.5,
        fleet_alpha: float = 0.3,
        contention_ladder: Optional[dict] = None,
        **client_kw,
    ):
        self.replicas = list(replicas)
        self.params = params
        self.tuner = tuner
        self.contention_ladder = dict(contention_ladder or {})
        self.fleet = FleetModel(
            max_inflight_per_replica=max_inflight_per_replica,
            alpha=fleet_alpha)
        self._estimator = estimator
        self._ewma_alpha = ewma_alpha
        self._client_kw = dict(client_kw)
        self._tuner_lock = threading.Lock()
        self._tids = itertools.count(1)
        #: reports of completed transfers, in completion order.
        self.reports: list = []

    # -- client lifecycle --------------------------------------------------

    def _job_replicas(self, replicas: Optional[Sequence[Replica]],
                      path: Optional[str]) -> list:
        reps = list(replicas) if replicas is not None else list(self.replicas)
        if path is not None:
            reps = [Replica(r.host, r.port, path) for r in reps]
        return reps

    def _warm_params(self, n_active: int) -> Optional[ChunkParams]:
        """Geometry a new transfer starts from: the contention ladder for
        the current active count if planned, else the last adopted
        params, else whatever the shared tuner has converged to."""
        ladder = self.contention_ladder.get(n_active)
        if ladder is not None:
            return ladder
        if self.params is not None:
            return self.params
        return getattr(self.tuner, "params", None)

    @contextlib.asynccontextmanager
    async def session(self, replicas: Optional[Sequence[Replica]] = None,
                      path: Optional[str] = None, **client_kw):
        """Register a managed client for a multi-fetch workflow (the
        checkpoint-restore wave loop).  On exit the transfer leaves the
        fleet's residual accounting and its adopted geometry persists on
        the manager."""
        tid = next(self._tids)
        reps = self._job_replicas(replicas, path)
        self.fleet.register(tid)
        kw = {**self._client_kw, **client_kw}
        if "tuner" not in kw:
            # the shared tuner rides along by default; callers running
            # their own wave-boundary updates pass tuner=None to keep the
            # in-fetch hook quiet (reward attribution stays single-source)
            kw["tuner"] = (_SharedTuner(self, tid, reps)
                           if self.tuner is not None else None)
        warm = self._warm_params(self.fleet.active_transfers)
        client = _ManagedClient(
            reps, self, tid, params=warm,
            estimator=self._estimator, ewma_alpha=self._ewma_alpha,
            **kw)
        try:
            yield client
        finally:
            self.fleet.forget(tid)
            # persist only geometry this transfer actually LEARNED (tuner
            # adoption / retune): a transfer that just rode its
            # construction-time warm params must not clobber what a
            # concurrent peer adopted in the meantime (last-writer-wins
            # on stale state)
            if (client._params_arg is not None
                    and client._params_arg != warm):
                self.params = client._params_arg

    # -- transfers ---------------------------------------------------------

    async def fetch(self, size: int, *, path: Optional[str] = None,
                    replicas: Optional[Sequence[Replica]] = None,
                    sink=None, offset: int = 0,
                    tune_interval_bytes: Optional[int] = None,
                    start_delay: float = 0.0):
        """One managed transfer (awaitable; gather several for a fleet).

        Same contract as ``MDTPClient.fetch`` plus ``path``/``replicas``
        re-pointing and ``start_delay`` for staggered arrivals.
        """
        if start_delay > 0.0:
            await asyncio.sleep(start_delay)
        async with self.session(replicas=replicas, path=path) as client:
            buf, report = await client.fetch(
                size, sink=sink, offset=offset,
                tune_interval_bytes=tune_interval_bytes)
            self.reports.append(report)
            return buf, report

    def run(self, jobs: Sequence[TransferJob]):
        """Synchronous batch entry: run every job concurrently on one
        event loop, respecting per-job start delays.  Returns the
        ``(buffer, report)`` pairs in JOB order."""

        async def go():
            return await asyncio.gather(*(
                self.fetch(j.size, path=j.path, sink=j.sink,
                           offset=j.offset,
                           tune_interval_bytes=j.tune_interval_bytes,
                           start_delay=j.start_delay)
                for j in jobs))

        return asyncio.run(go())

    # -- contention planning ----------------------------------------------

    def plan_contention(self, file_size: int, max_transfers: int = 4,
                        bandwidth: Optional[Sequence[float]] = None,
                        rtt: Optional[Sequence[float]] = None,
                        **sweep_kw) -> dict:
        """Precompute the contention ladder: per active-transfer count k,
        the (C, L) tuned for a fair k-way split of the fleet — one fused
        vmapped sweep (``repro.core.autotune.contention_sweep``) covering
        every (k, C, L) cell.  Uses the fleet model's capacities when no
        explicit bandwidth is given (requires at least one observed
        transfer in that case).  Stores and returns ``{k: ChunkParams}``.
        """
        from repro.core.autotune import contention_sweep

        if bandwidth is None:
            snap = self.snapshot()
            bandwidth, rtt_model = [], []
            for r in self.replicas:
                st = snap.get(r.name)
                if st is not None and st["capacity"] > 0.0:
                    bandwidth.append(st["capacity"])
                    rtt_model.append(st["rtt"] if st["rtt"] > 0.0
                                     else MDTPClient.DEFAULT_RTT)
            if not bandwidth:
                raise ValueError(
                    "no fleet capacity observations to plan from — pass "
                    "bandwidth= explicitly or run a transfer first")
            if rtt is None:
                rtt = rtt_model
        if rtt is None:
            rtt = MDTPClient.DEFAULT_RTT
        # plan for the data plane the managed clients actually run: the
        # ladder must model the same request pipelining (client_kw may
        # override the depth; mirror that here)
        sweep_kw.setdefault(
            "pipeline_depth",
            self._client_kw.get("pipeline_depth", DEFAULT_PIPELINE_DEPTH))
        results = contention_sweep(bandwidth, rtt, int(file_size),
                                   max_transfers=max_transfers, **sweep_kw)
        self.contention_ladder = {
            k: res.params for k, res in results.items()}
        return self.contention_ladder

    def snapshot(self) -> dict:
        """Fleet model diagnostics (see :meth:`FleetModel.snapshot`)."""
        return self.fleet.snapshot()
