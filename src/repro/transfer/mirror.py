"""Peer mirror: a restoring node that serves what it has so far.

The broadcast building block.  A node restoring a checkpoint owns a
:class:`~repro.transfer.sink.Sink` that is filling up; ``PeerMirror``
mounts that sink's buffer on a :class:`~repro.transfer.server.RangeServer`
as a read-only **partial mirror** — the server advertises the sink's
live ``covered_intervals()`` over the wire (``X-Available-Ranges`` on
HEAD, 416-with-advertisement for uncovered GETs) and serves committed
bytes with the usual Range/CRC machinery.  Other restorers add
``mirror.replica`` to their replica list: the client sees
``Replica.mirror`` set, tracks the peer's coverage, and only packs
chunks the peer actually holds — chain/tree dissemination without any
new wire protocol beyond one header.

The mirrored buffer must follow the sinks' write-once contract
(committed bytes immutable): server threads read committed regions
concurrently with the ongoing restore, unsynchronized by design.
"""

from __future__ import annotations

from typing import Optional

from repro.transfer.client import Replica
from repro.transfer.server import FaultPolicy, RangeServer, Throttle

__all__ = ["PeerMirror"]


class PeerMirror:
    """Serve a filling :class:`Sink`'s covered ranges to peers.

    ``throttle``/``faults``/``checksums`` configure the underlying
    :class:`RangeServer` — a peer's uplink is usually throttled
    (``Throttle(bytes_per_s=..., shared=True)``: one node's egress is a
    shared pipe) and chaos tests inject faults exactly like on an
    origin.  Bind at construction (``PeerMirror(sink)``) or later
    (``restore_checkpoint`` binds once the blob size is known); the
    server starts on first bind and keeps its port across rebinds, so a
    replica handed out early stays valid.
    """

    def __init__(self, sink=None, *, path: str = "/data",
                 total: Optional[int] = None,
                 throttle: Optional[Throttle] = None,
                 faults: Optional[FaultPolicy] = None,
                 checksums: bool = True):
        self.path = path if path.startswith("/") else "/" + path
        self._server = RangeServer(throttle=throttle, faults=faults,
                                   checksums=checksums)
        self._started = False
        self._bound = False
        if sink is not None:
            self.bind(sink, total)

    # -- lifecycle --------------------------------------------------------

    def bind(self, sink, total: Optional[int] = None) -> "PeerMirror":
        """Mount ``sink`` (a :class:`repro.transfer.Sink` whose
        ``writable(0, total)`` exposes the whole destination buffer) and
        start serving its covered ranges.  ``total`` defaults to the
        sink's ``total_bytes`` / ``len()``.  Rebinding replaces any
        previous mount."""
        if getattr(sink, "mirrorable", True) is False:
            raise ValueError(
                f"{type(sink).__name__} cannot back a mirror: its "
                "writable() hands out per-range scratch, not the landed "
                "bytes")
        if total is None:
            total = getattr(sink, "total_bytes", None)
        if total is None:
            try:
                total = len(sink)
            except TypeError:
                raise ValueError(
                    "total= required: sink exposes neither total_bytes "
                    "nor __len__") from None
        total = int(total)
        view = sink.writable(0, total)
        self._server.add_partial(self.path, view, sink.covered_intervals,
                                 total)
        self._bound = True
        if not self._started:
            self.start()
        return self

    def unbind(self) -> None:
        """Stop serving (requests 404) without tearing the server down —
        a restore whose landing buffer is about to die (spool mmap)
        unbinds; the port stays up for a later rebind."""
        self._server.remove_path(self.path)
        self._bound = False

    def start(self) -> "PeerMirror":
        if not self._started:
            self._server.start()
            self._started = True
        return self

    def stop(self) -> None:
        self.unbind()
        if self._started:
            self._server.stop()
            self._started = False

    def __enter__(self) -> "PeerMirror":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- introspection ----------------------------------------------------

    @property
    def bound(self) -> bool:
        return self._bound

    @property
    def server(self) -> RangeServer:
        """The underlying server (tests use it for ``kill_connections``,
        ``set_faults``, witnesses)."""
        return self._server

    @property
    def port(self) -> int:
        return self._server.port

    @property
    def served_bytes(self) -> int:
        """Bytes this peer has served to others — the origin-offload
        witness."""
        return self._server.served_bytes

    @property
    def replica(self) -> Replica:
        """This mirror as a transfer replica (``mirror=True``: clients
        track its coverage and only pack chunks it holds)."""
        return Replica("127.0.0.1", self._server.port, self.path,
                       mirror=True)
