"""Sharded, work-stealing restore across a K-host mesh.

Planning + theft bookkeeping are pure (stdlib only, built on the
extracted :mod:`repro.transfer.sched` philosophy: decisions separate
from I/O); :func:`fetch_sharded` is the asyncio orchestration that
drives K per-host :class:`~repro.transfer.client.MDTPClient` fetches
over real sockets.

The shape of the thing
----------------------
A checkpoint blob restored onto K hosts does not need every host to pull
every byte from the origin: :func:`plan_shards` splits ``[0, total)``
into K contiguous spans — snapped to manifest leaf boundaries so each
tensor lives wholly on one host — and each host fetches only its span
(``plan_for_mesh`` / ``plan_for_ctx`` derive K and the host index from a
``launch.mesh`` mesh or the active ``distributed.context``).

Hosts serve each other while they fetch: every host mounts its filling
:class:`~repro.transfer.sink.BufferSink` on a
:class:`~repro.transfer.mirror.PeerMirror` and lists every other host's
mirror among its replicas, so the existing coverage-gated packing
(``X-Available-Ranges``) routes any byte a peer already holds over the
peer link instead of the origin.

**Work stealing** (the pcircle idea, translated to byte ranges): a host
that finishes its own span early asks the :class:`StealLedger` for a
sub-span of the *most backlogged* peer — the victim's uncovered tail —
and fetches those bytes through its own (fast) origin path into its own
buffer.  Its mirror then advertises them, and the victim's normal
coverage-gated fetch drains the stolen span from the fast thief instead
of the straggling origin.  The victim needs no new protocol and never
learns it was robbed; the only shared state is the in-process ledger
that keeps two thieves from claiming the same range.  Stolen bytes are
duplicated traffic by construction (thief and victim both hold them) —
the ledger accounts them as the price paid for the makespan win, and
``benchmarks/shard_bench.py`` guards that trade.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

from repro.transfer.journal import uncovered_intervals

__all__ = [
    "ShardPlan", "StealLedger", "ShardFetchResult", "manifest_boundaries",
    "plan_shards", "plan_for_mesh", "plan_for_ctx", "fetch_sharded",
]


# --------------------------------------------------------------------------
# Planning (pure)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardPlan:
    """K contiguous per-host byte spans covering ``[0, total)``.

    ``spans[h]`` is host ``h``'s half-open ``(start, end)``; spans are
    ascending, disjoint, and jointly exhaustive (a host may own an empty
    span when K exceeds the snappable cut count).
    """

    total: int
    spans: tuple[tuple[int, int], ...]

    @property
    def n_hosts(self) -> int:
        return len(self.spans)

    def span_of(self, host: int) -> tuple[int, int]:
        return self.spans[host]

    def nbytes_of(self, host: int) -> int:
        s, e = self.spans[host]
        return e - s

    def host_of(self, offset: int) -> int:
        """Which host's span holds byte ``offset``."""
        for h, (s, e) in enumerate(self.spans):
            if s <= offset < e:
                return h
        raise ValueError(f"offset {offset} outside [0, {self.total})")


def manifest_boundaries(manifest: dict) -> tuple[int, ...]:
    """Interior leaf-start offsets of a checkpoint manifest (the legal
    shard cut points: cutting only here keeps every tensor whole on one
    host).  The manifest is the ``save_checkpoint`` JSON dict —
    ``{"leaves": [{"offset": ..., "nbytes": ...}, ...]}``."""
    starts = sorted(int(e["offset"]) for e in manifest["leaves"])
    return tuple(s for s in starts if s > 0)


def plan_shards(total: int, hosts: int,
                boundaries: Optional[Sequence[int]] = None) -> ShardPlan:
    """Split ``[0, total)`` into ``hosts`` contiguous ~equal spans.

    With ``boundaries`` (sorted legal cut offsets, e.g.
    :func:`manifest_boundaries`), each ideal cut ``total * h / hosts``
    snaps to the nearest boundary — monotonically, so spans never
    invert; without them cuts land on the ideal byte offsets.
    """
    if hosts < 1:
        raise ValueError(f"hosts must be >= 1, got {hosts}")
    if total < 0:
        raise ValueError(f"total must be >= 0, got {total}")
    cuts = [0]
    bnd = sorted(b for b in boundaries or () if 0 < b < total)
    for h in range(1, hosts):
        ideal = (total * h) // hosts
        if bnd:
            snapped = min(bnd, key=lambda b: (abs(b - ideal), b))
        else:
            snapped = ideal
        cuts.append(max(snapped, cuts[-1]))    # monotone: no inverted span
    cuts.append(total)
    return ShardPlan(total=total, spans=tuple(
        (cuts[h], cuts[h + 1]) for h in range(hosts)))


def plan_for_mesh(total: int, mesh: Any, axis: str = "data",
                  boundaries: Optional[Sequence[int]] = None) -> ShardPlan:
    """A :class:`ShardPlan` with one shard per slice of ``mesh`` along
    ``axis`` (duck-typed ``mesh.shape[axis]`` — works with a
    ``jax.sharding.Mesh`` from ``launch.mesh`` without importing JAX
    here, so planning stays usable on I/O-only hosts)."""
    try:
        k = int(mesh.shape[axis])
    except (KeyError, TypeError) as e:
        raise ValueError(
            f"mesh has no {axis!r} axis to shard the restore over") from e
    return plan_shards(total, k, boundaries)


def plan_for_ctx(total: int, axis: str = "data",
                 boundaries: Optional[Sequence[int]] = None,
                 ctx: Any = None) -> tuple[int, ShardPlan]:
    """(this host's shard index, the plan) from a sharding context.

    ``ctx`` defaults to ``repro.distributed.context.active_ctx()``
    (imported lazily — the context module needs JAX).  The host index is
    this process's coordinate along ``axis``, so every process of a
    ``jax.distributed`` launch computes the same plan and its own slot.
    """
    if ctx is None:
        from repro.distributed.context import active_ctx

        ctx = active_ctx()
        if ctx is None:
            raise RuntimeError("no active sharding context: pass ctx= or "
                               "activate() a mesh first")
    mesh = ctx.mesh
    plan = plan_for_mesh(total, mesh, axis, boundaries)
    import jax

    host = jax.process_index() % max(plan.n_hosts, 1)
    return host, plan


# --------------------------------------------------------------------------
# Work-stealing ledger (pure)
# --------------------------------------------------------------------------

@dataclass
class _Steal:
    thief: int
    victim: int
    start: int
    end: int


class StealLedger:
    """In-process claim coordination for cross-host range theft.

    Pure bookkeeping: the ledger never looks at sockets or sinks — the
    caller supplies each victim's *uncovered* intervals (what its sink
    has not landed yet) and the ledger layers its own claims on top so
    no two thieves grab the same range.  All hosts of one
    :func:`fetch_sharded` share one ledger on one event loop, so no
    locking is needed; a cross-process port would put this same logic
    behind an RPC.
    """

    def __init__(self, plan: ShardPlan, *,
                 min_steal: int = 256 * 1024, steal_frac: float = 0.5,
                 claim_horizon_s: float = 2.0):
        self.plan = plan
        #: floor on a claim's size: sub-chunk thefts cost a connection +
        #: coverage round-trip and save almost nothing.
        self.min_steal = int(min_steal)
        #: fraction of the victim's largest unclaimed gap taken per
        #: claim — half, by default, pcircle-style: leaves the victim's
        #: own frontier room while the thief works the tail.  Used only
        #: when the thief's bandwidth is unknown (``thief_bw == 0``).
        self.steal_frac = float(steal_frac)
        #: seconds of thief throughput a bandwidth-sized claim covers:
        #: with ``thief_bw`` the claim is ``thief_bw * claim_horizon_s``
        #: bytes, so a fast thief grabs big tails while a slow one takes
        #: bites it can actually finish before the victim's own frontier
        #: would have reached them.
        self.claim_horizon_s = float(claim_horizon_s)
        #: per-victim claimed spans (half-open, unordered).
        self._claimed: list[list[tuple[int, int]]] = [
            [] for _ in plan.spans]
        self.steals: list[_Steal] = []

    @property
    def stolen_bytes(self) -> int:
        return sum(s.end - s.start for s in self.steals)

    def _unclaimed(self, victim: int,
                   uncovered: list[tuple[int, int]]) -> list[tuple[int, int]]:
        """``uncovered`` (s, n pairs within the victim's span) minus this
        ledger's outstanding claims, as half-open pairs."""
        gaps = [(s, s + n) for s, n in uncovered]
        for cs, ce in self._claimed[victim]:
            nxt = []
            for gs, ge in gaps:
                if ce <= gs or cs >= ge:
                    nxt.append((gs, ge))
                    continue
                if gs < cs:
                    nxt.append((gs, cs))
                if ce < ge:
                    nxt.append((ce, ge))
            gaps = nxt
        return gaps

    def steal(self, thief: int,
              uncovered_of: Callable[[int], list[tuple[int, int]]],
              thief_bw: float = 0.0,
              ) -> Optional[tuple[int, int, int]]:
        """Claim a sub-span of the most backlogged victim for ``thief``.

        ``uncovered_of(host)`` returns the host's not-yet-landed
        ``(start, nbytes)`` intervals *within its own span*.  Returns
        ``(victim, start, end)`` — a tail of the victim's largest
        unclaimed gap — or None when no peer has enough backlog to be
        worth robbing.

        With ``thief_bw`` (the thief's observed bytes/s, e.g. the sum of
        its EWMA per-replica throughputs), the claim is sized to what
        the thief can move in ``claim_horizon_s`` seconds, clamped to
        ``[min_steal, gap]``; without it the static ``steal_frac``
        fraction of the gap is taken.  Either way the claim never drops
        below ``min_steal``, and a gap smaller than ``2 * min_steal`` is
        taken whole (too small to split).
        """
        best: Optional[tuple[int, list[tuple[int, int]]]] = None
        best_bytes = 0
        for v in range(self.plan.n_hosts):
            if v == thief:
                continue
            gaps = self._unclaimed(v, uncovered_of(v))
            backlog = sum(e - s for s, e in gaps)
            if backlog > best_bytes:
                best, best_bytes = (v, gaps), backlog
        if best is None or best_bytes < self.min_steal:
            return None
        victim, gaps = best
        gs, ge = max(gaps, key=lambda g: g[1] - g[0])
        if thief_bw > 0.0:
            take = min(int(thief_bw * self.claim_horizon_s), ge - gs)
            take = max(take, self.min_steal)
        else:
            take = max(int((ge - gs) * self.steal_frac), self.min_steal)
        if (ge - gs) < 2 * self.min_steal:
            take = ge - gs                      # too small to split: all of it
        start = max(gs, ge - take)              # the TAIL: the victim's own
        self._claimed[victim].append((start, ge))   # frontier eats the head
        self.steals.append(_Steal(thief, victim, start, ge))
        return victim, start, ge

    def release(self, victim: int, start: int, end: int) -> None:
        """Un-claim a span whose theft failed (the thief's fetch raised)
        so another host — or the victim's own refetch — can take it."""
        with_span = (start, end)
        claims = self._claimed[victim]
        if with_span in claims:
            claims.remove(with_span)
        self.steals = [s for s in self.steals
                       if not (s.victim == victim and s.start == start
                               and s.end == end)]


# --------------------------------------------------------------------------
# Orchestration (asyncio, real sockets)
# --------------------------------------------------------------------------

@dataclass
class ShardFetchResult:
    """What :func:`fetch_sharded` hands back, per host and in aggregate."""

    plan: ShardPlan
    #: each host's full-size :class:`BufferSink` — its own span (plus any
    #: spans it stole) is landed; everything else is zero-fill.
    sinks: list
    #: per-host transfer reports, own-span fetch first, one per steal after.
    reports: list
    #: per-host seconds until the host's OWN span was fully landed.
    elapsed: list
    #: per-host bytes fetched OUTSIDE the host's own span (the theft
    #: witness: > 0 means work stealing actually moved bytes).
    stolen_bytes_per_host: list
    steals: list

    @property
    def makespan(self) -> float:
        return max(self.elapsed) if self.elapsed else 0.0

    @property
    def stolen_bytes(self) -> int:
        return sum(self.stolen_bytes_per_host)


async def fetch_sharded(total: int, plan: ShardPlan, origins: Sequence,
                        *, steal: bool = True,
                        mirrors: Optional[Sequence] = None,
                        client_factory: Optional[Callable] = None,
                        min_steal: int = 256 * 1024,
                        steal_frac: float = 0.5,
                        claim_horizon_s: float = 2.0,
                        client_kw: Optional[dict] = None,
                        ) -> ShardFetchResult:
    """Restore one blob across ``plan.n_hosts`` cooperating hosts.

    ``origins`` is either one replica list shared by every host or a
    per-host sequence of replica lists (``origins[h]`` = the full
    mirrors host ``h`` fetches from — its "own" origin path).  Each host
    lands bytes in a full-size :class:`BufferSink`, serves them through
    a :class:`PeerMirror` (pass prebuilt ``mirrors`` to throttle peer
    uplinks; unbound ones are bound here, and mirrors created here are
    stopped on exit), and lists every other host's mirror as a
    coverage-gated replica.

    With ``steal`` (default), a host that finishes its own span claims
    uncovered tails of backlogged peers from a shared
    :class:`StealLedger` and fetches them through its own origin path —
    see the module docstring for why that drains a straggler.  Claims
    are sized from the thief's just-measured throughput (the sum of its
    own-span fetch's EWMA per-replica rates, covering
    ``claim_horizon_s`` seconds of its bandwidth) so fast finishers take
    proportionally bigger tails; when a host has no throughput sample
    (empty own span) the static ``steal_frac`` rule applies.  Hosts
    always fetch their own span regardless, so the result is correct
    (every host holds its own shard) even with stealing off.
    """
    from repro.transfer.client import MDTPClient
    from repro.transfer.mirror import PeerMirror
    from repro.transfer.sink import BufferSink

    k = plan.n_hosts
    if origins and isinstance(origins[0], (list, tuple)):
        per_host = [list(o) for o in origins]
        if len(per_host) != k:
            raise ValueError(f"origins: {len(per_host)} lists for {k} hosts")
    else:
        per_host = [list(origins) for _ in range(k)]

    sinks = [BufferSink(total) for _ in range(k)]
    own_mirrors = mirrors is None
    if own_mirrors:
        mirrors = [PeerMirror(sinks[h], path=f"/shard{h}") for h in range(k)]
    else:
        mirrors = list(mirrors)
        for h, m in enumerate(mirrors):
            if not m.bound:
                m.bind(sinks[h], total)
    ledger = StealLedger(plan, min_steal=min_steal, steal_frac=steal_frac,
                         claim_horizon_s=claim_horizon_s)

    def uncovered_of(h: int) -> list[tuple[int, int]]:
        s, e = plan.spans[h]
        out = []
        for us, un in uncovered_intervals(sinks[h].covered_intervals(),
                                          total):
            lo, hi = max(us, s), min(us + un, e)
            if hi > lo:
                out.append((lo, hi - lo))
        return out

    reports: list[list] = [[] for _ in range(k)]
    elapsed = [0.0] * k
    stolen = [0] * k
    t0 = time.monotonic()

    async def run_host(h: int):
        reps = per_host[h] + [mirrors[g].replica for g in range(k) if g != h]
        if client_factory is not None:
            client = client_factory(h, reps)
        else:
            client = MDTPClient(reps, **(client_kw or {}))
        s, e = plan.spans[h]
        if e > s:
            _, rep = await client.fetch(e - s, sink=sinks[h], offset=s)
            reports[h].append(rep)
        elapsed[h] = time.monotonic() - t0

        def my_bw() -> float:
            if not reports[h]:
                return 0.0
            return sum(reports[h][-1].observed_throughputs.values())

        while steal:
            grab = ledger.steal(h, uncovered_of, thief_bw=my_bw())
            if grab is None:
                return
            victim, gs, ge = grab
            try:
                _, rep = await client.fetch(ge - gs, sink=sinks[h],
                                            offset=gs)
            except BaseException:
                ledger.release(victim, gs, ge)
                raise
            reports[h].append(rep)
            stolen[h] += ge - gs

    try:
        import asyncio

        await asyncio.gather(*(run_host(h) for h in range(k)))
    finally:
        if own_mirrors:
            for m in mirrors:
                m.stop()

    return ShardFetchResult(plan=plan, sinks=sinks, reports=reports,
                            elapsed=elapsed, stolen_bytes_per_host=stolen,
                            steals=list(ledger.steals))
