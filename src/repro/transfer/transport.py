"""Raw-socket HTTP/1.1 range transport for the MDTP client.

The wire layer of :mod:`repro.transfer.client`, factored out so the
client module is scheduler glue + observation plumbing and THIS module
is everything that touches a socket.  No aiohttp in this environment —
:class:`_Conn` is a persistent pipelined HTTP/1.1 connection on
asyncio's ``loop.sock_*`` primitives with a zero-copy receive path
(bodies are ``sock_recv_into`` memoryview slices of the caller's
buffer).  Each connection is full-duplex: an independent writer
coroutine drains a queue of request writes while reader lanes stream
bodies, so issuing the next pipelined request never waits behind an
in-flight body.  Subclasses adapt it: the data pipeline's virtual-blob
connection translates offsets, the fleet manager's managed connection
caps concurrency and feeds telemetry.

Compressed ranges (``X-Range-Encoding``, see
:mod:`repro.transfer.codec`) decode transparently here: the framed
wire body lands in scratch, inflates off the event loop, and the reply
reports decoded bytes (``nbytes``) and wire bytes (``wire_nbytes``)
separately so telemetry can track the wire rate while coverage commits
decoded bytes.
"""

from __future__ import annotations

import asyncio
import contextlib
import socket
import time
import zlib
from typing import NamedTuple, Optional

from repro.transfer import codec
from repro.transfer.sched import defaults as sched_defaults

__all__ = ["_Conn", "_RangeReply", "_crc32_async"]

#: bodies at or below this size are CRC'd inline on the event loop (the
#: executor round-trip costs more than the hash); larger bodies hash in
#: the thread pool — zlib releases the GIL, so verification overlaps the
#: next body's socket reads instead of stalling them.
_CRC_INLINE_MAX = sched_defaults.CRC_INLINE_MAX


async def _crc32_async(data) -> int:
    """CRC32 of a body, off the event loop for large bodies.

    ``zlib.crc32`` accepts any buffer and releases the GIL, so hashing a
    multi-megabyte range in the default executor runs concurrently with
    the loop's socket reads; small bodies aren't worth the thread hop.
    """
    if len(data) <= _CRC_INLINE_MAX:
        return zlib.crc32(data)
    return await asyncio.get_running_loop().run_in_executor(
        None, zlib.crc32, data)


class _RangeReply(NamedTuple):
    """One completed range request, with the timing metadata the
    observation layer needs to de-bias throughput samples."""

    #: the body: ``memoryview`` of the caller's buffer when ``into`` was
    #: given, freshly-read ``bytes`` otherwise.
    data: object
    #: body length actually served (may be < requested on a clamped tail).
    nbytes: int
    #: seconds attributable to receiving THIS body.
    elapsed: float
    #: True when ``elapsed`` spans the full request round-trip (the pipe
    #: was idle at issue time) — the estimator must strip the RTT.
    rtt_included: bool
    #: server-declared CRC32 of the range (``X-Range-Checksum`` header),
    #: None when the server doesn't checksum.  For encoded bodies the
    #: server checksums the pristine DECODED range, so verification
    #: runs on ``data`` either way.
    crc32: Optional[int] = None
    #: bytes that actually crossed the wire for this reply; None for
    #: identity-encoded bodies (wire == decoded).  Telemetry must use
    #: ``wire_bytes`` — feeding decoded bytes into a bandwidth estimator
    #: over a compressed path would double-count the codec's savings.
    wire_nbytes: Optional[int] = None

    @property
    def wire_bytes(self) -> int:
        """Wire bytes received for this body (== ``nbytes`` unless the
        body was transfer-encoded)."""
        return self.nbytes if self.wire_nbytes is None else self.wire_nbytes


class _SendOp:
    """One queued request write (duplex mode).

    Carries the request bytes, the turnstile predecessor (so the writer
    can judge idle-pipe-ness at the moment the request actually hits the
    wire) and the caller's progress list (slot 1 takes the wire-send
    stamp).  ``fut`` resolves once the request is on the wire, or fails
    with ``ConnectionError`` — every queued-but-unsent request fails
    exactly once when the connection dies, which is what lets the lane
    layer re-pool each owed range exactly once (conservation)."""

    __slots__ = ("payload", "prior", "progress", "fut",
                 "t_send", "pipelined")

    def __init__(self, payload: bytes, prior: Optional[asyncio.Event],
                 progress: Optional[list]):
        self.payload = payload
        self.prior = prior
        self.progress = progress
        self.fut: asyncio.Future = \
            asyncio.get_running_loop().create_future()
        self.t_send = 0.0
        self.pipelined = False


class _Conn:
    """One persistent pipelined HTTP/1.1 connection on a raw socket.

    Requests may be issued concurrently by several tasks.  In duplex
    mode (the default) each request is enqueued to an independent writer
    coroutine that drains the queue onto the socket — a request write
    never waits behind an in-flight response body, so the pipe stays at
    depth even when bodies stream for whole RTTs.  Responses are read
    strictly in request order via a FIFO turnstile (each request waits
    on its predecessor's completion event); enqueue order and turnstile
    order are linked atomically, and the single writer preserves that
    order on the wire.  With ``duplex=False`` the legacy half-duplex
    path sends inline under the write lock (kept as a benchmark
    baseline).  Bodies are received with ``sock_recv_into`` directly
    into the caller's buffer — the only copied bytes are the
    header-phase read-ahead (bounded by ``_HEADER_RECV`` per response)
    and encoded bodies' wire scratch.

    Collects per-connection RTT samples: the TCP connect time on session
    establishment, then the request-write → status-line turnaround of
    every request issued on an idle pipe (a queued-behind-a-body
    turnaround measures the predecessor's streaming time, not the path).
    Consumers drain ``take_rtt_samples()`` and min-aggregate.

    Any failure (transport error, malformed response, a read stalled past
    ``read_timeout``, cancellation mid-read) marks the connection
    ``broken``: the stream position is unrecoverable, so every queued
    request fails fast instead of parsing from the middle of a
    predecessor's body.
    """

    #: recv size while parsing status/headers — small so read-ahead into
    #: the copied header buffer steals at most this many body bytes from
    #: the zero-copy path per response.
    _HEADER_RECV = 4096

    def __init__(self, replica, request_latency: float = 0.0,
                 read_timeout: float = 0.0, duplex: bool = True):
        #: the replica this session targets — anything with ``host`` /
        #: ``port`` / ``path`` / ``name`` (duck-typed so this module
        #: doesn't import the client layer).
        self.replica = replica
        #: emulated request-path propagation delay (seconds) — a test and
        #: benchmark knob: loopback has no real RTT, so the dataplane
        #: bench injects one here to reproduce the WAN regime where
        #: pipelining pays off.  Applied before each request send, off
        #: the critical path of already-streaming predecessors.
        self.request_latency = request_latency
        #: per-READ inactivity bound (seconds; 0 disables).  A replica
        #: that stalls without dying would otherwise hang a lane forever
        #: — the timeout converts the stall into a ``ConnectionError`` so
        #: it takes the same re-pool path as a connection death.  Scoped
        #: per socket read, not per request: a huge range streaming
        #: slowly-but-steadily never trips it.
        self.read_timeout = read_timeout
        #: False = legacy half-duplex sends (inline under the write
        #: lock) — the benchmark baseline the duplex win-guard measures
        #: against.
        self.duplex = duplex
        self.broken = False
        self._sock: Optional[socket.socket] = None
        self._rbuf = bytearray()
        self._rtt_samples: list[float] = []
        self._wlock = asyncio.Lock()
        #: completion event of the most recently issued request (the
        #: turnstile tail); None = pipe idle since connect.
        self._tail: Optional[asyncio.Event] = None
        #: duplex writer state: the request queue and the coroutine
        #: draining it (both created lazily on the first duplex send).
        self._sendq: Optional[asyncio.Queue] = None
        self._writer: Optional[asyncio.Task] = None

    def take_rtt_samples(self) -> list[float]:
        samples, self._rtt_samples = self._rtt_samples, []
        return samples

    async def connect(self):
        loop = asyncio.get_running_loop()
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setblocking(False)
        t0 = time.monotonic()
        try:
            await loop.sock_connect(
                sock, (self.replica.host, self.replica.port))
        except BaseException:
            sock.close()
            raise
        self._rtt_samples.append(time.monotonic() - t0)
        # pipelined requests are tiny back-to-back writes: without NODELAY
        # Nagle would hold them hostage to the previous response's ACKs
        with contextlib.suppress(OSError):
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock

    async def close(self):
        writer, self._writer = self._writer, None
        if writer is not None:
            writer.cancel()
            with contextlib.suppress(asyncio.CancelledError, Exception):
                await writer
        self._fail_queued("connection closed")
        if self._sock is not None:
            with contextlib.suppress(OSError):
                self._sock.close()
            self._sock = None

    def abort(self) -> None:
        """Break the connection under a CONCURRENT reader (hedge-win
        cancellation).  ``close()`` would free the fd while a
        ``sock_recv`` future is still registered on it — the selector
        never fires for a closed fd and the loser's read would only die
        at the inactivity timeout.  ``shutdown()`` keeps the fd alive
        and wakes the pending read with EOF immediately; the owning
        worker then closes the socket on its normal unwind path.

        The writer must not deadlock either: queued-but-unsent requests
        fail synchronously here, and a write blocked in ``sock_sendall``
        wakes with an error from the shutdown — either way every lane
        parked on a send future gets its ConnectionError promptly."""
        self.broken = True
        self._fail_queued("connection aborted")
        if self._sock is not None:
            with contextlib.suppress(OSError):
                self._sock.shutdown(socket.SHUT_RDWR)

    # -- duplex writer -----------------------------------------------------

    def _fail_queued(self, why: str) -> None:
        """Fail every queued-but-unsent request (sync — callable from
        ``abort``).  Runs on the event loop thread with no await points,
        so it cannot race the writer popping the same op."""
        if self._sendq is None:
            return
        while not self._sendq.empty():
            op = self._sendq.get_nowait()
            if op is not None and not op.fut.done():
                op.fut.set_exception(ConnectionError(why))

    def _ensure_writer(self) -> None:
        if self._writer is None:
            self._sendq = asyncio.Queue()
            self._writer = asyncio.ensure_future(self._drain_sends())

    async def _drain_sends(self) -> None:
        """The writer coroutine: pop queued requests and put them on the
        wire, independent of any lane streaming a body.  A send failure
        breaks the connection and fails that op; already-queued ops then
        fail fast on the broken check — each exactly once."""
        loop = asyncio.get_running_loop()
        try:
            while True:
                op = await self._sendq.get()
                if op is None or op.fut.done():
                    continue
                if self.broken or self._sock is None:
                    op.fut.set_exception(
                        ConnectionError("pipelined connection broken"))
                    continue
                # idle-pipe-ness is judged at the moment the request
                # actually goes on the wire — a queued request whose
                # predecessor completed while it waited is NOT pipelined
                # (its turnaround measures the path, so it may RTT-sample)
                op.pipelined = (op.prior is not None
                                and not op.prior.is_set())
                op.t_send = time.monotonic()
                if op.progress is not None and len(op.progress) > 1:
                    # wire-send stamp for the hedging layer: a range
                    # starts aging only once its request is on the wire
                    op.progress[1] = op.t_send
                try:
                    await loop.sock_sendall(self._sock, op.payload)
                except BaseException as e:
                    self.broken = True
                    if not op.fut.done():
                        op.fut.set_exception(ConnectionError(
                            f"request write failed: {e!r}"))
                    if not isinstance(e, Exception):
                        raise            # propagate cancellation
                    continue
                if not op.fut.done():
                    op.fut.set_result(None)
        finally:
            # writer exiting (cancelled by close, or cancelled mid-send):
            # nothing will drain the queue any more — fail the leftovers
            # so no lane awaits a send that can never happen
            self._fail_queued("writer stopped")

    # -- buffered header reads / zero-copy body reads ----------------------

    async def _timed(self, aw):
        """Bound one socket read by the inactivity timeout."""
        if self.read_timeout <= 0.0:
            return await aw
        try:
            return await asyncio.wait_for(aw, self.read_timeout)
        except asyncio.TimeoutError:
            raise ConnectionError(
                f"read stalled > {self.read_timeout:g}s "
                f"(inactivity timeout)") from None

    def _live_sock(self) -> socket.socket:
        """Snapshot the socket for one read.  A concurrent ``close()``
        (a hedge winner severing the losing lane) nulls ``_sock`` between
        awaits; reading through the snapshot turns that race into the
        ConnectionError every caller already handles instead of an
        AttributeError on ``None``."""
        sock = self._sock
        if sock is None:
            raise ConnectionError("connection closed")
        return sock

    async def _fill(self, hint: int) -> None:
        data = await self._timed(
            asyncio.get_running_loop().sock_recv(self._live_sock(), hint))
        if not data:
            raise ConnectionError("connection closed")
        self._rbuf += data

    async def _readline(self) -> bytes:
        while True:
            idx = self._rbuf.find(b"\n")
            if idx >= 0:
                line = bytes(self._rbuf[:idx + 1])
                del self._rbuf[:idx + 1]
                return line
            if len(self._rbuf) > 65536:
                raise ConnectionError("oversized header line")
            await self._fill(self._HEADER_RECV)

    async def _read_headers(self) -> tuple[int, dict]:
        status = await self._readline()
        parts = status.split()
        if len(parts) < 2 or not parts[1].isdigit():
            raise ConnectionError(f"malformed status line: {status!r}")
        code = int(parts[1])
        headers = {}
        while True:
            line = await self._readline()
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        return code, headers

    async def _read_body(self, n: int, into: Optional[memoryview],
                         progress: Optional[list] = None):
        """Read exactly ``n`` body bytes — into the caller's view when
        given (zero-copy), into fresh ``bytes`` otherwise.  Slot 0 of
        ``progress`` (a list) is kept updated with the byte count landed
        so far — the hedging layer reads it to avoid duplicating ranges
        whose owner has already received most of the body."""
        if into is None:
            scratch = bytearray(n)
            view = memoryview(scratch)
        else:
            if len(into) < n:
                raise ConnectionError(
                    f"response body {n} B overruns the {len(into)} B "
                    f"destination range")
            scratch = None
            view = into
        got = min(len(self._rbuf), n)   # header-phase read-ahead first
        if got:
            view[:got] = self._rbuf[:got]
            del self._rbuf[:got]
        if progress is not None:
            progress[0] = got
        loop = asyncio.get_running_loop()
        try:
            while got < n:
                r = await self._timed(
                    loop.sock_recv_into(self._live_sock(), view[got:n]))
                if r <= 0:
                    raise ConnectionError(
                        f"connection closed mid-body ({got}/{n} B)")
                got += r
                if progress is not None:
                    progress[0] = got
        except ConnectionError as e:
            # how much of the body actually landed before the break —
            # the waste accounting for a hedge-cancelled read charges
            # the bytes genuinely spent, not the whole range
            e.partial_bytes = got
            raise
        return bytes(scratch) if scratch is not None else view[:n]

    # -- requests ----------------------------------------------------------

    def _request_bytes(self, method: str, start=None, end=None) -> bytes:
        rng = (f"Range: bytes={start}-{end}\r\n"
               if start is not None else "")
        return (f"{method} {self.replica.path} HTTP/1.1\r\n"
                f"Host: {self.replica.host}\r\n{rng}"
                f"Connection: keep-alive\r\n\r\n").encode()

    @staticmethod
    def _parse_checksum(headers: dict) -> Optional[int]:
        raw = headers.get("x-range-checksum")
        if raw and raw.startswith("crc32:"):
            try:
                return int(raw[len("crc32:"):], 16)
            except ValueError:
                return None
        return None

    async def fetch_range(self, start: int, end: int,
                          into: Optional[memoryview] = None,
                          progress: Optional[list] = None) -> _RangeReply:
        """GET bytes [start, end] inclusive over the persistent session.

        May be called concurrently: the request goes on the wire
        immediately (pipelined behind any in-flight predecessors) and the
        response is read in FIFO order.  With ``into``, the body is
        received directly into that view and the reply's ``data`` is
        ``into[:nbytes]``; without it, fresh ``bytes`` are returned.
        """
        if self._sock is None:
            # concurrent lanes race to the first request: exactly one may
            # establish the session (an unguarded lazy connect would open
            # one socket per lane and leak all but the last)
            async with self._wlock:
                if self._sock is None and not self.broken:
                    try:
                        await self.connect()
                    except BaseException:
                        self.broken = True
                        raise
        if self.request_latency > 0.0:
            await asyncio.sleep(self.request_latency)
        my_done = asyncio.Event()
        op: Optional[_SendOp] = None
        if self.duplex:
            # no awaits between the broken check and the enqueue: the
            # turnstile link and the queue position are taken atomically,
            # and the single writer preserves that order on the wire
            if self.broken or self._sock is None:
                raise ConnectionError("pipelined connection broken")
            self._ensure_writer()
            op = _SendOp(self._request_bytes("GET", start, end),
                         self._tail, progress)
            prior = op.prior
            self._tail = my_done
            self._sendq.put_nowait(op)
            pipelined, t_send = False, 0.0       # filled in by the writer
        else:
            async with self._wlock:
                if self.broken or self._sock is None:
                    raise ConnectionError("pipelined connection broken")
                prior = self._tail
                self._tail = my_done
                pipelined = prior is not None and not prior.is_set()
                t_send = time.monotonic()
                if progress is not None and len(progress) > 1:
                    # wire-send stamp for the hedging layer: a range
                    # starts aging only once its request is on the wire
                    progress[1] = t_send
                try:
                    await asyncio.get_running_loop().sock_sendall(
                        self._sock, self._request_bytes("GET", start, end))
                except BaseException:
                    self.broken = True
                    my_done.set()
                    raise
        try:
            if op is not None:
                # request on the wire (or the connection died first —
                # every queued-unsent request fails here exactly once)
                await op.fut
                pipelined, t_send = op.pipelined, op.t_send
            if prior is not None:
                await prior.wait()
            if self.broken:
                raise ConnectionError("pipelined predecessor failed")
            t_ready = time.monotonic()
            code, headers = await self._read_headers()
            if not pipelined:
                # idle-pipe turnaround = request RTT + server think time
                self._rtt_samples.append(time.monotonic() - t_send)
            if code not in (200, 206):
                raise ConnectionError(f"HTTP {code}")
            try:
                n = int(headers["content-length"])
            except (KeyError, ValueError):
                raise ConnectionError("missing/invalid Content-Length")
            enc_block = codec.parse_encoding(
                headers.get("x-range-encoding"))
            if enc_block is None:
                body = await self._read_body(n, into, progress)
                t_end = time.monotonic()
                wire_n = None
                ndec = n
            else:
                # encoded body: the framed wire payload lands in scratch
                # (progress tracks WIRE bytes — hedge aging sees real
                # landings), then inflates off the event loop into the
                # caller's buffer.  elapsed is stamped before the decode:
                # it measures the wire, and the decode overlaps other
                # lanes' socket reads in the executor anyway.
                lo, hi = self._decoded_span(headers, start, end)
                ndec = hi - lo + 1
                if into is not None and len(into) < ndec:
                    raise ConnectionError(
                        f"decoded body {ndec} B overruns the "
                        f"{len(into)} B destination range")
                wire = await self._read_body(n, None, progress)
                t_end = time.monotonic()
                wire_n = n
                # the socket is past this response: release the read
                # turnstile BEFORE inflating, so the successor lane's
                # header/body reads overlap this lane's decode (the
                # stream stays aligned either way — decode failures
                # mark the conn broken without desyncing it)
                my_done.set()
                if into is not None:
                    await codec.decode_range_async(wire, lo, hi, out=into)
                    body = into[:ndec]
                else:
                    body = await codec.decode_range_async(wire, lo, hi)
            return _RangeReply(
                data=body, nbytes=ndec,
                elapsed=t_end - (t_ready if pipelined else t_send),
                rtt_included=not pipelined,
                crc32=self._parse_checksum(headers),
                wire_nbytes=wire_n)
        except BaseException:
            self.broken = True
            raise
        finally:
            my_done.set()

    @staticmethod
    def _decoded_span(headers: dict, start: int, end: int) -> tuple[int, int]:
        """Decoded [lo, hi] served for an encoded reply — from
        Content-Range (authoritative: the server clamps tails there, in
        decoded coordinates), falling back to the requested span."""
        cr = headers.get("content-range", "")
        if cr.startswith("bytes "):
            span = cr[len("bytes "):].split("/", 1)[0]
            lo_s, _, hi_s = span.partition("-")
            try:
                return int(lo_s), int(hi_s)
            except ValueError:
                pass
        return start, end

    async def head(self) -> tuple[int, dict]:
        """HEAD the replica's path; returns (status, headers).  Not
        pipelined — used once per transfer for size discovery."""
        if self._sock is None:
            await self.connect()
        await asyncio.get_running_loop().sock_sendall(
            self._sock, self._request_bytes("HEAD"))
        return await self._read_headers()
