"""Block-compressed range framing for the MDTP dataplane.

The compressed-range path moves fewer bytes for the same data: the
server holds a blob as fixed-size DECODED blocks (the last one short),
each deflated independently with zlib, and a range response's body is
the framed sequence of whole blocks covering the requested decoded
span.  Responses carry ``X-Range-Encoding: zblock; block=<B>`` so the
client knows to decode; range semantics stay byte-addressable in
decoded coordinates throughout — ``Range``/``Content-Range``, the
checksum header and the scheduler's coverage accounting all speak
decoded offsets, and only ``Content-Length`` (plus bandwidth
telemetry) is the framed *wire* length.

Frame layout (16-byte big-endian header, one frame per block)::

    +---------------+-------------+----------+------------------+
    | decoded_start | decoded_len | comp_len |  zlib payload    |
    |      u64      |     u32     |   u32    |  comp_len bytes  |
    +---------------+-------------+----------+------------------+

Blocks compress independently, so a client trims the head and tail
frames to the requested span without touching the rest of the blob.

Everything here is synchronous and pure; :func:`decode_range_async` is
the event-loop adapter — small payloads decode inline (the executor
round-trip costs more than the inflate), large ones in the default
executor where zlib releases the GIL, so decode overlaps the next
body's socket reads and the sink's device transfers.
"""

from __future__ import annotations

import asyncio
import struct
import zlib
from typing import Optional

from repro.transfer.sched import defaults as sched_defaults

__all__ = ["BlockStore", "CodecError", "DEFAULT_BLOCK", "ENCODING",
           "compress_blocks", "decode_range", "decode_range_into",
           "decode_range_async", "encoding_header", "parse_encoding"]

#: default decoded block size.  Big enough that zlib's per-call overhead
#: and the 16 B frame header are noise, small enough that a head/tail
#: trim never inflates much more than it needs.
DEFAULT_BLOCK = 256 * 1024

#: codec name carried in ``X-Range-Encoding``.
ENCODING = "zblock"

#: payloads at or below this size inflate inline on the event loop;
#: larger ones go to the executor (same split as the CRC path).
_INLINE_MAX = sched_defaults.CRC_INLINE_MAX

_FRAME = struct.Struct(">QII")


class CodecError(ConnectionError):
    """Malformed or short frame stream.  A ``ConnectionError`` subclass
    on purpose: the transport's failure handling already re-pools the
    range and retires the connection on ConnectionError, and a framing
    error means the stream can't be trusted any more than a torn one."""


def encoding_header(block_size: int) -> str:
    """Value for ``X-Range-Encoding``."""
    return f"{ENCODING}; block={int(block_size)}"


def parse_encoding(value: Optional[str]) -> Optional[int]:
    """Block size from an ``X-Range-Encoding`` value, None when the
    header is absent or names a codec this module doesn't speak."""
    if not value:
        return None
    name, _, rest = value.partition(";")
    if name.strip().lower() != ENCODING:
        return None
    for part in rest.split(";"):
        k, _, v = part.partition("=")
        if k.strip().lower() == "block":
            try:
                return int(v.strip())
            except ValueError:
                return None
    return None


class BlockStore:
    """An immutable block-compressed blob: per-block frames ready to
    concatenate into response bodies (no per-request compression)."""

    __slots__ = ("block_size", "total", "_frames")

    def __init__(self, block_size: int, total: int, frames: list):
        self.block_size = int(block_size)
        self.total = int(total)
        self._frames = frames

    @property
    def wire_total(self) -> int:
        """Framed size of the whole blob (the wire bytes a full GET
        moves) — ``wire_total / total`` is the achieved ratio."""
        return sum(len(f) for f in self._frames)

    def _span(self, lo: int, hi: int) -> tuple[int, int]:
        if not (0 <= lo <= hi < self.total):
            raise ValueError(f"range [{lo}, {hi}] outside blob "
                             f"of {self.total} B")
        return lo // self.block_size, hi // self.block_size

    def encode_range(self, lo: int, hi: int) -> bytes:
        """Framed body covering decoded ``[lo, hi]`` inclusive — whole
        blocks, so the body may decode to a superset of the request."""
        b0, b1 = self._span(lo, hi)
        return b"".join(self._frames[b0:b1 + 1])

    def wire_length(self, lo: int, hi: int) -> int:
        """Length of :meth:`encode_range` without building the body."""
        b0, b1 = self._span(lo, hi)
        return sum(len(f) for f in self._frames[b0:b1 + 1])


def compress_blocks(data, block_size: int = DEFAULT_BLOCK) -> BlockStore:
    """Deflate ``data`` into independent fixed-size blocks."""
    if block_size <= 0:
        raise ValueError("block_size must be positive")
    view = memoryview(data)
    frames = []
    for start in range(0, len(view), block_size):
        raw = view[start:start + block_size]
        comp = zlib.compress(bytes(raw))
        frames.append(_FRAME.pack(start, len(raw), len(comp)) + comp)
    return BlockStore(block_size, len(view), frames)


def _iter_frames(payload):
    view = memoryview(payload)
    off = 0
    while off < len(view):
        if off + _FRAME.size > len(view):
            raise CodecError(f"torn frame header at byte {off}")
        dstart, dlen, clen = _FRAME.unpack_from(view, off)
        off += _FRAME.size
        if off + clen > len(view):
            raise CodecError(f"torn frame payload at byte {off}")
        yield dstart, dlen, view[off:off + clen]
        off += clen


def decode_range_into(payload, lo: int, hi: int, out) -> int:
    """Inflate a framed body into ``out``, keeping only decoded bytes
    ``[lo, hi]`` inclusive (head/tail blocks are trimmed).  Frames must
    arrive in order and cover the span contiguously — a gap or a short
    block raises :class:`CodecError`.  Returns the byte count written
    (``hi - lo + 1``)."""
    need = hi - lo + 1
    if len(out) < need:
        raise CodecError(f"decoded range {need} B overruns the "
                         f"{len(out)} B destination")
    cursor = lo                      # next decoded offset still owed
    for dstart, dlen, comp in _iter_frames(payload):
        try:
            block = zlib.decompress(comp)
        except zlib.error as e:
            raise CodecError(f"inflate failed at decoded offset "
                             f"{dstart}: {e}") from None
        if len(block) != dlen:
            raise CodecError(f"block at {dstart} decoded to "
                             f"{len(block)} B, header said {dlen} B")
        dend = dstart + dlen
        if dstart > cursor:
            raise CodecError(f"frame gap: owed decoded offset {cursor}, "
                             f"next frame starts at {dstart}")
        if dend <= cursor:
            continue
        take_hi = min(dend, hi + 1)
        out[cursor - lo:take_hi - lo] = block[cursor - dstart:
                                              take_hi - dstart]
        cursor = take_hi
        if cursor > hi:
            break
    if cursor <= hi:
        raise CodecError(f"frame stream ended at decoded offset "
                         f"{cursor}, range runs to {hi}")
    return need


def decode_range(payload, lo: int, hi: int) -> bytes:
    """:func:`decode_range_into` with a fresh buffer."""
    out = bytearray(hi - lo + 1)
    decode_range_into(payload, lo, hi, memoryview(out))
    return bytes(out)


async def decode_range_async(payload, lo: int, hi: int,
                             out: Optional[memoryview] = None):
    """Decode off the event loop for large payloads.  With ``out``,
    writes into it and returns the decoded byte count; without, returns
    fresh ``bytes``."""
    if len(payload) <= _INLINE_MAX:
        if out is not None:
            return decode_range_into(payload, lo, hi, out)
        return decode_range(payload, lo, hi)
    loop = asyncio.get_running_loop()
    if out is not None:
        return await loop.run_in_executor(
            None, decode_range_into, payload, lo, hi, out)
    return await loop.run_in_executor(None, decode_range, payload, lo, hi)
