"""Sans-I/O chunk scheduling: MDTP's allocator as a pure state machine.

``repro.transfer.sched`` holds the transfer stack's decision code with
no transport attached — no sockets, no event loop, no JAX (the layering
gate ``tools/layercheck.py`` enforces this transitively).  The real
socket client (``repro.transfer.client``), the fleet manager, the
sharded-restore planner, simulators, and tests all drive the same
:class:`ChunkScheduler` through explicit events; :mod:`.defaults` is
the single source of truth for the tuning constants the layers used to
duplicate.
"""

from . import defaults
from .core import (
    Assignment,
    ChunkScheduler,
    CommitResult,
    CorruptResult,
    HedgeResult,
    ReclaimResult,
    cov_contains,
    cov_first_in,
    cov_first_out,
    cov_run_at,
    replay,
)

__all__ = [
    "Assignment", "ChunkScheduler", "CommitResult", "CorruptResult",
    "HedgeResult", "ReclaimResult", "cov_contains", "cov_first_in",
    "cov_first_out", "cov_run_at", "defaults", "replay",
]
