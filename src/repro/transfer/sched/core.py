"""The sans-I/O chunk-scheduling core of MDTP.

:class:`ChunkScheduler` is the allocator brain extracted whole from
``MDTPClient.fetch``: the fresh-byte frontier with stripe rotation, the
reclaimed-range min-heap with per-replica ban sets, coverage-constrained
packing onto partial mirrors with the origin-offload pass, the hedged
endgame (eligibility, waste budget, settled-range healing), and the
give-up rule for uncoverable tails.  It is a plain synchronous state
machine: no sockets, no event loop, no JAX — ``tools/layercheck.py``
enforces that transitively.  Transports drive it through explicit
events:

* ``next_want`` / ``on_assign`` — size and claim the next sub-range for
  a replica (the allocator's bin-packing step),
* ``on_commit`` / ``on_corrupt`` / ``on_reclaim`` — resolve an owed
  range (landed clean, landed corrupt, or returned by a failure),
* ``pick_hedge`` / ``on_hedge_issue`` / ``on_hedge_result`` /
  ``on_hedge_abandon`` / ``on_hedge_corrupt`` — the endgame race,
* ``on_coverage_update`` / ``on_replica_death`` — mirror advertisement
  and liveness changes,
* ``observe_rtt`` / ``observe_latency`` / ``add_stall`` — telemetry.

Time is injected (``clock=``), so simulators and tests replay recorded
timelines exactly.  The event-loop client calls every method under its
own lock; the scheduler itself does no synchronization.

Decision methods return small result tuples describing the I/O the
transport must perform (heal these winner bytes back over a losing
landing, abort that replica's duplicate connection, wake parked lanes)
— the scheduler decides, the transport acts.

Pass ``trace=[]`` to record every event (name, clock, args, normalized
result); :func:`replay` re-drives a recorded trace through a fresh
scheduler and reports any decision divergence — the parity harness in
``tests/test_sched.py`` uses it to prove the socket client and the bare
state machine share one brain.
"""

from __future__ import annotations

import bisect
import heapq
import time
from typing import NamedTuple, Optional, Sequence

from repro.core.chunking import ChunkParams, next_chunk_size
from repro.transfer.journal import uncovered_intervals

from . import defaults

__all__ = [
    "Assignment", "ChunkScheduler", "CommitResult", "CorruptResult",
    "HedgeResult", "ReclaimResult", "cov_contains", "cov_first_in",
    "cov_first_out", "cov_run_at", "replay",
]


# -- coverage-run helpers -------------------------------------------------
# ``runs`` is sorted disjoint (start, end) pairs.  These are the packing
# primitives shared by the draw path, the hedge eligibility check, and
# the give-up rule.

def cov_run_at(runs: list, pos: int) -> Optional[tuple]:
    """The (start, end) run containing ``pos``, or None."""
    k = bisect.bisect_right(runs, (pos, 1 << 62)) - 1
    if k >= 0 and runs[k][0] <= pos < runs[k][1]:
        return runs[k]
    return None


def cov_contains(runs: list, s: int, e: int) -> bool:
    """Does one run cover ``[s, e)`` entirely?"""
    got = cov_run_at(runs, s)
    return got is not None and got[1] >= e


def cov_first_in(runs: list, s: int, e: int) -> Optional[tuple]:
    """First sub-span of ``[s, e)`` INSIDE the runs, or None."""
    got = cov_run_at(runs, s)
    if got is not None:
        return s, min(e, got[1])
    k = bisect.bisect_left(runs, (s, s))
    if k < len(runs) and runs[k][0] < e:
        return runs[k][0], min(e, runs[k][1])
    return None


def cov_first_out(runs: list, s: int, e: int) -> Optional[tuple]:
    """First sub-span of ``[s, e)`` OUTSIDE the runs, or None."""
    at = s
    while at < e:
        got = cov_run_at(runs, at)
        if got is None:
            k = bisect.bisect_left(runs, (at, at))
            nxt = runs[k][0] if k < len(runs) else e
            return at, min(e, nxt)
        at = got[1]
    return None


# -- event results --------------------------------------------------------

class Assignment(NamedTuple):
    """A claimed sub-range: fetch ``[start, start + length)``.

    ``progress`` is a live ``[bytes_landed, wire_send_time]`` list the
    transport updates as the body streams — the hedge trigger reads it.
    """
    start: int
    length: int
    ban: frozenset
    progress: list


class CommitResult(NamedTuple):
    """Outcome of a clean owner landing.  ``settled_won``: a hedge beat
    this body — count nothing, write ``heal`` back over the landing.
    ``cancel_hedger``: replica index whose in-flight duplicate of this
    range should be aborted.  ``wake``: wake parked lanes."""
    settled_won: bool
    heal: Optional[bytes]
    cancel_hedger: Optional[int]
    wake: bool


class CorruptResult(NamedTuple):
    """Outcome of a corrupt owner landing (range re-pooled, banned for
    the offender).  ``dead``: the offender crossed the corruption cap
    and was retired."""
    dead: bool
    heal: Optional[bytes]
    cancel_hedger: Optional[int]


class ReclaimResult(NamedTuple):
    """Outcome of returning an owed range after a failure.  ``settled``:
    a winning hedge already delivered it — nothing re-pooled."""
    settled: bool
    heal: Optional[bytes]
    cancel_hedger: Optional[int]


class HedgeResult(NamedTuple):
    """Outcome of a completed hedge body: ``won`` means the duplicate
    settled the range and ``cancel_owner`` (the losing owner's index)
    should have its connection aborted."""
    won: bool
    cancel_owner: Optional[int]


class ChunkScheduler:
    """Pure decision state for one window of ``size`` bytes.

    ``mirrors[i]`` flags replica ``i`` as a partial peer mirror (packed
    only where its advertised coverage allows); full replicas pass
    False.  ``hedge_quantile`` of 0 disables the endgame race entirely
    (the in-flight ``outstanding`` map is then not maintained).

    All byte positions are window-relative; the transport applies its
    own absolute offset on the wire.
    """

    def __init__(self, size: int, mirrors: Sequence[bool], *,
                 params: Optional[ChunkParams] = None,
                 depth: int = defaults.PIPELINE_DEPTH,
                 hedge_quantile: float = 0.0,
                 hedge_waste_frac: float = defaults.HEDGE_WASTE_FRAC,
                 default_rtt: float = defaults.DEFAULT_RTT,
                 max_failures: int = 3,
                 coverage_refresh_s: float = 0.05,
                 stripe: Optional[tuple] = None,
                 clock=None, trace: Optional[list] = None):
        self.size = int(size)
        self.n = len(mirrors)
        self.params = params
        self.depth = int(depth)
        self.hedge_quantile = float(hedge_quantile)
        self.hedge_waste_frac = float(hedge_waste_frac)
        self.default_rtt = float(default_rtt)
        self.max_failures = int(max_failures)
        self.refresh_s = max(float(coverage_refresh_s), 0.005)
        self.cov_patience = max(1.0, 10.0 * self.refresh_s)
        self._clock = clock if clock is not None else time.monotonic
        self.trace = trace

        size = self.size
        # fresh-byte frontier: never-assigned (start, end) segments;
        # ``stripe=(k, n)`` rotates the walk to start at size*k//n.
        self.segs: list = [(0, size)] if size > 0 else []
        if stripe is not None and size > 0:
            k_, n_ = stripe
            p = (size * (k_ % max(int(n_), 1))) // max(int(n_), 1)
            if 0 < p < size:
                self.segs = [(p, size), (0, p)]
        self.fresh = sum(e_ - s_ for s_, e_ in self.segs)
        # reclaimed (start, len, banned) min-heap; ranges never overlap
        # so comparisons never reach the non-orderable ban frozenset.
        self.pool: list = []
        self.pooled = 0
        self.inflight = 0
        self.done_bytes = 0
        self.resumed_bytes = 0
        self.refetched = 0
        self.alive: set = set(range(self.n))
        self.failed: list = []          # replica indices, append order
        self._failed_set: set = set()
        self.bytes_per = [0] * self.n
        self.reqs_per = [0] * self.n
        self.retries_per = [0] * self.n
        self.corrupt_per = [0] * self.n
        self.rtt_min = [0.0] * self.n   # 0 = no sample yet
        # -- partial-mirror coverage --------------------------------------
        #: index -> window-relative sorted disjoint (start, end) runs;
        #: None = full replica.  Mirrors start EMPTY until advertised.
        self.avail: list = [([] if m else None) for m in mirrors]
        self.partial_idx = [j for j, m in enumerate(mirrors) if m]
        self.cov_union: list = []
        self.cov_stamp = self._clock()
        # -- hedged endgame ----------------------------------------------
        self.lat_ewma = [0.0] * self.n  # per-byte receive latency EWMA
        self.last_done = [0.0] * self.n
        self.last_done_stall = [0.0] * self.n
        self.stall = 0.0                # accumulated scheduler-stall time
        #: start -> (length, owner, ban, progress, stall_at); maintained
        #: only while hedging is enabled.
        self.outstanding: dict = {}
        self.hedged: dict = {}          # start -> (length, hedger)
        self.settled: set = set()
        self.settled_data: dict = {}
        self.hedges_issued = 0
        self.hedges_won = 0
        self.hedge_wasted = 0
        self._rec("init", self._clock(), (), None)

    # -- recording --------------------------------------------------------

    def _rec(self, name, now, args, result):
        if self.trace is not None:
            self.trace.append((name, now, args, result))
        return result

    # -- plain state views ------------------------------------------------

    @property
    def remaining(self) -> int:
        """Unassigned bytes (fresh frontier + reclaimed pool)."""
        return self.fresh + self.pooled

    @property
    def finished(self) -> bool:
        """No unassigned work and nothing on the wire."""
        return self.remaining <= 0 and self.inflight <= 0

    def is_alive(self, i: int) -> bool:
        return i in self.alive

    def is_failed(self, i: int) -> bool:
        return i in self._failed_set

    def coverage_of(self, j: int):
        return self.avail[j]

    # -- configuration events --------------------------------------------

    def adopt_params(self, params: ChunkParams) -> None:
        """Switch chunk geometry mid-transfer (a retune landing)."""
        self.params = params
        self._rec("adopt_params", self._clock(), (params,), None)

    def seed_resume(self, covered: list) -> int:
        """Credit already-verified coverage (sorted disjoint
        ``(start, nbytes)`` pairs): uncovered gaps go to the pool, the
        fresh frontier is dropped, and the covered bytes count done.
        Returns the resumed byte count."""
        for s_, n_ in uncovered_intervals(covered, self.size):
            heapq.heappush(self.pool, (s_, n_, frozenset()))
            self.pooled += n_
        self.segs.clear()
        self.fresh = 0
        self.resumed_bytes = self.size - self.pooled
        self.done_bytes = self.resumed_bytes
        return self._rec("seed_resume", self._clock(), (tuple(covered),),
                         self.resumed_bytes)

    # -- telemetry events -------------------------------------------------

    def observe_rtt(self, i: int, sample: float) -> None:
        if sample > 0.0:
            self.rtt_min[i] = (sample if self.rtt_min[i] <= 0.0
                               else min(self.rtt_min[i], sample))
        self._rec("observe_rtt", self._clock(), (i, sample), None)

    def observe_latency(self, i: int, ndata: int, elapsed: float) -> None:
        """Feed the straggler signal: per-byte latency EWMA plus the
        last-completion stamp (the wedge signal)."""
        now = self._clock()
        if ndata > 0 and elapsed > 0.0:
            self.last_done[i] = now
            self.last_done_stall[i] = self.stall
            pb = elapsed / ndata
            self.lat_ewma[i] = pb if self.lat_ewma[i] <= 0.0 \
                else 0.5 * self.lat_ewma[i] + 0.5 * pb
        self._rec("observe_latency", now, (i, ndata, elapsed), None)

    def add_stall(self, seconds: float) -> None:
        """Charge scheduler-stall time: the host starved every lane at
        once, so in-flight ages discount it rather than hedge healthy
        owners."""
        self.stall += seconds
        self._rec("add_stall", self._clock(), (seconds,), None)

    def on_retry(self, i: int) -> None:
        self.retries_per[i] += 1
        self._rec("on_retry", self._clock(), (i,), None)

    def mark_failed(self, i: int) -> None:
        """Retire replica ``i`` permanently (failure cap crossed)."""
        if i not in self._failed_set:
            self._failed_set.add(i)
            self.failed.append(i)
        self._rec("mark_failed", self._clock(), (i,), None)

    # -- liveness / coverage events --------------------------------------

    def on_replica_death(self, i: int) -> None:
        """Worker exit: parked peers key takeability off the live set,
        and a dead mirror's advertisement no longer counts."""
        now = self._clock()
        self.alive.discard(i)
        if self.avail[i] is not None:
            self.avail[i] = []
            self._recompute_union()
            self.cov_stamp = now
        self._rec("on_replica_death", now, (i,), None)

    def on_coverage_update(self, j: int, runs: list) -> bool:
        """Publish mirror ``j``'s advertised coverage (window-relative
        sorted disjoint (start, end) runs).  Returns True when it
        changed — the transport wakes parked lanes."""
        now = self._clock()
        runs = list(runs)
        changed = runs != self.avail[j]
        if changed:
            self.avail[j] = runs
            self._recompute_union()
            self.cov_stamp = now
        return self._rec("on_coverage_update", now, (j, tuple(runs)),
                         changed)

    def _recompute_union(self) -> None:
        runs = []
        for j in self.partial_idx:
            if j in self.alive:
                runs.extend(self.avail[j])
        runs.sort()
        merged: list = []
        for s_, e_ in runs:
            if merged and s_ <= merged[-1][1]:
                if e_ > merged[-1][1]:
                    merged[-1] = (merged[-1][0], e_)
            else:
                merged.append((s_, e_))
        self.cov_union[:] = merged

    # -- packing internals ------------------------------------------------

    def _capable(self, j: int, s_: int, ln_: int) -> bool:
        """Could replica ``j`` serve any part of ``[s_, s_+ln_)``?"""
        cov_j = self.avail[j]
        return cov_j is None or \
            cov_first_in(cov_j, s_, s_ + ln_) is not None

    def _ban_ok(self, i: int, s_: int, ln_: int, ban_: frozenset) -> bool:
        """May replica ``i`` take an entry tagged ``ban_``?  A banned
        replica stands aside while any OTHER live capable replica
        remains unbanned; once none does, anyone may retry (the
        re-verify catches a repeat corruption; refusing would deadlock
        the tail)."""
        if i not in ban_:
            return True
        return not any(j not in ban_ and self._capable(j, s_, ln_)
                       for j in self.alive)

    def _pick_pool_entry(self, i: int) -> Optional[int]:
        """Index of the lowest-start pool entry replica ``i`` may take.
        Linear scan: the pool holds reclaimed ranges only."""
        best = None
        for k, (s_, ln_, ban_) in enumerate(self.pool):
            if not self._ban_ok(i, s_, ln_, ban_):
                continue
            if best is None or s_ < self.pool[best][0]:
                best = k
        return best

    def _take_pool(self, k: int, at: int, take: int) -> None:
        """Claim ``[at, at+take)`` out of pool entry ``k``: un-taken
        prefix/suffix pieces keep the ban tag and return to the heap."""
        s_, ln_, ban_ = self.pool.pop(k)
        if at > s_:
            self.pool.append((s_, at - s_, ban_))
        tail = (s_ + ln_) - (at + take)
        if tail > 0:
            self.pool.append((at + take, tail, ban_))
        heapq.heapify(self.pool)
        self.pooled -= take

    def _take_seg(self, si: int, at: int, take: int) -> None:
        """Claim ``[at, at+take)`` out of frontier segment ``si``."""
        s_, e_ = self.segs[si]
        if at == s_ and at + take == e_:
            del self.segs[si]
        elif at == s_:
            self.segs[si] = (at + take, e_)
        elif at + take == e_:
            self.segs[si] = (s_, at)
        else:
            self.segs[si:si + 1] = [(s_, at), (at + take, e_)]
        self.fresh -= take

    def _past_endgame(self) -> bool:
        """Residual still ABOVE the endgame window (~ENDGAME_ROUNDS
        allocator rounds: large_chunk per live replica is one round's
        share)."""
        return self.fresh + self.pooled + self.inflight > \
            defaults.ENDGAME_ROUNDS * self.params.large_chunk \
            * max(len(self.alive), 1)

    def origin_restricted(self) -> bool:
        """Should full replicas keep off peer-covered spans right now?
        True while live peers advertise coverage AND the transfer is
        not in its endgame: every peer-covered byte the origin
        re-serves is egress the whole swarm pays for.  In the endgame
        the origin rejoins freely — an idle origin must not stretch
        the tail."""
        if not self.cov_union:
            return False
        return self._past_endgame()

    def can_draw(self, i: int) -> bool:
        """Is there ANY remaining span replica ``i`` may serve right
        now?  The park/draw gate: full replicas can take fresh bytes or
        any un-banned pool entry (uncovered-only while
        ``origin_restricted``); a partial mirror needs its advertisement
        to intersect something."""
        cov = self.avail[i]
        if cov is None:
            if self.origin_restricted():
                for s_, ln_, ban_ in self.pool:
                    if self._ban_ok(i, s_, ln_, ban_) and cov_first_out(
                            self.cov_union, s_, s_ + ln_) is not None:
                        return self._rec("can_draw", self._clock(), (i,),
                                         True)
                got = any(
                    cov_first_out(self.cov_union, s_, e_) is not None
                    for s_, e_ in self.segs)
                return self._rec("can_draw", self._clock(), (i,), got)
            got = self.fresh > 0 or (bool(self.pool)
                                     and self._pick_pool_entry(i)
                                     is not None)
            return self._rec("can_draw", self._clock(), (i,), got)
        if not cov:
            return self._rec("can_draw", self._clock(), (i,), False)
        got = False
        for s_, ln_, ban_ in self.pool:
            if self._ban_ok(i, s_, ln_, ban_) \
                    and cov_first_in(cov, s_, s_ + ln_) is not None:
                got = True
                break
        got = got or any(cov_first_in(cov, s_, e_) is not None
                         for s_, e_ in self.segs)
        return self._rec("can_draw", self._clock(), (i,), got)

    def hopeless(self) -> bool:
        """Give-up rule: every surviving source is a partial mirror,
        their joint coverage has been static for a patience window, and
        some remaining span lies outside it — those bytes can never
        arrive, so the transport should stop waiting and raise."""
        now = self._clock()
        if self.inflight > 0 or not self.partial_idx:
            return self._rec("hopeless", now, (), False)
        if any(self.avail[j] is None for j in self.alive):
            return self._rec("hopeless", now, (), False)
        if now - self.cov_stamp < self.cov_patience:
            return self._rec("hopeless", now, (), False)
        got = False
        for s_, ln_, _b in self.pool:
            if not cov_contains(self.cov_union, s_, s_ + ln_):
                got = True
                break
        got = got or any(not cov_contains(self.cov_union, s_, e_)
                         for s_, e_ in self.segs)
        return self._rec("hopeless", now, (), got)

    # -- the allocation step ----------------------------------------------

    def next_want(self, i: int, throughputs: Sequence[float]) -> int:
        """Size replica ``i``'s next draw: MDTP's adaptive chunk size
        for one round, then (depth > 1) split across lanes so the
        pipeline in aggregate holds ~two rounds' worth while the
        endgame keeps rebalancing shrinking pieces onto whoever is
        actually fast."""
        remaining = self.fresh + self.pooled
        params = self.params
        want = next_chunk_size(i, throughputs, params, remaining)
        if want > 0 and self.depth > 1:
            want = min(max(want // ((self.depth + 1) // 2),
                           params.min_chunk),
                       want, remaining)
            want = min(want, max(remaining // (2 * self.depth),
                                 params.min_chunk))
        return self._rec("next_want", self._clock(),
                         (i, tuple(float(t) for t in throughputs)), want)

    def _draw(self, i: int, want: int):
        """Pick and claim the next sub-range for replica ``i``:
        ``(start, length, ban)`` or None when nothing it may serve is
        available right now.

        Full replicas: while live peers advertise coverage, prefer
        spans NO peer holds yet (origin offload); with no peer coverage
        this reduces to the classic packing — reclaimed pool work first
        (lowest start), then the fresh frontier's head.  Partial
        mirrors: only spans their advertisement covers."""
        cov = self.avail[i]
        if cov is None:
            if self.cov_union:
                best = None
                for k, (s_, ln_, ban_) in enumerate(self.pool):
                    if not self._ban_ok(i, s_, ln_, ban_):
                        continue
                    got = cov_first_out(self.cov_union, s_, s_ + ln_)
                    if got is not None and (best is None
                                            or got[0] < best[0]):
                        best = (got[0], got[1], k, ban_)
                if best is not None:
                    at, end_, k, ban_ = best
                    take = min(end_ - at, want)
                    self._take_pool(k, at, take)
                    return at, take, ban_
                for si, (s_, e_) in enumerate(self.segs):
                    got = cov_first_out(self.cov_union, s_, e_)
                    if got is not None:
                        at, end_ = got
                        take = min(end_ - at, want)
                        self._take_seg(si, at, take)
                        return at, take, frozenset()
                if self.origin_restricted():
                    # everything left is peer-covered and the transfer
                    # isn't in its endgame: leave it to the peers
                    return None
            pick = self._pick_pool_entry(i) if self.pool else None
            if pick is not None:
                s_, ln_, ban_ = self.pool[pick]
                take = min(ln_, want)
                self._take_pool(pick, s_, take)
                return s_, take, ban_
            if self.segs:
                s_, e_ = self.segs[0]
                take = min(want, e_ - s_)
                self._take_seg(0, s_, take)
                return s_, take, frozenset()
            return None
        best = None
        for k, (s_, ln_, ban_) in enumerate(self.pool):
            if not self._ban_ok(i, s_, ln_, ban_):
                continue
            got = cov_first_in(cov, s_, s_ + ln_)
            if got is not None and (best is None or got[0] < best[0]):
                best = (got[0], got[1], k, ban_)
        if best is not None:
            at, end_, k, ban_ = best
            take = min(end_ - at, want)
            self._take_pool(k, at, take)
            return at, take, ban_
        for si, (s_, e_) in enumerate(self.segs):
            got = cov_first_in(cov, s_, e_)
            if got is not None:
                at, end_ = got
                take = min(end_ - at, want)
                self._take_seg(si, at, take)
                return at, take, frozenset()
        return None

    def on_assign(self, i: int, want: int) -> Optional[Assignment]:
        """Claim the next sub-range for replica ``i`` and count it in
        flight.  While hedging is enabled the range is tracked in
        ``outstanding`` so ``pick_hedge`` can age it."""
        drawn = self._draw(i, want)
        if drawn is None:
            self._rec("on_assign", self._clock(), (i, want), None)
            return None
        start, length, ban = drawn
        self.inflight += length
        prog = [0, 0.0]
        if self.hedge_quantile:
            self.outstanding[start] = (length, i, ban, prog, self.stall)
        self._rec("on_assign", self._clock(), (i, want),
                  (start, length, ban))
        return Assignment(start, length, ban, prog)

    # -- range resolution --------------------------------------------------

    def _heal_settled(self, start: int) -> Optional[bytes]:
        """Hand back a winning hedge's bytes so the transport can
        restore them over whatever a losing copy wrote."""
        self.settled.discard(start)
        return self.settled_data.pop(start, None)

    def on_commit(self, i: int, start: int, length: int, ban: frozenset,
                  ndata: int) -> CommitResult:
        """Replica ``i``'s body for ``[start, start+length)`` landed
        clean (``ndata`` bytes — short means truncated, the tail
        re-pools).  If a hedge already settled the range the landing is
        pure waste and the winner's bytes heal back."""
        now = self._clock()
        self.outstanding.pop(start, None)
        if start in self.settled:
            heal = self._heal_settled(start)
            self.reqs_per[i] += 1
            self.hedge_wasted += ndata
            res = CommitResult(True, heal, None, True)
            self._rec("on_commit", now, (i, start, length, ban, ndata),
                      (True, heal, None, True))
            return res
        self.bytes_per[i] += ndata
        self.reqs_per[i] += 1
        self.done_bytes += ndata
        self.inflight -= length
        # the owner landed first: a still-running duplicate can no
        # longer win the race — cancel it now rather than let a whole
        # losing body stream to completion
        h = self.hedged.get(start)
        cancel = h[1] if h is not None else None
        wake = False
        if ndata < length:
            heapq.heappush(self.pool,
                           (start + ndata, length - ndata, ban))
            self.pooled += length - ndata
            wake = True
        elif self.inflight <= 0:
            wake = True
        res = CommitResult(False, None, cancel, wake)
        self._rec("on_commit", now, (i, start, length, ban, ndata),
                  tuple(res))
        return res

    def on_corrupt(self, i: int, start: int, length: int, ban: frozenset,
                   ndata: int) -> CorruptResult:
        """Replica ``i``'s body failed verification: the bytes never
        count — the WHOLE range re-pools tagged "not this replica" so
        the packer re-fetches from an alternate mirror."""
        now = self._clock()
        self.corrupt_per[i] += 1
        dead = self.corrupt_per[i] >= self.max_failures
        self.outstanding.pop(start, None)
        heal = None
        cancel = None
        if start in self.settled:
            heal = self._heal_settled(start)
            self.hedge_wasted += ndata
        else:
            h = self.hedged.get(start)
            cancel = h[1] if h is not None else None
            heapq.heappush(self.pool, (start, length, ban | {i}))
            self.pooled += length
            self.inflight -= length
            self.refetched += 1
        if dead:
            self.mark_failed(i)
        res = CorruptResult(dead, heal, cancel)
        self._rec("on_corrupt", now, (i, start, length, ban, ndata),
                  tuple(res))
        return res

    def on_reclaim(self, start: int, length: int, ban: frozenset, *,
                   count: bool, lost: int = 0) -> ReclaimResult:
        """Return an owed range after a connection failure.  A range a
        winning hedge already settled is NOT re-pooled (its bytes are
        done); the loser's ``lost`` partial bytes charge the hedge
        waste and its zero-copy writes heal back.  A hedge still racing
        the reclaimed range is cancelled: the endgame's shrinking draws
        mean the re-pooled range usually re-enters SPLIT — a shape the
        duplicate can no longer settle."""
        now = self._clock()
        self.outstanding.pop(start, None)
        if start in self.settled:
            heal = self._heal_settled(start)
            self.hedge_wasted += min(lost, length)
            res = ReclaimResult(True, heal, None)
        else:
            h = self.hedged.get(start)
            cancel = h[1] if h is not None else None
            heapq.heappush(self.pool, (start, length, ban))
            self.pooled += length
            self.inflight -= length
            if count:
                self.refetched += 1
            res = ReclaimResult(False, None, cancel)
        self._rec("on_reclaim", now, (start, length, ban, count, lost),
                  tuple(res))
        return res

    # -- the endgame race --------------------------------------------------

    def pick_hedge(self, j: int):
        """A straggling in-flight range worth duplicating onto idle
        replica ``j``, as ``(start, length, owner, ban)``, or None.

        A candidate must be OVERDUE: aged past what its owner should
        plausibly have needed, where "should" spans the lane queue — a
        pipelined range can wait ``depth`` service times behind healthy
        siblings.  An owner whose per-byte latency EWMA sits at or
        above the ``hedge_quantile`` of the live fleet gets the lower
        bar; a healthy-looking owner must overshoot twice that AND look
        wedged (no range completed within an expected service time —
        the gray-failure shape).  Either way replica ``j`` must
        plausibly beat continuing to wait.  All ages discount measured
        scheduler stall: on a starved host every range ages at once,
        and that is evidence against the HOST, not any owner."""
        now = self._clock()
        progs = None
        if self.trace is not None:
            progs = {s_: (p_[0], p_[1]) for s_, (_l, _o, _b, p_, _s)
                     in self.outstanding.items()}

        def done(result):
            self._rec("pick_hedge", now, (j, progs), result)
            return result

        if not self.hedge_quantile or not self.outstanding:
            return done(None)
        if self._past_endgame():
            return done(None)
        if self.lat_ewma[j] <= 0.0:
            return done(None)       # no evidence j is any faster
        # waste budget: committed waste + reserved in-flight lengths.
        # The first hedge is always affordable — on a small transfer a
        # single range can exceed the fractional budget outright, and a
        # cap that can never admit ANY hedge is no cap at all.
        budget = self.hedge_waste_frac * self.size - self.hedge_wasted \
            - sum(h[0] for h in self.hedged.values())
        first_free = not self.hedged and self.hedge_wasted <= 0.0
        samples = sorted(self.lat_ewma[k] for k in self.alive
                         if self.lat_ewma[k] > 0.0)
        slow_cut = None
        if len(samples) >= 2:
            pos = self.hedge_quantile * (len(samples) - 1)
            lo = int(pos)
            hi = min(lo + 1, len(samples) - 1)
            slow_cut = samples[lo] \
                + (samples[hi] - samples[lo]) * (pos - lo)
        my_rtt = self.rtt_min[j] if self.rtt_min[j] > 0.0 \
            else self.default_rtt
        grace = defaults.OVERDUE_GRACE_POLLS * defaults.HEDGE_POLL_S
        best = None
        for s_, (ln_, owner, ban_, prog_, st_) in \
                self.outstanding.items():
            if owner == j or s_ in self.hedged or s_ in self.settled \
                    or j in ban_ or (ln_ > budget and not first_free):
                continue
            if self.avail[j] is not None and \
                    not cov_contains(self.avail[j], s_, s_ + ln_):
                # a partial mirror may only duplicate ranges its
                # advertisement covers in full
                continue
            if 2 * prog_[0] > ln_:
                # the owner already landed most of the body: cancelling
                # it would waste more bytes than the duplicate could
                # save — let the remainder trickle in
                continue
            if prog_[1] <= 0.0:
                # the request never hit the wire (still queued on a
                # slot semaphore or the byte budget): whatever delays
                # it sits upstream of the owner
                continue
            # age from the wire-send stamp, discounting scheduler stall
            # accrued since issue
            age = (now - prog_[1]) - (self.stall - st_)
            if age <= my_rtt + ln_ * self.lat_ewma[j]:
                continue            # j would not have finished it yet
            if prog_[0] > 0:
                # the owner is visibly streaming: from its observed
                # rate ON THIS RANGE, project the remainder's landing
                # time, and duplicate only when j would finish the
                # WHOLE range well before that
                rem = (ln_ - prog_[0]) * age / prog_[0]
                if rem <= 2.0 * (my_rtt + ln_ * self.lat_ewma[j]):
                    continue
            slow = slow_cut is not None \
                and self.lat_ewma[owner] >= slow_cut
            o_rtt = self.rtt_min[owner] if self.rtt_min[owner] > 0.0 \
                else self.default_rtt
            expect_owner = o_rtt + ln_ * self.lat_ewma[owner]
            # absolute grace floor: at small-chunk scale the expected
            # times are milliseconds and scheduler jitter alone would
            # look like lateness
            overdue = (self.depth + defaults.OVERDUE_DEPTH_SLACK) \
                * expect_owner + grace
            # wedge signal for healthy-LOOKING owners: a gray mirror
            # stops completing anything, while an honestly-congested
            # one keeps finishing sibling ranges
            wedged = self.last_done[owner] <= 0.0 or \
                (now - self.last_done[owner]) \
                - (self.stall - self.last_done_stall[owner]) > \
                expect_owner + grace
            if self.lat_ewma[owner] <= 0.0 \
                    or (slow and age > overdue) \
                    or (wedged and age > 2.0 * overdue):
                # cheapest insurance first: among overdue candidates
                # duplicate the SHORTEST range — a losing copy can
                # waste at most its own length
                if best is None or ln_ < best[1]:
                    best = (s_, ln_, owner, ban_)
        return done(best)

    def on_hedge_issue(self, j: int, start: int, length: int) -> None:
        """Replica ``j``'s duplicate of ``[start, start+length)`` is
        going on the wire; its length reserves waste budget."""
        self.hedged[start] = (length, j)
        self.hedges_issued += 1
        self._rec("on_hedge_issue", self._clock(), (j, start, length),
                  None)

    def on_hedge_abandon(self, start: int, wasted: int = 0) -> None:
        """The duplicate broke mid-copy (usually the owner landing
        first and cancelling the race): whatever it DID land is real
        duplicated traffic and charges the waste meter."""
        h = self.hedged.pop(start, None)
        if h is not None and wasted > 0:
            self.hedge_wasted += min(wasted, h[0])
        self._rec("on_hedge_abandon", self._clock(), (start, wasted),
                  None)

    def on_hedge_corrupt(self, j: int, start: int) -> bool:
        """The duplicate body failed verification: the range is not
        ours to re-pool — discard the copy, but the corruption still
        counts against ``j``.  Returns True when ``j`` crossed the
        corruption cap."""
        now = self._clock()
        self.hedged.pop(start, None)
        self.corrupt_per[j] += 1
        dead = self.corrupt_per[j] >= self.max_failures
        if dead:
            self.mark_failed(j)
        return self._rec("on_hedge_corrupt", now, (j, start), dead)

    def on_hedge_result(self, j: int, start: int, length: int,
                        ndata: int, body=None) -> HedgeResult:
        """The duplicate body landed clean.  It wins only if the live
        claim is still the EXACT range it duplicated: after a reclaim
        the range can re-enter the pool and be re-drawn SPLIT, and
        crediting the full hedge body against that narrower claim would
        double-count the remainder.  A win settles the range (keeping
        ``body`` so a late losing landing heals back) and cancels the
        current owner."""
        now = self._clock()
        self.hedged.pop(start, None)
        entry = self.outstanding.get(start)
        if ndata < length or start in self.settled \
                or entry is None or entry[0] != length:
            # truncated, re-split, or the owner resolved it first: the
            # duplicated body is pure waste
            self.hedge_wasted += ndata
            res = HedgeResult(False, None)
        else:
            loser = entry[1]
            self.settled.add(start)
            self.settled_data[start] = bytes(body) \
                if body is not None else b""
            self.bytes_per[j] += ndata
            self.reqs_per[j] += 1
            self.done_bytes += ndata
            self.inflight -= length
            self.hedges_won += 1
            res = HedgeResult(True, loser)
        self._rec("on_hedge_result", now,
                  (j, start, length, ndata,
                   bytes(body) if body is not None else None),
                  tuple(res))
        return res


def replay(events: list, factory) -> list:
    """Re-drive a recorded decision trace through a fresh scheduler.

    ``events`` is the ``trace`` list a recording scheduler filled;
    ``factory(clock)`` must build a scheduler configured like the
    recording one (same size/params/mirrors/…), with ``trace=None`` and
    the given clock.  Every recorded event is replayed at its recorded
    timestamp and its result compared; the return value lists the
    mismatches (empty = decision parity).
    """
    box = [0.0]
    sched = None
    mismatches: list = []
    for name, now, args, expected in events:
        box[0] = now
        if name == "init":
            sched = (factory(lambda: box[0])
                     if sched is None else sched)
            continue
        if sched is None:
            sched = factory(lambda: box[0])
        if name == "pick_hedge":
            j, progs = args
            # progress lists mutate outside the event stream (the
            # transport's body reads update them in place); the trace
            # carries a snapshot to re-apply
            for s_, (p0, p1) in (progs or {}).items():
                ent = sched.outstanding.get(s_)
                if ent is not None:
                    ent[3][0] = p0
                    ent[3][1] = p1
            got = sched.pick_hedge(j)
        elif name == "on_reclaim":
            start, length, ban, count, lost = args
            got = sched.on_reclaim(start, length, ban,
                                   count=count, lost=lost)
        else:
            got = getattr(sched, name)(*args)
        if isinstance(got, Assignment):
            got = (got.start, got.length, got.ban)
        elif isinstance(got, tuple) and type(got) is not tuple:
            got = tuple(got)
        if got != expected:
            mismatches.append(
                f"{name}{tuple(args)!r}: got {got!r}, "
                f"want {expected!r}")
    return mismatches
