"""Single source of truth for the transfer stack's tuning constants.

Before the sans-I/O extraction these thresholds were duplicated (and
drifting) between ``repro.transfer.client`` and
``repro.transfer.manager``: the endgame window showed up as a literal
``2`` in both the hedge trigger and the origin-offload pass, the
overdue bar's grace terms were copy-pasted, and the probation floor
family lived only in ``FleetModel``'s signature.  Every layer now reads
the one value defined here; ``tests/test_sched.py`` pins the wiring so
a future edit cannot re-fork them.

These are *defaults*, not policy: callers override per-instance via
``ClientOptions`` / ``FleetModel`` / ``ChunkScheduler`` arguments.
"""

from __future__ import annotations

# -- pipelining / data plane ---------------------------------------------

#: concurrent request lanes per replica connection (HTTP/1.1 pipelining).
PIPELINE_DEPTH = 2

#: CRC32 bodies at or below this size hash inline on the event loop;
#: larger bodies go to the thread-pool executor.
CRC_INLINE_MAX = 128 * 1024

#: RTT assumed for a replica with no sample yet (seconds).
DEFAULT_RTT = 0.03

#: per-replica observation-window flush threshold (seconds of streaming
#: time aggregated before one estimator reading).
OBS_WINDOW_S = 0.02

# -- endgame / hedging ---------------------------------------------------

#: the endgame window, in allocator rounds: the transfer is "in its
#: endgame" once the residual (fresh + pooled + in-flight) drops below
#: ``ENDGAME_ROUNDS * large_chunk * len(alive)``.  Shared by the hedge
#: trigger (no hedges before the endgame) and the origin-offload pass
#: (the origin rejoins peer-covered spans inside it).
ENDGAME_ROUNDS = 2

#: hedge poll period (seconds): parked lanes wake this often to look
#: for straggling ranges, and the stall clock heartbeats at this rate.
HEDGE_POLL_S = 0.05

#: the overdue bar starts at ``(pipeline_depth + OVERDUE_DEPTH_SLACK)``
#: expected service times — a pipelined range can wait ``depth`` service
#: times behind healthy siblings.
OVERDUE_DEPTH_SLACK = 1.0

#: absolute grace floor on the overdue bar and the wedge window, in
#: hedge-poll periods: at small-chunk scale expected times are
#: milliseconds and scheduler jitter alone would read as lateness.
OVERDUE_GRACE_POLLS = 4.0

#: per-byte latency quantile across the live fleet above which an owner
#: counts as slow (the manager's default; bare clients default to 0 =
#: hedging off).
HEDGE_QUANTILE = 0.95

#: speculative duplicate budget as a fraction of the transfer size.
HEDGE_WASTE_FRAC = 0.05

# -- fleet probation (FleetModel) ----------------------------------------

#: health at or below this trips probation review.
PROBATION_HEALTH = 0.3

#: connection-retry count that counts as a probation strike.
PROBATION_RETRY_LIMIT = 3

#: a replica observed below this fraction of its fair share is "slow".
PROBATION_SLOW_FRAC = 0.125

#: consecutive slow/faulty observations before probation trips.
PROBATION_STRIKES = 3

#: clean probes required before a probated replica is readmitted.
PROBATION_CLEAN_STREAK = 3

#: allocation share floor while on probation — probated replicas keep
#: receiving a trickle so recovery is observable (interplays with the
#: hedged endgame: the trickle is what a hedge can duplicate around).
PROBATION_FLOOR = 0.02

#: readmission slow-start: trust multiplier right after probation lifts.
READMIT_INIT = 0.1
