"""Real asyncio transfer runtime: MDTP client + range-serving HTTP server
plus the fleet-level multi-transfer scheduler, end-to-end integrity
(per-range CRC32 verification), crash-resume journaling, a
fault-injecting chaos harness, peer-assisted broadcast (restoring nodes
re-serve what they have via :class:`PeerMirror`), and sharded
work-stealing restore planning (:mod:`repro.transfer.shard`).

Exports resolve lazily (PEP 562) so the sans-I/O scheduling core
(``repro.transfer.sched``) stays importable without dragging in the
event loop, sockets, or JAX — the layering contract
``tools/layercheck.py`` enforces.
"""

from importlib import import_module

#: export name -> defining submodule (resolved on first attribute access)
_EXPORTS = {
    "MDTPClient": ".client", "ClientOptions": ".client",
    "Replica": ".client", "TransferReport": ".client",
    "TransferIncompleteError": ".client", "fetch_blob": ".client",
    "ResumeJournal": ".journal", "claim_interval": ".journal",
    "merge_intervals": ".journal", "uncovered_intervals": ".journal",
    "FleetModel": ".manager", "TransferJob": ".manager",
    "TransferManager": ".manager",
    "RangeServer": ".server", "Throttle": ".server",
    "FaultPolicy": ".server",
    "PeerMirror": ".mirror",
    "Sink": ".sink", "BufferSink": ".sink", "CallableSink": ".sink",
    "ChunkScheduler": ".sched",
    "ShardPlan": ".shard", "StealLedger": ".shard",
    "plan_shards": ".shard", "plan_for_mesh": ".shard",
    "fetch_sharded": ".shard",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    value = getattr(import_module(target, __name__), name)
    globals()[name] = value          # cache: __getattr__ runs once per name
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
