"""Real asyncio transfer runtime: MDTP client + range-serving HTTP server
plus the fleet-level multi-transfer scheduler."""

from .client import MDTPClient, Replica, TransferReport, fetch_blob
from .manager import FleetModel, TransferJob, TransferManager
from .server import RangeServer, Throttle

__all__ = ["MDTPClient", "Replica", "TransferReport", "fetch_blob",
           "FleetModel", "TransferJob", "TransferManager",
           "RangeServer", "Throttle"]
