"""Real asyncio transfer runtime: MDTP client + range-serving HTTP server
plus the fleet-level multi-transfer scheduler, end-to-end integrity
(per-range CRC32 verification), crash-resume journaling, and a
fault-injecting chaos harness."""

from .client import (MDTPClient, Replica, TransferIncompleteError,
                     TransferReport, fetch_blob)
from .journal import ResumeJournal
from .manager import FleetModel, TransferJob, TransferManager
from .server import FaultPolicy, RangeServer, Throttle

__all__ = ["MDTPClient", "Replica", "TransferReport",
           "TransferIncompleteError", "fetch_blob", "ResumeJournal",
           "FleetModel", "TransferJob", "TransferManager",
           "RangeServer", "Throttle", "FaultPolicy"]
