"""Real asyncio transfer runtime: MDTP client + range-serving HTTP server
plus the fleet-level multi-transfer scheduler, end-to-end integrity
(per-range CRC32 verification), crash-resume journaling, a
fault-injecting chaos harness, and peer-assisted broadcast (restoring
nodes re-serve what they have via :class:`PeerMirror`)."""

from .client import (ClientOptions, MDTPClient, Replica,
                     TransferIncompleteError, TransferReport, fetch_blob)
from .journal import (ResumeJournal, claim_interval, merge_intervals,
                      uncovered_intervals)
from .manager import FleetModel, TransferJob, TransferManager
from .mirror import PeerMirror
from .server import FaultPolicy, RangeServer, Throttle
from .sink import BufferSink, CallableSink, Sink

__all__ = ["MDTPClient", "ClientOptions", "Replica", "TransferReport",
           "TransferIncompleteError", "fetch_blob", "ResumeJournal",
           "claim_interval", "merge_intervals", "uncovered_intervals",
           "FleetModel", "TransferJob", "TransferManager",
           "RangeServer", "Throttle", "FaultPolicy",
           "PeerMirror", "Sink", "BufferSink", "CallableSink"]
