"""Real asyncio transfer runtime: MDTP client + range-serving HTTP server."""

from .client import MDTPClient, Replica, TransferReport, fetch_blob
from .server import RangeServer, Throttle

__all__ = ["MDTPClient", "Replica", "TransferReport", "fetch_blob",
           "RangeServer", "Throttle"]
