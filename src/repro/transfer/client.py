"""Asyncio multi-source transfer client (the real MDTP runtime).

No aiohttp in this environment — this is a raw-socket HTTP/1.1 client on
``asyncio`` streams with:

* one persistent connection per replica (paper §III-A: avoid TCP slow-start
  and session re-establishment),
* byte-range requests sized by the SAME allocator the simulator uses
  (``repro.core.chunking`` — single source of truth),
* per-chunk throughput observation feeding the next allocation,
* failure handling: a replica that errors mid-chunk is retired (or retried
  after ``retry_after``) and its unfinished range is re-queued — the
  checkpoint-restore path's fault tolerance.

The client is transport-generic: anything exposing ``fetch_range`` works
(tests use the in-process ``RangeServer``; production would point at real
mirrors).
"""

from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.chunking import ChunkParams, default_chunk_params, next_chunk_size
from repro.core.throughput import make_estimator

__all__ = ["Replica", "TransferReport", "MDTPClient", "fetch_blob"]


@dataclass(frozen=True)
class Replica:
    host: str
    port: int
    path: str              # HTTP path of the blob on this mirror

    @property
    def name(self) -> str:
        return f"{self.host}:{self.port}"


@dataclass
class TransferReport:
    total_bytes: int
    elapsed: float
    bytes_per_replica: dict
    requests_per_replica: dict
    failed_replicas: list
    refetched_ranges: int
    #: final per-replica estimator values (bytes/s; 0 = never observed) —
    #: the live inputs the autotuner re-tunes chunk sizes from.
    observed_throughputs: dict = field(default_factory=dict)

    @property
    def throughput(self) -> float:
        return self.total_bytes / self.elapsed if self.elapsed > 0 else 0.0


class _Conn:
    """One persistent HTTP/1.1 connection."""

    def __init__(self, replica: Replica):
        self.replica = replica
        self.reader: Optional[asyncio.StreamReader] = None
        self.writer: Optional[asyncio.StreamWriter] = None

    async def connect(self):
        self.reader, self.writer = await asyncio.open_connection(
            self.replica.host, self.replica.port)

    async def close(self):
        if self.writer is not None:
            self.writer.close()
            try:
                await self.writer.wait_closed()
            except Exception:
                pass

    async def fetch_range(self, start: int, end: int) -> bytes:
        """GET bytes [start, end] inclusive over the persistent session."""
        if self.writer is None:
            await self.connect()
        req = (f"GET {self.replica.path} HTTP/1.1\r\n"
               f"Host: {self.replica.host}\r\n"
               f"Range: bytes={start}-{end}\r\n"
               f"Connection: keep-alive\r\n\r\n")
        self.writer.write(req.encode())
        await self.writer.drain()
        # status line + headers
        status = await self.reader.readline()
        if not status:
            raise ConnectionError("connection closed")
        code = int(status.split()[1])
        headers = {}
        while True:
            line = await self.reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode().partition(":")
            headers[k.strip().lower()] = v.strip()
        if code not in (200, 206):
            raise ConnectionError(f"HTTP {code}")
        n = int(headers["content-length"])
        body = await self.reader.readexactly(n)
        return body


class MDTPClient:
    """Downloads one blob from N replicas with MDTP adaptive chunking."""

    def __init__(
        self,
        replicas: Sequence[Replica],
        params: Optional[ChunkParams] = None,
        estimator: str = "ewma",
        ewma_alpha: float = 0.5,
        retry_after: float = 0.0,
        max_failures: int = 3,
    ):
        self.replicas = list(replicas)
        self._params_arg = params
        self._estimator = estimator
        self._alpha = ewma_alpha
        self.retry_after = retry_after
        self.max_failures = max_failures
        #: report of the most recent ``fetch`` (None before the first one).
        self.last_report: Optional[TransferReport] = None

    def retune(self, file_size: int, **autotune_kw):
        """Re-tune chunk sizes from the last transfer's live throughputs.

        Runs the fused on-device grid sweep (``repro.core.autotune`` — one
        compiled call for the whole (C, L) × seed lattice) against the
        per-replica throughputs observed during the previous ``fetch`` and
        adopts the winning ``ChunkParams`` for subsequent transfers.
        Typical use: between checkpoint-restore waves, where mirror
        conditions drift but the replica set is stable.

        Returns the ``AutotuneResult``; raises if no transfer has been
        observed yet or no replica produced a throughput sample.
        """
        from repro.core.autotune import autotune_chunk_params

        if self.last_report is None:
            raise RuntimeError("retune() needs a completed fetch() first")
        # Replicas with no sample (failed / never dispatched) are excluded,
        # mirroring how fetch() retires them — a 0-throughput entry would
        # otherwise dominate every simulated grid point.
        bw = [b for r in self.replicas
              if (b := self.last_report.observed_throughputs.get(r.name, 0.0))
              > 0.0]
        if not bw:
            raise RuntimeError("no throughput observations to retune from")
        autotune_kw.setdefault("rtt", 0.03)
        res = autotune_chunk_params(bw, file_size=int(file_size),
                                    **autotune_kw)
        self._params_arg = res.params
        return res

    def _make_conn(self, replica: Replica) -> "_Conn":
        """Connection factory — subclasses may translate offsets (the data
        pipeline's virtual-blob client)."""
        return _Conn(replica)

    async def fetch(self, size: int, sink=None) -> tuple[bytearray, TransferReport]:
        """Fetch ``size`` bytes.  ``sink(start, data)`` (if given) receives
        chunks as they land (streaming to disk); otherwise an in-memory
        buffer is assembled."""
        params = self._params_arg or default_chunk_params(size)
        n = len(self.replicas)
        est = [make_estimator(self._estimator, self._alpha) for _ in range(n)]
        buf = bytearray(size) if sink is None else None

        cursor = 0
        pool: list[tuple[int, int]] = []         # reclaimed (start, len)
        bytes_per = {r.name: 0 for r in self.replicas}
        reqs_per = {r.name: 0 for r in self.replicas}
        failed: list[str] = []
        refetched = 0
        lock = asyncio.Lock()
        done_bytes = 0
        t0 = time.monotonic()

        async def allocate(nbytes: int) -> tuple[int, int]:
            nonlocal cursor
            async with lock:
                if pool:
                    s, ln = pool.pop(0)
                    take = min(ln, nbytes)
                    if take < ln:
                        pool.insert(0, (s + take, ln - take))
                    return s, take
                take = min(nbytes, size - cursor)
                s = cursor
                cursor += take
                return s, take

        async def worker(i: int):
            nonlocal done_bytes, refetched
            conn = self._make_conn(self.replicas[i])
            failures = 0
            while True:
                async with lock:
                    remaining = (size - cursor) + sum(l for _, l in pool)
                if remaining <= 0:
                    break
                want = next_chunk_size(i, [e.value for e in est], params,
                                       remaining)
                if want <= 0:
                    break
                start, length = await allocate(want)
                if length == 0:
                    await asyncio.sleep(0)
                    continue
                t_req = time.monotonic()
                try:
                    data = await conn.fetch_range(start, start + length - 1)
                except (ConnectionError, OSError, asyncio.IncompleteReadError):
                    async with lock:
                        pool.append((start, length))
                        pool.sort()
                        refetched += 1
                    failures += 1
                    await conn.close()
                    conn = self._make_conn(self.replicas[i])
                    if failures >= self.max_failures:
                        failed.append(self.replicas[i].name)
                        break
                    if self.retry_after > 0:
                        await asyncio.sleep(self.retry_after)
                    continue
                elapsed = time.monotonic() - t_req
                est[i].observe(len(data), elapsed)
                if sink is None:
                    buf[start:start + len(data)] = data
                else:
                    sink(start, data)
                async with lock:
                    bytes_per[self.replicas[i].name] += len(data)
                    reqs_per[self.replicas[i].name] += 1
                    done_bytes += len(data)
                if len(data) < length:   # truncated: server sent short range
                    async with lock:
                        pool.append((start + len(data), length - len(data)))
                        pool.sort()
            await conn.close()

        await asyncio.gather(*(worker(i) for i in range(len(self.replicas))))
        if done_bytes != size:
            raise IOError(
                f"transfer incomplete: {done_bytes}/{size} bytes "
                f"(failed replicas: {failed})")
        report = TransferReport(
            total_bytes=size, elapsed=time.monotonic() - t0,
            bytes_per_replica=bytes_per, requests_per_replica=reqs_per,
            failed_replicas=failed, refetched_ranges=refetched,
            observed_throughputs={
                r.name: float(est[i].value)
                for i, r in enumerate(self.replicas)
            },
        )
        self.last_report = report
        return buf, report

    async def blob_size(self) -> int:
        """HEAD the first healthy replica for the blob size."""
        for r in self.replicas:
            conn = _Conn(r)
            try:
                await conn.connect()
                req = (f"HEAD {r.path} HTTP/1.1\r\nHost: {r.host}\r\n"
                       f"Connection: keep-alive\r\n\r\n")
                conn.writer.write(req.encode())
                await conn.writer.drain()
                status = await conn.reader.readline()
                code = int(status.split()[1])
                headers = {}
                while True:
                    line = await conn.reader.readline()
                    if line in (b"\r\n", b"\n", b""):
                        break
                    k, _, v = line.decode().partition(":")
                    headers[k.strip().lower()] = v.strip()
                if code == 200:
                    return int(headers["content-length"])
            except (OSError, ValueError):
                continue
            finally:
                await conn.close()
        raise IOError("no replica answered HEAD")


def fetch_blob(replicas: Sequence[Replica], size: Optional[int] = None,
               **kw) -> tuple[bytes, TransferReport]:
    """Synchronous convenience wrapper."""
    client = MDTPClient(replicas, **kw)

    async def run():
        nonlocal size
        if size is None:
            size = await client.blob_size()
        return await client.fetch(size)

    buf, report = asyncio.run(run())
    return bytes(buf), report
