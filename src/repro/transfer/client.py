"""Asyncio multi-source transfer client (the real MDTP runtime).

No aiohttp in this environment — this is a raw-socket HTTP/1.1 client on
asyncio's ``loop.sock_*`` primitives with:

* one persistent connection per replica (paper §III-A: avoid TCP slow-start
  and session re-establishment),
* **depth-k request pipelining** per connection: the next Range request is
  issued while the previous body is still streaming, so steady-state
  chunks do not pay a request RTT each (the CDTP-style overlap of request
  issue with in-flight body streaming — see PAPERS.md),
* a **zero-copy receive path**: the destination ``bytearray`` is
  preallocated and bodies are ``sock_recv_into`` memoryview slices of it —
  no per-chunk ``bytes`` materialization and no assembly copy,
* byte-range requests sized by the SAME allocator the simulator uses
  (``repro.core.chunking`` — single source of truth),
* per-chunk throughput observation feeding the next allocation (RTT bias
  removed at the observation point — see :func:`wire_elapsed`),
* **end-to-end integrity**: every range's CRC32 (the server's
  ``X-Range-Checksum`` header) is verified off the event loop as bodies
  land; a mismatching range is atomically re-pooled tagged "not this
  replica" so it re-fetches from an alternate mirror, and a chronically
  corrupt replica is retired like a dead one,
* **crash-resume**: ``fetch(resume=journal)`` replays an append-only
  :class:`~repro.transfer.journal.ResumeJournal`, re-verifies journaled
  range checksums against the destination, and requests only the
  uncovered intervals,
* failure handling: a replica that errors mid-chunk — or stalls past the
  per-read inactivity timeout — is retired (or retried with capped
  exponential backoff after ``retry_after``) and every range it still
  owes, including all pipelined in-flight requests, is atomically
  re-pooled for surviving peers (the checkpoint-restore path's fault
  tolerance).

Sink contract
-------------
``fetch(size, sink=...)`` accepts either:

* a callable ``sink(start, view)`` — ``view`` is a ``memoryview`` that is
  only valid DURING the call (the backing buffer is per-chunk scratch);
  a sink that wants to keep the bytes must copy before returning, or
* an object with ``writable(start, length) -> memoryview`` and
  ``commit(start, nbytes)`` — the client reads the socket directly into
  the returned view and calls ``commit`` once the bytes landed, so the
  path from socket to the sink's buffer is copy-free
  (``repro.checkpoint.manager._StreamingRestore`` implements this).

The client is transport-generic: anything exposing ``fetch_range`` works
(tests use the in-process ``RangeServer``; production would point at real
mirrors).
"""

from __future__ import annotations

import asyncio
import bisect
import contextlib
import heapq
import random
import socket
import time
import zlib
from dataclasses import dataclass, field, replace as _dc_replace
from typing import NamedTuple, Optional, Sequence

from repro.core.chunking import ChunkParams, default_chunk_params, next_chunk_size
from repro.core.throughput import make_estimator, rtt_corrected_bandwidth
from repro.transfer.journal import merge_intervals, uncovered_intervals

__all__ = ["Replica", "ClientOptions", "TransferReport", "MDTPClient",
           "NoTelemetryError", "TransferIncompleteError", "fetch_blob",
           "wire_elapsed", "DEFAULT_PIPELINE_DEPTH"]

#: default per-connection request pipeline depth.  2 keeps a request on
#: the wire while the previous body streams (the RTT-hiding that matters)
#: at minimal client-side concurrency — important because lane tasks
#: share one event loop and a loaded host inflates their scheduling
#: delays, which distorts throughput observations.  High-RTT paths gain
#: another ~10-20% from depth 4 (see benchmarks/dataplane_bench.py);
#: tune per deployment via ``MDTPClient(pipeline_depth=...)``.
DEFAULT_PIPELINE_DEPTH = 2

#: bodies at or below this size are CRC'd inline on the event loop (the
#: executor round-trip costs more than the hash); larger bodies hash in
#: the thread pool — zlib releases the GIL, so verification overlaps the
#: next body's socket reads instead of stalling them.
_CRC_INLINE_MAX = 128 * 1024

#: endgame re-poll cadence (s) for lanes parked with hedging enabled: a
#: grayed-out mirror produces NO events to wake a parked lane (that is
#: the failure mode hedging exists for), so idle endgame lanes re-check
#: for straggling in-flight ranges on this period instead of waiting on
#: a notification that will never come.
_HEDGE_POLL_S = 0.05


class NoTelemetryError(RuntimeError):
    """``retune()`` had no usable observations to re-plan from (no
    completed fetch yet, or every replica failed/went unobserved).

    A dedicated type so callers that tolerate missing telemetry (the
    checkpoint-restore wave loop) don't have to catch blanket
    ``RuntimeError`` — which would also swallow real failures like
    jax's ``XlaRuntimeError`` from the fused sweep itself.
    """


class TransferIncompleteError(IOError):
    """``fetch()`` could not deliver every byte (all replicas failed or
    were retired for corruption before the pool drained).

    A dedicated type — previously this surfaced as a bare ``IOError``,
    and before that a short buffer could silently escape — so callers
    can distinguish "the transfer is incomplete, retry/resume it" from
    unrelated I/O failures.  Subclasses ``IOError`` for compatibility.
    """

    def __init__(self, message: str, *, done_bytes: int = 0,
                 expected_bytes: int = 0,
                 failed_replicas: Sequence[str] = ()):
        super().__init__(message)
        self.done_bytes = done_bytes
        self.expected_bytes = expected_bytes
        self.failed_replicas = list(failed_replicas)


@dataclass(frozen=True)
class Replica:
    host: str
    port: int
    path: str              # HTTP path of the blob on this mirror
    #: True = a PARTIAL peer mirror (a restoring node serving what it has
    #: so far): the client queries its ``X-Available-Ranges`` coverage,
    #: keeps refreshing it in the background, and only packs chunks the
    #: peer actually holds.  False (default) = an ordinary full mirror.
    mirror: bool = False

    @property
    def name(self) -> str:
        return f"{self.host}:{self.port}"


@dataclass(frozen=True)
class ClientOptions:
    """Consolidated :class:`MDTPClient` configuration.

    What used to be 15 bare constructor kwargs, grouped by concern.  The
    bare kwargs still work (``MDTPClient(reps, pipeline_depth=3)`` —
    they are folded into an options instance, overriding it field by
    field), so existing call sites don't change; new code should prefer
    ``MDTPClient(reps, options=ClientOptions(...))``.
    """

    # -- allocation & estimation ------------------------------------------
    #: chunk geometry; None = size-derived defaults per fetch.
    params: Optional[ChunkParams] = None
    #: throughput estimator kind (``repro.core.throughput``).
    estimator: str = "ewma"
    ewma_alpha: float = 0.5
    #: default online tuner (``repro.core.online`` contract: an object
    #: with ``update(telemetry) -> ChunkParams | None``) applied to every
    #: ``fetch`` unless overridden per call.
    tuner: object = None

    # -- pipeline / zero-copy data plane ----------------------------------
    #: concurrent pipelined requests per replica connection (>= 1;
    #: 1 = the serial request-response data plane).
    pipeline_depth: int = DEFAULT_PIPELINE_DEPTH
    #: False = legacy copy path (bodies materialize as ``bytes`` and are
    #: copied into place) — kept as the benchmark baseline and an escape
    #: hatch; the default receives into the destination buffer.
    zero_copy: bool = True
    #: emulated request-path delay per request (see ``_Conn``).
    request_latency: float = 0.0

    # -- integrity / retry / timeout --------------------------------------
    #: verify each range's CRC32 against the server's
    #: ``X-Range-Checksum`` header and re-fetch mismatches from an
    #: alternate mirror.  Servers that don't send the header are simply
    #: not verified (no error).
    verify_integrity: bool = True
    #: seconds before retrying a failed replica (0 = retire immediately).
    retry_after: float = 0.0
    #: connection/corruption failures before a replica is retired.
    max_failures: int = 3
    #: per-read inactivity timeout (seconds; 0 disables) applied to every
    #: connection — see ``_Conn.read_timeout``.
    read_timeout: float = 30.0
    #: ceiling (seconds) on the exponential dead-replica retry backoff:
    #: attempt k waits ``min(retry_after * 2**(k-1), cap)`` scaled by
    #: ±50% jitter so reconnect storms decorrelate.
    retry_backoff_cap: float = 5.0

    # -- endgame hedging ---------------------------------------------------
    #: straggler quantile for speculative endgame duplicates (0 disables;
    #: see the ``MDTPClient`` docs for the full trigger conditions).
    hedge_quantile: float = 0.0
    #: hard cap on hedge waste as a fraction of the transfer size.
    hedge_waste_frac: float = 0.05

    # -- peer mirrors ------------------------------------------------------
    #: background coverage-refresh cadence (seconds) for partial peer
    #: replicas (``Replica.mirror``): how often each peer's
    #: ``X-Available-Ranges`` is re-queried during a fetch.
    coverage_refresh_s: float = 0.05

    # -- misc --------------------------------------------------------------
    #: randomness source for reconnect-backoff jitter — pass a seeded
    #: ``random.Random`` to make chaos-test retry timing reproducible;
    #: None = the module-global generator.
    rng: Optional[random.Random] = None


# -- coverage-interval helpers (sorted disjoint [s, e) lists) -------------

def _cov_run_at(cov: list, p: int) -> int:
    """Index of the covered run containing point ``p``, else -1."""
    k = bisect.bisect_right(cov, (p, 1 << 62)) - 1
    if k >= 0 and cov[k][1] > p:
        return k
    return -1


def _cov_contains(cov: list, lo: int, hi: int) -> bool:
    """``[lo, hi)`` entirely inside one covered run?  (Empty spans are
    trivially covered.)"""
    if hi <= lo:
        return True
    k = _cov_run_at(cov, lo)
    return k >= 0 and cov[k][1] >= hi


def _cov_first_in(cov: list, lo: int, hi: int):
    """First covered sub-span of ``[lo, hi)`` as ``(start, end)``, or
    None when the window touches no coverage."""
    if hi <= lo:
        return None
    k = _cov_run_at(cov, lo)
    if k >= 0:
        return lo, min(cov[k][1], hi)
    k = bisect.bisect_right(cov, (lo, 1 << 62))
    if k < len(cov) and cov[k][0] < hi:
        return cov[k][0], min(cov[k][1], hi)
    return None


def _cov_first_out(cov: list, lo: int, hi: int):
    """First UNcovered sub-span of ``[lo, hi)`` as ``(start, end)``, or
    None when the window is fully covered."""
    if hi <= lo:
        return None
    pos = lo
    k = _cov_run_at(cov, lo)
    if k >= 0:
        pos = cov[k][1]
        if pos >= hi:
            return None
    k = bisect.bisect_right(cov, (pos, 1 << 62))
    end = cov[k][0] if k < len(cov) and cov[k][0] < hi else hi
    return pos, end


def _parse_ranges_header(raw: str) -> list:
    """``X-Available-Ranges`` value -> list of inclusive ``(lo, hi)``
    pairs (empty list for an empty advertisement)."""
    out = []
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        lo_s, _, hi_s = part.partition("-")
        out.append((int(lo_s), int(hi_s)))
    return out


@dataclass
class TransferReport:
    total_bytes: int
    elapsed: float
    bytes_per_replica: dict
    requests_per_replica: dict
    failed_replicas: list
    refetched_ranges: int
    #: number of mid-transfer tuner adoptions (``fetch(tuner=...)``) — 0
    #: for un-tuned transfers.
    retunes: int = 0
    #: final per-replica estimator values (bytes/s; 0 = never observed) —
    #: the live inputs the autotuner re-tunes chunk sizes from.  These are
    #: WIRE rates: the per-request RTT bias is already removed at the
    #: observation point (:func:`wire_elapsed`), so consumers must not
    #: apply ``rtt_corrected_bandwidth`` again.
    observed_throughputs: dict = field(default_factory=dict)
    #: measured per-replica request RTT in seconds (min over connect time
    #: and idle-pipe header turnarounds; 0 = never measured).  Feeds
    #: ``retune`` so the simulated sweep uses live latencies, not a
    #: guessed constant.
    observed_rtts: dict = field(default_factory=dict)
    #: per-replica count of connection-level retries (reconnect after a
    #: break/stall, with capped exponential backoff between attempts).
    retries_per_replica: dict = field(default_factory=dict)
    #: per-replica count of ranges that failed checksum verification and
    #: were re-fetched from an alternate mirror.
    corrupt_ranges: dict = field(default_factory=dict)
    #: bytes satisfied from the resume journal instead of the wire
    #: (``fetch(resume=...)``); 0 for fresh transfers.
    resumed_bytes: int = 0
    #: seconds spent re-verifying journaled range checksums during resume
    #: replay (large records hash in the executor); 0.0 for fresh fetches.
    resume_verify_seconds: float = 0.0
    #: endgame hedges (``hedge_quantile`` > 0): speculative duplicate
    #: fetches issued for straggling in-flight ranges, and how many beat
    #: their original copy to completion.
    hedges_issued: int = 0
    hedges_won: int = 0
    #: duplicated bytes the losing copies cost.  Cancellation is
    #: symmetric — whichever side lands first breaks the other's
    #: connection — so each losing copy is charged the bytes it actually
    #: received before the race resolved, not its whole range.
    hedge_wasted_bytes: int = 0

    @property
    def throughput(self) -> float:
        return self.total_bytes / self.elapsed if self.elapsed > 0 else 0.0


def wire_elapsed(nbytes: int, elapsed: float, rtt: float) -> float:
    """Strip the request RTT from a serial chunk observation.

    A request issued on an idle pipe spans ``rtt + nbytes / wire_rate``
    seconds, so feeding ``(nbytes, elapsed)`` straight into an estimator
    under-states the wire rate — badly for small chunks on high-RTT paths.
    A *pipelined* request's elapsed starts when its body starts streaming
    and needs no correction; this helper is applied only to observations
    flagged as RTT-inclusive.  Delegates the guard logic (no RTT sample,
    implied non-positive wire time) to
    :func:`repro.core.throughput.rtt_corrected_bandwidth`, returning the
    elapsed unchanged when the correction is impossible.
    """
    if elapsed <= 0.0 or nbytes <= 0:
        return elapsed
    corrected = rtt_corrected_bandwidth(nbytes / elapsed, rtt, float(nbytes))
    return nbytes / corrected if corrected > 0.0 else elapsed


async def _crc32_async(data) -> int:
    """CRC32 of a body, off the event loop for large bodies.

    ``zlib.crc32`` accepts any buffer and releases the GIL, so hashing a
    multi-megabyte range in the default executor runs concurrently with
    the loop's socket reads; small bodies aren't worth the thread hop.
    """
    if len(data) <= _CRC_INLINE_MAX:
        return zlib.crc32(data)
    return await asyncio.get_running_loop().run_in_executor(
        None, zlib.crc32, data)


class _RangeReply(NamedTuple):
    """One completed range request, with the timing metadata the
    observation layer needs to de-bias throughput samples."""

    #: the body: ``memoryview`` of the caller's buffer when ``into`` was
    #: given, freshly-read ``bytes`` otherwise.
    data: object
    #: body length actually served (may be < requested on a clamped tail).
    nbytes: int
    #: seconds attributable to receiving THIS body.
    elapsed: float
    #: True when ``elapsed`` spans the full request round-trip (the pipe
    #: was idle at issue time) — the estimator must strip the RTT.
    rtt_included: bool
    #: server-declared CRC32 of the range (``X-Range-Checksum`` header),
    #: None when the server doesn't checksum.
    crc32: Optional[int] = None


class _Conn:
    """One persistent pipelined HTTP/1.1 connection on a raw socket.

    Requests may be issued concurrently by several tasks; writes are
    serialized by a lock and responses are read strictly in request order
    via a FIFO turnstile (each request waits on its predecessor's
    completion event).  Bodies are received with ``sock_recv_into``
    directly into the caller's buffer — the only copied bytes are the
    header-phase read-ahead (bounded by ``_HEADER_RECV`` per response).

    Collects per-connection RTT samples: the TCP connect time on session
    establishment, then the request-write → status-line turnaround of
    every request issued on an idle pipe (a queued-behind-a-body
    turnaround measures the predecessor's streaming time, not the path).
    Consumers drain ``take_rtt_samples()`` and min-aggregate.

    Any failure (transport error, malformed response, a read stalled past
    ``read_timeout``, cancellation mid-read) marks the connection
    ``broken``: the stream position is unrecoverable, so every queued
    request fails fast instead of parsing from the middle of a
    predecessor's body.
    """

    #: recv size while parsing status/headers — small so read-ahead into
    #: the copied header buffer steals at most this many body bytes from
    #: the zero-copy path per response.
    _HEADER_RECV = 4096

    def __init__(self, replica: Replica, request_latency: float = 0.0,
                 read_timeout: float = 0.0):
        self.replica = replica
        #: emulated request-path propagation delay (seconds) — a test and
        #: benchmark knob: loopback has no real RTT, so the dataplane
        #: bench injects one here to reproduce the WAN regime where
        #: pipelining pays off.  Applied before each request send, off
        #: the critical path of already-streaming predecessors.
        self.request_latency = request_latency
        #: per-READ inactivity bound (seconds; 0 disables).  A replica
        #: that stalls without dying would otherwise hang a lane forever
        #: — the timeout converts the stall into a ``ConnectionError`` so
        #: it takes the same re-pool path as a connection death.  Scoped
        #: per socket read, not per request: a huge range streaming
        #: slowly-but-steadily never trips it.
        self.read_timeout = read_timeout
        self.broken = False
        self._sock: Optional[socket.socket] = None
        self._rbuf = bytearray()
        self._rtt_samples: list[float] = []
        self._wlock = asyncio.Lock()
        #: completion event of the most recently issued request (the
        #: turnstile tail); None = pipe idle since connect.
        self._tail: Optional[asyncio.Event] = None

    def take_rtt_samples(self) -> list[float]:
        samples, self._rtt_samples = self._rtt_samples, []
        return samples

    async def connect(self):
        loop = asyncio.get_running_loop()
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setblocking(False)
        t0 = time.monotonic()
        try:
            await loop.sock_connect(
                sock, (self.replica.host, self.replica.port))
        except BaseException:
            sock.close()
            raise
        self._rtt_samples.append(time.monotonic() - t0)
        # pipelined requests are tiny back-to-back writes: without NODELAY
        # Nagle would hold them hostage to the previous response's ACKs
        with contextlib.suppress(OSError):
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock

    async def close(self):
        if self._sock is not None:
            with contextlib.suppress(OSError):
                self._sock.close()
            self._sock = None

    def abort(self) -> None:
        """Break the connection under a CONCURRENT reader (hedge-win
        cancellation).  ``close()`` would free the fd while a
        ``sock_recv`` future is still registered on it — the selector
        never fires for a closed fd and the loser's read would only die
        at the inactivity timeout.  ``shutdown()`` keeps the fd alive
        and wakes the pending read with EOF immediately; the owning
        worker then closes the socket on its normal unwind path."""
        self.broken = True
        if self._sock is not None:
            with contextlib.suppress(OSError):
                self._sock.shutdown(socket.SHUT_RDWR)

    # -- buffered header reads / zero-copy body reads ----------------------

    async def _timed(self, aw):
        """Bound one socket read by the inactivity timeout."""
        if self.read_timeout <= 0.0:
            return await aw
        try:
            return await asyncio.wait_for(aw, self.read_timeout)
        except asyncio.TimeoutError:
            raise ConnectionError(
                f"read stalled > {self.read_timeout:g}s "
                f"(inactivity timeout)") from None

    def _live_sock(self) -> socket.socket:
        """Snapshot the socket for one read.  A concurrent ``close()``
        (a hedge winner severing the losing lane) nulls ``_sock`` between
        awaits; reading through the snapshot turns that race into the
        ConnectionError every caller already handles instead of an
        AttributeError on ``None``."""
        sock = self._sock
        if sock is None:
            raise ConnectionError("connection closed")
        return sock

    async def _fill(self, hint: int) -> None:
        data = await self._timed(
            asyncio.get_running_loop().sock_recv(self._live_sock(), hint))
        if not data:
            raise ConnectionError("connection closed")
        self._rbuf += data

    async def _readline(self) -> bytes:
        while True:
            idx = self._rbuf.find(b"\n")
            if idx >= 0:
                line = bytes(self._rbuf[:idx + 1])
                del self._rbuf[:idx + 1]
                return line
            if len(self._rbuf) > 65536:
                raise ConnectionError("oversized header line")
            await self._fill(self._HEADER_RECV)

    async def _read_headers(self) -> tuple[int, dict]:
        status = await self._readline()
        parts = status.split()
        if len(parts) < 2 or not parts[1].isdigit():
            raise ConnectionError(f"malformed status line: {status!r}")
        code = int(parts[1])
        headers = {}
        while True:
            line = await self._readline()
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        return code, headers

    async def _read_body(self, n: int, into: Optional[memoryview],
                         progress: Optional[list] = None):
        """Read exactly ``n`` body bytes — into the caller's view when
        given (zero-copy), into fresh ``bytes`` otherwise.  Slot 0 of
        ``progress`` (a list) is kept updated with the byte count landed
        so far — the hedging layer reads it to avoid duplicating ranges
        whose owner has already received most of the body."""
        if into is None:
            scratch = bytearray(n)
            view = memoryview(scratch)
        else:
            if len(into) < n:
                raise ConnectionError(
                    f"response body {n} B overruns the {len(into)} B "
                    f"destination range")
            scratch = None
            view = into
        got = min(len(self._rbuf), n)   # header-phase read-ahead first
        if got:
            view[:got] = self._rbuf[:got]
            del self._rbuf[:got]
        if progress is not None:
            progress[0] = got
        loop = asyncio.get_running_loop()
        try:
            while got < n:
                r = await self._timed(
                    loop.sock_recv_into(self._live_sock(), view[got:n]))
                if r <= 0:
                    raise ConnectionError(
                        f"connection closed mid-body ({got}/{n} B)")
                got += r
                if progress is not None:
                    progress[0] = got
        except ConnectionError as e:
            # how much of the body actually landed before the break —
            # the waste accounting for a hedge-cancelled read charges
            # the bytes genuinely spent, not the whole range
            e.partial_bytes = got
            raise
        return bytes(scratch) if scratch is not None else view[:n]

    # -- requests ----------------------------------------------------------

    def _request_bytes(self, method: str, start=None, end=None) -> bytes:
        rng = (f"Range: bytes={start}-{end}\r\n"
               if start is not None else "")
        return (f"{method} {self.replica.path} HTTP/1.1\r\n"
                f"Host: {self.replica.host}\r\n{rng}"
                f"Connection: keep-alive\r\n\r\n").encode()

    @staticmethod
    def _parse_checksum(headers: dict) -> Optional[int]:
        raw = headers.get("x-range-checksum")
        if raw and raw.startswith("crc32:"):
            try:
                return int(raw[len("crc32:"):], 16)
            except ValueError:
                return None
        return None

    async def fetch_range(self, start: int, end: int,
                          into: Optional[memoryview] = None,
                          progress: Optional[list] = None) -> _RangeReply:
        """GET bytes [start, end] inclusive over the persistent session.

        May be called concurrently: the request goes on the wire
        immediately (pipelined behind any in-flight predecessors) and the
        response is read in FIFO order.  With ``into``, the body is
        received directly into that view and the reply's ``data`` is
        ``into[:nbytes]``; without it, fresh ``bytes`` are returned.
        """
        if self._sock is None:
            # concurrent lanes race to the first request: exactly one may
            # establish the session (an unguarded lazy connect would open
            # one socket per lane and leak all but the last)
            async with self._wlock:
                if self._sock is None and not self.broken:
                    try:
                        await self.connect()
                    except BaseException:
                        self.broken = True
                        raise
        if self.request_latency > 0.0:
            await asyncio.sleep(self.request_latency)
        my_done = asyncio.Event()
        async with self._wlock:
            if self.broken or self._sock is None:
                raise ConnectionError("pipelined connection broken")
            prior = self._tail
            self._tail = my_done
            pipelined = prior is not None and not prior.is_set()
            t_send = time.monotonic()
            if progress is not None and len(progress) > 1:
                # wire-send stamp for the hedging layer: a range starts
                # aging only once its request is actually on the wire
                progress[1] = t_send
            try:
                await asyncio.get_running_loop().sock_sendall(
                    self._sock, self._request_bytes("GET", start, end))
            except BaseException:
                self.broken = True
                my_done.set()
                raise
        try:
            if prior is not None:
                await prior.wait()
            if self.broken:
                raise ConnectionError("pipelined predecessor failed")
            t_ready = time.monotonic()
            code, headers = await self._read_headers()
            if not pipelined:
                # idle-pipe turnaround = request RTT + server think time
                self._rtt_samples.append(time.monotonic() - t_send)
            if code not in (200, 206):
                raise ConnectionError(f"HTTP {code}")
            try:
                n = int(headers["content-length"])
            except (KeyError, ValueError):
                raise ConnectionError("missing/invalid Content-Length")
            body = await self._read_body(n, into, progress)
            t_end = time.monotonic()
            return _RangeReply(
                data=body, nbytes=n,
                elapsed=t_end - (t_ready if pipelined else t_send),
                rtt_included=not pipelined,
                crc32=self._parse_checksum(headers))
        except BaseException:
            self.broken = True
            raise
        finally:
            my_done.set()

    async def head(self) -> tuple[int, dict]:
        """HEAD the replica's path; returns (status, headers).  Not
        pipelined — used once per transfer for size discovery."""
        if self._sock is None:
            await self.connect()
        await asyncio.get_running_loop().sock_sendall(
            self._sock, self._request_bytes("HEAD"))
        return await self._read_headers()


class MDTPClient:
    """Downloads one blob from N replicas with MDTP adaptive chunking."""

    def __init__(
        self,
        replicas: Sequence[Replica],
        params: Optional[ChunkParams] = None,
        options: Optional[ClientOptions] = None,
        **kw,
    ):
        """``options`` is the consolidated configuration
        (:class:`ClientOptions`, grouped and documented there); any bare
        keyword from the historical 15-kwarg constructor is still
        accepted and overrides the corresponding options field — the
        compatibility shim that keeps every existing call site (and the
        fleet manager's ``**client_kw`` forwarding) working unchanged.
        An unknown keyword raises ``TypeError`` exactly as before."""
        if options is None:
            try:
                options = ClientOptions(**kw)
            except TypeError as e:
                raise TypeError(f"MDTPClient: {e}") from None
        elif kw:
            options = _dc_replace(options, **kw)
        if params is not None:
            options = _dc_replace(options, params=params)
        #: the resolved configuration (read-only snapshot).
        self.options = options
        self.replicas = list(replicas)
        self._params_arg = options.params
        self._estimator = options.estimator
        self._alpha = options.ewma_alpha
        self.retry_after = options.retry_after
        self.max_failures = options.max_failures
        self.tuner = options.tuner
        self.pipeline_depth = max(int(options.pipeline_depth), 1)
        self.zero_copy = options.zero_copy
        self.request_latency = options.request_latency
        self.verify_integrity = options.verify_integrity
        self.read_timeout = options.read_timeout
        self.retry_backoff_cap = options.retry_backoff_cap
        #: endgame hedging (0 disables): once the residual drops below
        #: ~2 allocator rounds, an idle lane speculatively duplicates an
        #: in-flight range whose owner's per-byte latency EWMA sits at or
        #: above this fleet quantile (or whose range has aged well past
        #: the owner's own expected service time — the grayed-out-mirror
        #: case, where the EWMA goes stale).  First completion wins; the
        #: loser is cancelled/discarded with byte accounting on the
        #: report (``hedges_issued`` / ``hedges_won`` /
        #: ``hedge_wasted_bytes``).  Applies only when assembling
        #: in-memory (``sink=None``): hedge bodies land in private
        #: scratch, never the destination, so a losing or corrupt copy
        #: cannot touch committed bytes.
        self.hedge_quantile = float(options.hedge_quantile)
        #: hard cap on hedge waste as a fraction of the transfer size: a
        #: hedge is only issued while committed waste plus every
        #: in-flight hedge's reserved length stays under this budget —
        #: each race can waste at most its own range, whichever side
        #: loses, so ``hedge_wasted_bytes <= hedge_waste_frac * size``
        #: holds by construction.
        self.hedge_waste_frac = float(options.hedge_waste_frac)
        self.coverage_refresh_s = float(options.coverage_refresh_s)
        self._rng = options.rng if options.rng is not None else random
        #: report of the most recent ``fetch`` (None before the first one).
        self.last_report: Optional[TransferReport] = None

    #: fallback request RTT (s) for replicas that never produced a sample —
    #: ~WAN RTT between FABRIC sites, matching the simulator scenarios.
    DEFAULT_RTT = 0.03

    #: minimum contiguous streaming time (s) aggregated into one
    #: throughput observation — see the observation-window comment in
    #: ``fetch``.
    OBS_WINDOW_S = 0.02

    def retune(self, file_size: int, **autotune_kw):
        """Re-tune chunk sizes from the last transfer's live observations.

        Runs the fused on-device grid sweep (``repro.core.autotune`` — one
        compiled call for the whole (C, L) × seed lattice) against the
        per-replica throughputs AND measured request RTTs observed during
        the previous ``fetch`` and adopts the winning ``ChunkParams`` for
        subsequent transfers.  Typical use: between checkpoint-restore
        waves, where mirror conditions drift but the replica set is stable.

        The client's own ``pipeline_depth`` is passed to the sweep (unless
        overridden) so the simulated request-latency amortization matches
        what this runtime actually does on the wire; likewise an observed
        corruption rate (re-fetched ranges / requests) is folded in so the
        sweep's (C, L) pays the same re-fetch overhead the wire did.

        Returns the ``AutotuneResult``; raises if no transfer has been
        observed yet or no replica produced a throughput sample.
        """
        from repro.core.autotune import autotune_chunk_params

        if self.last_report is None:
            raise NoTelemetryError("retune() needs a completed fetch() first")
        # Replicas with no sample (failed / never dispatched) are excluded,
        # mirroring how fetch() retires them — a 0-throughput entry would
        # otherwise dominate every simulated grid point.  RTTs stay aligned
        # with the surviving bandwidth entries.  Estimates are already wire
        # rates (the RTT bias is stripped per observation, see
        # ``wire_elapsed``), so they feed the sweep directly.
        rep = self.last_report
        bw, rtts = [], []
        for r in self.replicas:
            b = rep.observed_throughputs.get(r.name, 0.0)
            if b <= 0.0:
                continue
            rtt = rep.observed_rtts.get(r.name, 0.0)
            bw.append(b)
            rtts.append(rtt if rtt > 0.0 else self.DEFAULT_RTT)
        if not bw:
            raise NoTelemetryError("no throughput observations to retune from")
        autotune_kw.setdefault("rtt", rtts)
        autotune_kw.setdefault("pipeline_depth", self.pipeline_depth)
        total_reqs = sum(rep.requests_per_replica.values())
        total_corrupt = sum(rep.corrupt_ranges.values())
        if total_corrupt > 0 and total_reqs > 0:
            autotune_kw.setdefault(
                "corruption_rate", min(total_corrupt / total_reqs, 0.5))
            # a single seed sees one fault realization; average a few
            autotune_kw.setdefault("n_seeds", 4)
        res = autotune_chunk_params(bw, file_size=int(file_size),
                                    **autotune_kw)
        self._params_arg = res.params
        return res

    def adopt_params(self, params: ChunkParams) -> None:
        """Adopt chunk geometry for subsequent transfers.

        The public hook for external re-tuning loops (e.g. the
        checkpoint-restore wave loop feeding an online tuner between
        waves); ``fetch(tuner=...)`` and ``retune`` adopt internally.
        """
        self._params_arg = params

    def _make_conn(self, replica: Replica) -> "_Conn":
        """Connection factory — subclasses may translate offsets (the data
        pipeline's virtual-blob client) or wrap requests (the fleet
        manager's capped, telemetry-fed connections)."""
        return _Conn(replica, request_latency=self.request_latency,
                     read_timeout=self.read_timeout)

    def _allocation_throughputs(self, est_values: list) -> list:
        """Per-replica throughput vector the allocator sizes chunks from.

        Default: this transfer's own estimator values.  The fleet manager
        (``repro.transfer.manager``) overrides this to pack each round
        into *residual* replica capacity — fleet bandwidth minus what
        other concurrent transfers are consuming — so co-scheduled
        transfers don't all plan as if they owned the mirrors.
        """
        return est_values

    def _on_corruption(self, name: str) -> None:
        """Integrity-failure hook: called once per checksum-mismatched
        range, outside the transfer lock.  The fleet manager overrides
        this to feed per-replica corruption counters into the
        ``FleetModel`` so chronically corrupt replicas are deprioritized
        fleet-wide, not just within this transfer."""

    def _on_retry(self, name: str) -> None:
        """Connection-retry hook: called once per reconnect-with-backoff
        attempt (a break, stall, or reset that the worker survives).  The
        fleet manager overrides this to feed retry counts into the
        ``FleetModel``'s probation thresholds — a replica that keeps
        costing reconnects goes on probation fleet-wide."""

    async def fetch(self, size: int, sink=None, *, offset: int = 0,
                    tuner=None, tune_interval_bytes: Optional[int] = None,
                    resume=None, into: Optional[bytearray] = None,
                    stripe: Optional[tuple] = None,
                    ) -> tuple[Optional[bytearray], TransferReport]:
        """Fetch ``size`` bytes.  ``sink`` (if given) receives ranges as
        they land — see the module docstring for the two sink protocols
        (callable receiving transient memoryviews, or ``writable``/
        ``commit`` for the copy-free path); otherwise an in-memory buffer
        is assembled (and received into directly — zero-copy).  ``into``
        supplies that buffer (``len(into) >= size``) instead of a fresh
        allocation — resume needs the previous attempt's bytes in place.

        ``offset`` shifts every byte-range request (and the ``sink`` start
        offsets) by a constant — a wave of a larger blob fetches
        ``[offset, offset + size)`` while the internal frontier/pool stay
        0-based (the checkpoint-restore wave loop uses this).

        ``resume`` (a :class:`~repro.transfer.journal.ResumeJournal`)
        replays previously committed intervals: each journaled record
        inside this fetch's window is re-verified against the destination
        (its CRC32 — data that never reached stable storage fails and is
        re-fetched), verified bytes are counted done without touching the
        wire, and every NEW committed range is appended to the journal
        (fsync'd at the journal's checkpoint interval).  The journal is
        left open; call ``complete()`` on it after the overall operation
        (which may span several waves) succeeds.

        Raises :class:`TransferIncompleteError` if the surviving replicas
        could not deliver every byte — a short buffer never escapes.

        ``tuner`` (default: the client's ``tuner``) re-tunes chunk
        geometry mid-transfer: every ``tune_interval_bytes`` delivered
        bytes the client snapshots live telemetry (per-replica estimator
        values + measured RTTs, achieved window throughput) into a
        ``repro.core.online.Telemetry`` and adopts whatever ``ChunkParams``
        the tuner returns — workers pick up the new geometry on their next
        allocation.  The tuner runs in a thread-pool executor so its
        (possibly jit-compiling) sweep never stalls the event loop; at
        most one update is in flight at a time.  Adopted params persist on
        the client for subsequent transfers, and ``report.retunes`` counts
        the adoptions.

        ``stripe=(k, n)`` rotates the fresh-byte frontier to start at
        ``size * k // n`` (wrapping) instead of 0.  In a swarm of ``n``
        restorers this de-correlates what each node fetches FIRST, so
        peers become useful sources for each other almost immediately —
        everyone starting at byte 0 would race the origin for the same
        prefix and have nothing to trade.  Purely an ordering hint:
        every byte is still fetched exactly once.

        Replicas flagged ``mirror=True`` are PARTIAL peer mirrors: their
        advertised coverage (``X-Available-Ranges``) is polled in the
        background every ``coverage_refresh_s`` and chunks are packed
        onto a peer only when its advertisement covers them; full
        replicas meanwhile prefer spans no live peer holds yet (origin
        offload).  A fetch whose only surviving sources are partial
        mirrors that cannot cover the remaining bytes gives up with
        :class:`TransferIncompleteError` once their joint coverage has
        been static for a patience window, instead of waiting forever.
        """
        params_box = [self._params_arg or default_chunk_params(size)]
        n = len(self.replicas)
        depth = self.pipeline_depth
        est = [make_estimator(self._estimator, self._alpha) for _ in range(n)]
        # per-replica [bytes, seconds] observation windows: back-to-back
        # pipelined replies carry wildly noisy per-reply timings (a body
        # the kernel buffered ahead reads in microseconds, the next one
        # absorbs the wait), but their SUM over a contiguous streaming
        # window is exact — so samples are aggregated until the window
        # holds enough signal, then fed to the estimator as one reading
        obs_win = [[0, 0.0] for _ in range(n)]
        zero_copy = self.zero_copy
        if sink is not None and into is not None:
            raise TypeError("into= only applies when assembling in-memory "
                            "(sink is None)")
        if into is not None and len(into) < size:
            raise ValueError(f"into buffer ({len(into)} B) smaller than "
                             f"transfer size ({size} B)")
        buf = (into if into is not None else bytearray(size)) \
            if sink is None else None
        sink_writable = getattr(sink, "writable", None)
        sink_commit = getattr(sink, "commit", None)
        if (sink_writable is None) != (sink_commit is None):
            raise TypeError(
                "zero-copy sinks must provide BOTH writable() and commit()")

        verify = self.verify_integrity
        journal = resume
        need_crc = verify or journal is not None

        # the fresh-byte frontier: never-assigned spans as ordered
        # (start, end) segments.  The classic single ``cursor`` is the
        # one-segment case [(0, size)]; ``stripe=(k, n)`` rotates the
        # walk to start at size*k//n (two segments, wrapping).  ``fresh``
        # mirrors the segments' byte total so the hot remaining-work
        # check stays O(1).
        segs: list = [(0, size)] if size > 0 else []
        if stripe is not None and size > 0:
            k_, n_ = stripe
            p = (size * (k_ % max(int(n_), 1))) // max(int(n_), 1)
            if 0 < p < size:
                segs = [(p, size), (0, p)]
        fresh = sum(e_ - s_ for s_, e_ in segs)
        # reclaimed (start, len, banned) min-heap keyed on range start
        # (ranges never overlap, so comparisons never reach the
        # non-orderable ban set); ``banned`` is the frozenset of replica
        # indices that served this range corrupt — the packer re-fetches
        # it from anyone else.  ``pooled`` mirrors the heap's byte total
        # so the hot remaining-work check is O(1).
        pool: list[tuple[int, int, frozenset]] = []
        pooled = 0
        bytes_per = {r.name: 0 for r in self.replicas}
        reqs_per = {r.name: 0 for r in self.replicas}
        retries_per = {r.name: 0 for r in self.replicas}
        corrupt_per = {r.name: 0 for r in self.replicas}
        rtt_min = [0.0] * n                      # 0 = no sample yet
        failed: list[str] = []
        #: replica indices whose worker is still running — the ban-set
        #: escape hatch (a range banned for EVERY live replica may be
        #: retried by anyone rather than deadlock) and the worker-exit
        #: wakeup both key off this.
        alive: set = set(range(n))
        refetched = 0
        # -- partial-mirror coverage (``Replica.mirror``) ------------------
        #: replica index -> advertised coverage as window-relative sorted
        #: disjoint (start, end) runs; None = full replica (everything).
        #: Starts EMPTY for mirrors — nothing is packed onto a peer until
        #: its first advertisement arrives.
        avail: list = [([] if r.mirror else None) for r in self.replicas]
        partial_idx = [j for j, r in enumerate(self.replicas) if r.mirror]
        #: union of all LIVE peers' coverage (same run form) — what the
        #: origin-offload pass steers full replicas away from.
        cov_union: list = []
        #: monotonic stamp of the last coverage CHANGE; the give-up rule
        #: for uncoverable work keys off how long it has been static.
        cov_stamp = [time.monotonic()]
        refresh_s = max(float(self.coverage_refresh_s), 0.005)
        cov_patience = max(1.0, 10.0 * refresh_s)

        def _recompute_union() -> None:
            runs = []
            for j in partial_idx:
                if j in alive:
                    runs.extend(avail[j])
            runs.sort()
            merged: list = []
            for s_, e_ in runs:
                if merged and s_ <= merged[-1][1]:
                    if e_ > merged[-1][1]:
                        merged[-1] = (merged[-1][0], e_)
                else:
                    merged.append((s_, e_))
            cov_union[:] = merged

        lock = asyncio.Lock()
        #: signalled whenever reclaimed work appears or in-flight bytes
        #: drain to zero — a lane with nothing to draw parks here instead
        #: of polling (it must stay alive while peers owe ranges: if a
        #: peer's replica dies, its range returns to the pool and needs a
        #: surviving taker — the mirror-death fault-tolerance contract).
        cond = asyncio.Condition(lock)
        done_bytes = 0
        resumed_bytes = 0
        resume_verify = 0.0

        if journal is not None:
            # Replay: every journaled record inside this window whose
            # bytes still verify is covered; everything else re-fetches.
            # Verification needs a readable destination — the assembly
            # buffer or a writable() sink view; callable sinks can't be
            # read back, so their records are trusted as journaled.
            def _view_of(abs_start: int, nb: int):
                if buf is not None:
                    lo = abs_start - offset
                    return memoryview(buf)[lo:lo + nb]
                if sink_writable is not None:
                    return sink_writable(abs_start, nb)
                return None

            verified: list[tuple[int, int]] = []
            t_verify = time.monotonic()
            for s_abs, nb, rcrc in journal.records():
                if s_abs < offset or s_abs + nb > offset + size:
                    continue
                v = _view_of(s_abs, nb)
                if v is not None and rcrc is not None \
                        and await _crc32_async(v) != rcrc:
                    continue
                verified.append((s_abs - offset, nb))
            resume_verify = time.monotonic() - t_verify
            covered = merge_intervals(verified)
            for s_, n_ in uncovered_intervals(covered, size):
                heapq.heappush(pool, (s_, n_, frozenset()))
                pooled += n_
            segs.clear()             # all remaining work lives in the pool
            fresh = 0
            resumed_bytes = size - pooled
            done_bytes = resumed_bytes
            if sink_commit is not None:
                # drive the sink's covered-interval accounting so resumed
                # regions materialize exactly like freshly landed ones
                for s_, n_ in covered:
                    sink_commit(offset + s_, n_)

        t0 = time.monotonic()

        tuner = tuner if tuner is not None else self.tuner
        retunes = 0
        # telemetry cadence: a handful of updates per transfer by default,
        # but never finer than a couple of large chunks' worth of signal
        tune_every = tune_interval_bytes or max(
            size // 8, 2 * params_box[0].large_chunk)
        tune_state = {"bytes": done_bytes, "t": t0, "busy": False,
                      "task": None}

        def _telemetry_bandwidths() -> tuple:
            """Full-fleet positional wire-rate vector for ``Telemetry``:
            estimator values (already RTT-de-biased at observation time),
            dead replicas zeroed in place."""
            return tuple(
                0.0 if r.name in failed else float(est[i].value)
                for i, r in enumerate(self.replicas))

        async def maybe_retune():
            """Snapshot telemetry and let the tuner re-plan (at most one
            update in flight — the trigger site claims the busy flag
            BEFORE scheduling, so a second trigger can't race in between;
            runs in an executor so jit compiles inside the tuner don't
            stall the event loop)."""
            nonlocal retunes
            try:
                try:
                    from repro.core.online import Telemetry

                    now = time.monotonic()
                    window_bytes = done_bytes - tune_state["bytes"]
                    window_t = max(now - tune_state["t"], 1e-9)
                    telemetry = Telemetry(
                        bandwidth=_telemetry_bandwidths(),
                        rtt=tuple(float(x) for x in rtt_min),
                        remaining_bytes=float(size - done_bytes),
                        measured_throughput=window_bytes / window_t,
                        elapsed=now - t0,
                    )
                    loop = asyncio.get_running_loop()
                    new = await loop.run_in_executor(None, tuner.update,
                                                     telemetry)
                except asyncio.CancelledError:
                    raise
                except Exception:
                    # a failing tuner path (the lazy online import in a
                    # jax-less deployment, a bad jit compile, a tuner
                    # bug) must never fail a transfer whose bytes are
                    # flowing fine — keep the current geometry, carry on
                    new = None
                tune_state["bytes"] = done_bytes
                tune_state["t"] = time.monotonic()
                if new is not None:
                    params_box[0] = new
                    retunes += 1
            finally:
                tune_state["busy"] = False

        # bytes currently on the wire somewhere; a lane that sees no
        # unassigned bytes must NOT exit while another lane still owes a
        # range (see ``cond`` above).
        inflight = 0

        # -- endgame hedging state (``hedge_quantile`` > 0) ----------------
        # scratch-buffer hedges need a readable destination to commit to,
        # so hedging is in-memory-assembly only (see __init__ docstring)
        hedge_q = self.hedge_quantile if sink is None else 0.0
        #: per-replica EWMA of per-byte receive latency (s/B) — the
        #: straggler signal the hedge quantile cuts across.
        lat_ewma = [0.0] * n
        #: per-replica monotonic time of the last COMPLETED range — the
        #: wedge signal: a gray mirror stops finishing anything, while an
        #: honestly-congested one keeps completing sibling ranges.
        last_done = [0.0] * n
        #: scheduler-stall clock.  A heartbeat task sleeps
        #: ``_HEDGE_POLL_S`` at a time; waking far later means the whole
        #: process was starved (CPU contention, GC pause) — every
        #: in-flight range aged without its owner getting any airtime,
        #: and firing on that age would hedge perfectly healthy owners
        #: at a full range's waste each.  ``stall_s[0]`` accumulates the
        #: stolen time; the trigger subtracts the portion accrued over
        #: each range's own lifetime, so a loaded host DELAYS hedges
        #: instead of misfiring them.  ``last_done_stall`` pairs a
        #: snapshot with each ``last_done`` stamp for the wedge window.
        stall_s = [0.0]
        last_done_stall = [0.0] * n
        #: start -> (length, owner, ban, progress, stall_at) for every
        #: range on the wire; maintained only while hedging is enabled.
        #: ``progress`` is ``[bytes_landed, wire_send_time]``: the
        #: owner's body read keeps slot 0 updated, and the connection
        #: stamps slot 1 the moment the request is actually SENT — the
        #: hedge trigger ages ranges from that stamp, because time spent
        #: queued on a slot semaphore or byte budget says nothing about
        #: the owner's health.  ``stall_at`` snapshots ``stall_s`` at
        #: issue time.
        outstanding: dict = {}
        #: start -> (length, hedger, conn) for every hedge in flight;
        #: the lengths are RESERVED against the waste budget (a hedge
        #: can waste at most its own range, whichever side loses the
        #: race), and the connection is what an owner that lands first
        #: breaks to cancel the losing copy promptly.
        hedged: dict = {}
        settled: set = set()         # starts a winning hedge completed
        #: winner bytes kept until the losing copy resolves, so a loser
        #: body that zero-copy-landed over them can be healed back.
        settled_data: dict = {}
        #: owner indices whose connection was broken ON PURPOSE to cancel
        #: a lost race — the worker reconnects without charging its
        #: failure budget.
        hedge_broke: set = set()
        #: replica index -> the connection its worker currently runs
        #: lanes on (so a winning hedge can break the loser's connection
        #: and turn its pending read into a prompt error).
        conn_of: dict = {}
        hedges_issued = hedges_won = 0
        hedge_wasted = 0

        def observe_latency(i: int, ndata: int, elapsed: float) -> None:
            if ndata <= 0 or elapsed <= 0.0:
                return
            last_done[i] = time.monotonic()
            last_done_stall[i] = stall_s[0]
            pb = elapsed / ndata
            lat_ewma[i] = pb if lat_ewma[i] <= 0.0 \
                else 0.5 * lat_ewma[i] + 0.5 * pb

        async def _stall_clock() -> None:
            """Heartbeat feeding ``stall_s``: each sleep should wake
            after ``_HEDGE_POLL_S``; waking well past twice that means
            the event loop (and so every lane) was starved, and the
            overshoot is time stolen from ALL owners at once, not
            evidence against any one of them."""
            prev = time.monotonic()
            while True:
                await asyncio.sleep(_HEDGE_POLL_S)
                t = time.monotonic()
                if t - prev > 2.0 * _HEDGE_POLL_S:
                    stall_s[0] += (t - prev) - _HEDGE_POLL_S
                prev = t

        def _heal_settled(start: int) -> None:
            """Restore a winning hedge's bytes over whatever a losing
            copy wrote into the destination (called under the lock when
            the loser resolves)."""
            settled.discard(start)
            good = settled_data.pop(start, None)
            if buf is not None and good is not None:
                buf[start:start + len(good)] = good

        def _pick_hedge(j: int):
            """A straggling in-flight range worth duplicating onto idle
            replica ``j`` (called under the lock), or None.

            A candidate must be OVERDUE: aged past what its owner should
            plausibly have needed, where "should" spans the lane queue —
            a pipelined range can wait ``depth`` service times behind its
            siblings while perfectly healthy, so the overdue bar starts
            at ``depth + 1`` expected service times.  MDTP sizes chunks
            so slow mirrors finish ON TIME; being slow per-byte is not by
            itself straggling.  An owner whose per-byte latency EWMA sits
            at or above the ``hedge_quantile`` of the live fleet's EWMAs
            gets the lower bar; a healthy-looking owner must overshoot
            twice that AND look wedged — no range completed within an
            expected service time.  That is the gray-failure shape: a
            stalled mirror stops producing samples, its EWMA stays
            stale-fast (so the bar built on it is tiny) and only the
            range's age betrays it, whereas an honestly-congested owner
            keeps completing sibling ranges, and a near-tie duplicate
            race against it would waste a range's worth of bytes to
            save almost nothing.  Either way replica ``j`` must
            plausibly beat continuing to wait: the range's age already
            exceeds what ``j`` itself would have needed to fetch it.
            All ages discount measured scheduler stall (``stall_s``):
            on a starved host every range ages at once, and that is
            evidence against the HOST, not any owner."""
            if not hedge_q or not outstanding:
                return None
            # endgame window: residual below ~2 allocator rounds (upper
            # bound — L per live replica is one full round's share)
            if fresh + pooled + inflight > \
                    2 * params_box[0].large_chunk * max(len(alive), 1):
                return None
            if lat_ewma[j] <= 0.0:
                return None          # no evidence j is any faster
            # waste budget: committed waste + reserved in-flight lengths.
            # The first hedge is always affordable — on a small transfer
            # a single range can exceed the fractional budget outright,
            # and a cap that can never admit ANY hedge is no cap at all;
            # the bound is therefore frac*size plus at most one range.
            budget = self.hedge_waste_frac * size \
                - hedge_wasted - sum(h[0] for h in hedged.values())
            first_free = not hedged and hedge_wasted <= 0.0
            samples = sorted(lat_ewma[k] for k in alive
                             if lat_ewma[k] > 0.0)
            slow_cut = None
            if len(samples) >= 2:
                pos = hedge_q * (len(samples) - 1)
                lo = int(pos)
                hi = min(lo + 1, len(samples) - 1)
                slow_cut = samples[lo] \
                    + (samples[hi] - samples[lo]) * (pos - lo)
            now = time.monotonic()
            my_rtt = rtt_min[j] if rtt_min[j] > 0.0 else self.DEFAULT_RTT
            best = None
            for s_, (ln_, owner, ban_, prog_, st_) in \
                    outstanding.items():
                if owner == j or s_ in hedged or s_ in settled \
                        or j in ban_ or (ln_ > budget and not first_free):
                    continue
                if avail[j] is not None and \
                        not _cov_contains(avail[j], s_, s_ + ln_):
                    # a partial mirror may only duplicate ranges its
                    # advertisement covers in full
                    continue
                if 2 * prog_[0] > ln_:
                    # the owner already landed most of the body: cancel-
                    # ling it would waste more bytes than the duplicate
                    # could save — let the remainder trickle in
                    continue
                if prog_[1] <= 0.0:
                    # the request never hit the wire (still queued on a
                    # slot semaphore or the byte budget): whatever delays
                    # it sits upstream of the owner, and a duplicate
                    # would just queue behind the same gate
                    continue
                # age from the wire-send stamp, discounting scheduler
                # stall accrued since issue: queueing and host starvation
                # age every range at once and say nothing about THIS
                # owner's health
                age = (now - prog_[1]) - (stall_s[0] - st_)
                if age <= my_rtt + ln_ * lat_ewma[j]:
                    continue         # j would not have finished it yet
                if prog_[0] > 0:
                    # the owner is visibly streaming: from its observed
                    # rate ON THIS RANGE, project the remainder's
                    # landing time, and duplicate only when j would
                    # finish the WHOLE range well before that — a
                    # merely-contended owner (storm sharing the mirror)
                    # streams slower than its EWMA promises, and racing
                    # it is a near-tie that wastes a body to save
                    # almost nothing.  A gray mirror's trickle projects
                    # seconds of remainder and still qualifies.
                    rem = (ln_ - prog_[0]) * age / prog_[0]
                    if rem <= 2.0 * (my_rtt + ln_ * lat_ewma[j]):
                        continue
                slow = slow_cut is not None and lat_ewma[owner] >= slow_cut
                o_rtt = rtt_min[owner] if rtt_min[owner] > 0.0 \
                    else self.DEFAULT_RTT
                expect_owner = o_rtt + ln_ * lat_ewma[owner]
                # absolute grace floor: at small-chunk scale the expected
                # times are milliseconds, and event-loop/scheduler jitter
                # alone would look like lateness — a few poll periods of
                # slack costs a genuine straggler almost nothing
                overdue = (depth + 1.0) * expect_owner + 4.0 * _HEDGE_POLL_S
                # wedge signal for healthy-LOOKING owners: a gray mirror
                # stops completing anything, while an honestly-congested
                # one keeps finishing sibling ranges — hedging the latter
                # is a near-tie race that wastes a range to save nothing
                wedged = last_done[owner] <= 0.0 or \
                    (now - last_done[owner]) \
                    - (stall_s[0] - last_done_stall[owner]) > \
                    expect_owner + 4.0 * _HEDGE_POLL_S
                if lat_ewma[owner] <= 0.0 \
                        or (slow and age > overdue) \
                        or (wedged and age > 2.0 * overdue):
                    # cheapest insurance first: among overdue candidates
                    # duplicate the SHORTEST range — a losing copy can
                    # waste at most its own length, and a short range is
                    # also the one a hedge can actually win by a margin
                    if best is None or ln_ < best[1]:
                        best = (s_, ln_, owner, ban_)
            return best

        def observe_rtt(i: int, sample: float) -> None:
            if sample > 0.0:
                rtt_min[i] = (sample if rtt_min[i] <= 0.0
                              else min(rtt_min[i], sample))

        async def _reclaim(start: int, length: int, ban: frozenset, *,
                           count: bool, lost: int = 0) -> None:
            """Return an owed range to the pool and settle the in-flight
            count, atomically, waking parked lanes.  A range a winning
            hedge already settled is NOT re-pooled (its bytes are done
            and its in-flight claim already released); the loser's
            partial zero-copy writes are healed back instead, and the
            ``lost`` bytes it did land are charged to the hedge waste.

            A hedge still in flight on the reclaimed range is cancelled
            too: the claim it raced is gone, and the endgame's shrinking
            draws mean the re-pooled range usually re-enters SPLIT — a
            shape the duplicate can no longer settle, so letting it
            stream to completion could only charge a full body."""
            nonlocal inflight, pooled, refetched, hedge_wasted
            doomed = None
            async with lock:
                outstanding.pop(start, None)
                if start in settled:
                    _heal_settled(start)
                    hedge_wasted += min(lost, length)
                    cond.notify_all()
                    return
                doomed = hedged.get(start)
                heapq.heappush(pool, (start, length, ban))
                pooled += length
                inflight -= length
                if count:
                    refetched += 1
                cond.notify_all()
            if doomed is not None and not doomed[2].broken:
                hedge_broke.add(doomed[1])
                doomed[2].abort()

        def _capable(j: int, s_: int, ln_: int) -> bool:
            """Could replica ``j`` serve any part of ``[s_, s_+ln_)``?
            Full replicas always can; a partial mirror only when its
            advertisement intersects the span."""
            cov_j = avail[j]
            return cov_j is None or \
                _cov_first_in(cov_j, s_, s_ + ln_) is not None

        def _ban_ok(i: int, s_: int, ln_: int, ban_: frozenset) -> bool:
            """May replica ``i`` take an entry tagged ``ban_``?  A banned
            replica stands aside while any OTHER live replica that can
            actually cover the span remains unbanned; once none does,
            anyone may retry (the re-verify catches a repeat corruption;
            refusing would deadlock the tail)."""
            if i not in ban_:
                return True
            return not any(j not in ban_ and _capable(j, s_, ln_)
                           for j in alive)

        def _pick_pool_entry(i: int) -> Optional[int]:
            """Index of the lowest-start pool entry replica ``i`` may
            take (see ``_ban_ok``).  Linear scan: the pool holds
            reclaimed ranges only, a handful at worst."""
            best = None
            for k, (s_, ln_, ban_) in enumerate(pool):
                if not _ban_ok(i, s_, ln_, ban_):
                    continue
                if best is None or s_ < pool[best][0]:
                    best = k
            return best

        def _take_pool(k: int, at: int, take: int) -> None:
            """Claim ``[at, at+take)`` out of pool entry ``k`` (under the
            lock): un-taken prefix/suffix pieces keep the entry's ban
            tag and return to the heap."""
            nonlocal pooled
            s_, ln_, ban_ = pool.pop(k)
            if at > s_:
                pool.append((s_, at - s_, ban_))
            tail = (s_ + ln_) - (at + take)
            if tail > 0:
                pool.append((at + take, tail, ban_))
            heapq.heapify(pool)
            pooled -= take

        def _take_seg(si: int, at: int, take: int) -> None:
            """Claim ``[at, at+take)`` out of frontier segment ``si``
            (under the lock)."""
            nonlocal fresh
            s_, e_ = segs[si]
            if at == s_ and at + take == e_:
                del segs[si]
            elif at == s_:
                segs[si] = (at + take, e_)
            elif at + take == e_:
                segs[si] = (s_, at)
            else:
                segs[si:si + 1] = [(s_, at), (at + take, e_)]
            fresh -= take

        def _origin_restricted() -> bool:
            """Should full replicas keep their hands off peer-covered
            spans right now (under the lock)?  True while live peers
            advertise coverage AND the transfer is not in its endgame:
            every peer-covered byte the origin re-serves is egress the
            whole swarm pays for (the broadcast win is origin egress
            ~one copy of the blob), so outside the endgame the origin
            serves only bytes NO peer holds.  In the endgame (residual
            below ~2 allocator rounds) the origin rejoins freely — an
            idle origin must not stretch the tail."""
            if not cov_union:
                return False
            return fresh + pooled + inflight > \
                2 * params_box[0].large_chunk * max(len(alive), 1)

        def _can_draw(i: int) -> bool:
            """Is there ANY remaining span replica ``i`` may serve right
            now (under the lock)?  The park/draw gate: full replicas can
            take fresh bytes or any un-banned pool entry (uncovered-only
            while ``_origin_restricted``); a partial mirror needs its
            advertisement to intersect something."""
            cov = avail[i]
            if cov is None:
                if _origin_restricted():
                    for s_, ln_, ban_ in pool:
                        if _ban_ok(i, s_, ln_, ban_) and _cov_first_out(
                                cov_union, s_, s_ + ln_) is not None:
                            return True
                    return any(_cov_first_out(cov_union, s_, e_) is not None
                               for s_, e_ in segs)
                return fresh > 0 or (bool(pool)
                                     and _pick_pool_entry(i) is not None)
            if not cov:
                return False
            for s_, ln_, ban_ in pool:
                if _ban_ok(i, s_, ln_, ban_) \
                        and _cov_first_in(cov, s_, s_ + ln_) is not None:
                    return True
            return any(_cov_first_in(cov, s_, e_) is not None
                       for s_, e_ in segs)

        def _hopeless() -> bool:
            """Give-up rule (under the lock): every surviving source is
            a partial mirror, their joint coverage has been static for a
            patience window, and some remaining span lies outside it —
            those bytes can never arrive, so lanes should exit and let
            ``fetch`` raise instead of parking forever.  While any full
            replica survives (or coverage is still growing) this stays
            False."""
            if inflight > 0 or not partial_idx:
                return False
            if any(avail[j] is None for j in alive):
                return False
            if time.monotonic() - cov_stamp[0] < cov_patience:
                return False
            for s_, ln_, _b in pool:
                if not _cov_contains(cov_union, s_, s_ + ln_):
                    return True
            return any(not _cov_contains(cov_union, s_, e_)
                       for s_, e_ in segs)

        def _draw(i: int, want: int):
            """Pick and claim the next sub-range for replica ``i``
            (under the lock): ``(start, length, ban)`` or None when
            nothing it may serve is available right now.

            Full replicas: while live peers advertise coverage, prefer
            spans NO peer holds yet — every byte the swarm can trade
            internally is a byte the origin never re-serves, which is
            what bends origin egress toward one copy of the blob
            (origin offload).  With no peer coverage in play this
            reduces exactly to the classic packing: reclaimed pool
            work first (lowest start), then the fresh frontier's head.
            Partial mirrors: only spans their advertisement covers."""
            cov = avail[i]
            if cov is None:
                if cov_union:
                    best = None
                    for k, (s_, ln_, ban_) in enumerate(pool):
                        if not _ban_ok(i, s_, ln_, ban_):
                            continue
                        got = _cov_first_out(cov_union, s_, s_ + ln_)
                        if got is not None and (best is None
                                                or got[0] < best[0]):
                            best = (got[0], got[1], k, ban_)
                    if best is not None:
                        at, end_, k, ban_ = best
                        take = min(end_ - at, want)
                        _take_pool(k, at, take)
                        return at, take, ban_
                    for si, (s_, e_) in enumerate(segs):
                        got = _cov_first_out(cov_union, s_, e_)
                        if got is not None:
                            at, end_ = got
                            take = min(end_ - at, want)
                            _take_seg(si, at, take)
                            return at, take, frozenset()
                    if _origin_restricted():
                        # everything left is peer-covered and the
                        # transfer isn't in its endgame: leave it to the
                        # peers (see ``_origin_restricted``)
                        return None
                pick = _pick_pool_entry(i) if pool else None
                if pick is not None:
                    s_, ln_, ban_ = pool[pick]
                    take = min(ln_, want)
                    _take_pool(pick, s_, take)
                    return s_, take, ban_
                if segs:
                    s_, e_ = segs[0]
                    take = min(want, e_ - s_)
                    _take_seg(0, s_, take)
                    return s_, take, frozenset()
                return None
            best = None
            for k, (s_, ln_, ban_) in enumerate(pool):
                if not _ban_ok(i, s_, ln_, ban_):
                    continue
                got = _cov_first_in(cov, s_, s_ + ln_)
                if got is not None and (best is None or got[0] < best[0]):
                    best = (got[0], got[1], k, ban_)
            if best is not None:
                at, end_, k, ban_ = best
                take = min(end_ - at, want)
                _take_pool(k, at, take)
                return at, take, ban_
            for si, (s_, e_) in enumerate(segs):
                got = _cov_first_in(cov, s_, e_)
                if got is not None:
                    at, end_ = got
                    take = min(end_ - at, want)
                    _take_seg(si, at, take)
                    return at, take, frozenset()
            return None

        async def hedge_fetch(j: int, conn: "_Conn", start: int,
                              length: int, owner: int,
                              ban: frozenset) -> Optional[str]:
            """Speculatively duplicate an in-flight range onto replica
            ``j``, into PRIVATE scratch — never the destination, so a
            corrupt or losing body cannot touch committed bytes.  First
            completion wins, and cancellation is symmetric: a winning
            hedge commits its bytes, settles the owner's in-flight
            claim, and cancels the loser by breaking its connection —
            while an owner that lands first breaks THIS connection so
            the doomed copy stops streaming (charging only its partial
            bytes).  A truncated or corrupt hedge is discarded whole
            (the owner still owes the range).  Returns a lane outcome
            to propagate, or None to carry on."""
            nonlocal done_bytes, inflight, hedges_won, hedge_wasted
            name = self.replicas[j].name
            scratch = bytearray(length)
            try:
                reply = await conn.fetch_range(
                    offset + start, offset + start + length - 1,
                    into=memoryview(scratch) if zero_copy else None)
            except (ConnectionError, OSError,
                    asyncio.IncompleteReadError) as e:
                # broken mid-copy — usually the owner landing first and
                # cancelling this race (see the settled commit below).
                # Whatever the duplicate DID land before the break is
                # real duplicated traffic, so it still charges the
                # waste meter.
                async with lock:
                    hedged.pop(start, None)
                    hedge_wasted += min(
                        getattr(e, "partial_bytes", 0), length)
                return "broken"
            except BaseException:
                async with lock:
                    hedged.pop(start, None)
                raise
            ndata = reply.nbytes
            for sample in conn.take_rtt_samples():
                observe_rtt(j, sample)
            body = scratch[:ndata] if zero_copy else reply.data
            crc = await _crc32_async(body) if need_crc else None
            if verify and reply.crc32 is not None and crc != reply.crc32:
                # the range is not ours to re-pool — just discard the
                # copy, but the corruption still counts against j
                async with lock:
                    hedged.pop(start, None)
                    corrupt_per[name] += 1
                    dead = corrupt_per[name] >= self.max_failures
                    if dead and name not in failed:
                        failed.append(name)
                self._on_corruption(name)
                if dead:
                    conn.broken = True
                    return "corrupt-dead"
                return None
            observe_latency(j, ndata, reply.elapsed)
            o_conn = None
            loser = None
            async with lock:
                hedged.pop(start, None)
                # the live claim must still be the EXACT range this hedge
                # duplicated: after a reclaim the range can re-enter the
                # pool and be re-drawn SPLIT (same start, shorter length),
                # and crediting the full hedge body against that narrower
                # claim would double-count the remainder when its own
                # re-fetch lands.  A re-draw by a different replica with
                # identical boundaries is still a clean win — the
                # cancellation just goes to the CURRENT owner.
                entry = outstanding.get(start)
                if ndata < length or start in settled \
                        or entry is None or entry[0] != length:
                    # truncated, re-split, or the owner resolved it
                    # first: the duplicated body is pure waste
                    hedge_wasted += ndata
                else:
                    # hedge wins: commit from scratch, release the
                    # owner's in-flight claim, and keep the bytes so a
                    # late-landing loser body can be healed back over
                    loser = entry[1]
                    if buf is not None:
                        buf[start:start + ndata] = body
                    settled.add(start)
                    settled_data[start] = bytes(body)
                    bytes_per[name] += ndata
                    reqs_per[name] += 1
                    done_bytes += ndata
                    inflight -= length
                    hedges_won += 1
                    # the cancelled copy's waste is charged when the
                    # loser RESOLVES — the bytes it actually landed, not
                    # the whole range (see ``_reclaim`` / the settled
                    # branches of the lane)
                    o_conn = conn_of.get(loser)
                    if journal is not None:
                        journal.record(offset + start, ndata, crc)
                    cond.notify_all()
            if o_conn is not None and not o_conn.broken:
                # actively cancel the loser: breaking its connection
                # turns the pending read into a prompt ConnectionError
                # instead of waiting out the straggler
                hedge_broke.add(loser)
                o_conn.abort()
            return None

        async def pipe_lane(i: int, conn: "_Conn") -> str:
            """One pipelined request lane on replica ``i``'s shared
            connection.  Up to ``pipeline_depth`` lanes run per replica;
            their concurrent ``fetch_range`` calls are what keeps k
            requests on the wire.  Returns ``"done"`` when the transfer
            has no work left, ``"broken"`` on a connection failure (the
            owed range is already back in the pool), ``"corrupt-dead"``
            when this replica crossed the corruption cap and was
            retired."""
            nonlocal inflight, pooled, done_bytes, refetched
            nonlocal hedges_issued, hedge_wasted
            name = self.replicas[i].name

            async def _park() -> None:
                """Wait for pool/in-flight changes; with hedging on (or
                partial mirrors in play) wake periodically anyway — a
                grayed-out straggler generates no events, and a peer
                whose coverage went static fires no notifications either,
                so only a poll can spot an aging range or conclude the
                remaining work is uncoverable."""
                if not hedge_q and not partial_idx:
                    await cond.wait()
                    return
                with contextlib.suppress(asyncio.TimeoutError):
                    await asyncio.wait_for(
                        cond.wait(),
                        _HEDGE_POLL_S if hedge_q else refresh_s)

            while True:
                if conn.broken:
                    # a sibling lane hit the failure first; don't draw
                    # work a doomed request would just bounce back
                    return "broken"
                hedge = None
                async with lock:
                    while True:
                        if conn.broken:
                            # woke from cond.wait to a sibling's failure:
                            # don't draw a range a doomed send would just
                            # bounce back (and spuriously count as
                            # refetched)
                            return "broken"
                        remaining = fresh + pooled
                        if remaining <= 0:
                            if inflight <= 0:
                                return "done"
                            hedge = _pick_hedge(i)
                            if hedge is not None:
                                break
                            await _park()
                            continue
                        if not _can_draw(i):
                            # nothing this replica may serve right now:
                            # every pooled range is tagged away from it
                            # (and another capable replica can take it),
                            # or it's a partial mirror whose advertised
                            # coverage misses all remaining spans — park
                            # until the pool or an advertisement changes
                            # (or hedge a straggler meanwhile)... unless
                            # no possible source for the rest remains.
                            if _hopeless():
                                cond.notify_all()
                                return "done"
                            hedge = _pick_hedge(i)
                            if hedge is not None:
                                break
                            await _park()
                            continue
                        break
                    if hedge is not None:
                        h_start, h_len, h_owner, h_ban = hedge
                        hedged[h_start] = (h_len, i, conn)
                        hedges_issued += 1
                if hedge is not None:
                    outcome = await hedge_fetch(i, conn, h_start, h_len,
                                                h_owner, h_ban)
                    if outcome is not None:
                        return outcome
                    continue
                async with lock:
                    if conn.broken:
                        return "broken"
                    remaining = fresh + pooled
                    if remaining <= 0:
                        continue
                    if not _can_draw(i):
                        continue
                    want = next_chunk_size(
                        i,
                        self._allocation_throughputs(
                            [e.value for e in est]),
                        params_box[0], remaining)
                    if want <= 0:
                        return "done"
                    if depth > 1:
                        # the allocator sizes one MDTP round's share for
                        # this replica; the lanes split it so the
                        # PIPELINE in aggregate holds ~two rounds' worth
                        # — enough in-flight bytes to cover the
                        # bandwidth-delay product through lane-convoy
                        # phasing, while a slow mirror's queue stays
                        # bounded at 2 rounds instead of depth rounds
                        # (which would starve fast peers of tail work
                        # exactly like the stragglers §IV chunks rounds
                        # to avoid).  Near the end of the transfer the
                        # pieces shrink further (remaining / 2*depth) so
                        # the final bytes keep rebalancing onto whoever
                        # is actually fast instead of draining a slow
                        # pipeline's queue while fast peers idle.
                        want = min(max(want // ((depth + 1) // 2),
                                       params_box[0].min_chunk),
                                   want, remaining)
                        want = min(want, max(remaining // (2 * depth),
                                             params_box[0].min_chunk))
                    drawn = _draw(i, want)
                    if drawn is None:
                        # the pool/advertisement shifted between the two
                        # lock sections — go around and re-evaluate
                        continue
                    start, length, ban = drawn
                    inflight += length
                    prog = [0, 0.0]
                    if hedge_q:
                        outstanding[start] = (length, i, ban, prog,
                                              stall_s[0])
                # destination: straight into the assembly buffer / the
                # sink's own storage (zero-copy), or per-chunk scratch
                # for callable sinks / the legacy copy path.  A raising
                # ``writable()`` must reclaim like any other failure —
                # the range is already counted in flight.
                try:
                    if sink is None:
                        mv = (memoryview(buf)[start:start + length]
                              if zero_copy else None)
                    elif sink_writable is not None:
                        mv = sink_writable(offset + start, length)
                    else:
                        mv = (memoryview(bytearray(length))
                              if zero_copy else None)
                except BaseException:
                    await _reclaim(start, length, ban, count=False)
                    raise
                try:
                    reply = await conn.fetch_range(
                        offset + start, offset + start + length - 1,
                        into=mv, progress=prog)
                except (ConnectionError, OSError,
                        asyncio.IncompleteReadError) as e:
                    await _reclaim(start, length, ban, count=True,
                                   lost=getattr(e, "partial_bytes", 0))
                    return "broken"
                except BaseException:
                    # cancellation / unexpected error: release the range
                    # so peers waiting on in-flight work aren't stranded
                    await _reclaim(start, length, ban, count=False)
                    raise
                try:
                    ndata = reply.nbytes
                    for sample in conn.take_rtt_samples():
                        observe_rtt(i, sample)
                    crc = None
                    if need_crc:
                        # off the event loop for big bodies; the range is
                        # exclusively ours until committed or re-pooled,
                        # so hashing it unlocked is safe
                        crc = await _crc32_async(reply.data)
                    if (verify and reply.crc32 is not None
                            and crc != reply.crc32):
                        # corrupt body: the bytes never count — re-pool
                        # the WHOLE range tagged "not this replica" so
                        # the packer re-fetches from an alternate mirror
                        doomed = None
                        async with lock:
                            corrupt_per[name] += 1
                            dead = corrupt_per[name] >= self.max_failures
                            outstanding.pop(start, None)
                            if start in settled:
                                # a hedge already delivered this range:
                                # heal its bytes over the corrupt landing
                                # instead of re-pooling settled work (the
                                # discarded duplicate is hedge waste)
                                _heal_settled(start)
                                hedge_wasted += ndata
                            else:
                                # like ``_reclaim``: a duplicate still
                                # racing this now-re-pooled range can no
                                # longer settle it — cancel rather than
                                # let a doomed body stream whole
                                doomed = hedged.get(start)
                                heapq.heappush(
                                    pool, (start, length, ban | {i}))
                                pooled += length
                                inflight -= length
                                refetched += 1
                            if dead and name not in failed:
                                failed.append(name)
                            cond.notify_all()
                        if doomed is not None and not doomed[2].broken:
                            hedge_broke.add(doomed[1])
                            doomed[2].abort()
                        self._on_corruption(name)
                        if dead:
                            # chronically corrupt = retired, like a dead
                            # mirror; breaking the shared conn stops
                            # sibling lanes too
                            conn.broken = True
                            return "corrupt-dead"
                        continue
                    # estimators track the WIRE rate: serial observations
                    # have their request RTT stripped here, pipelined ones
                    # already measure pure body-streaming time
                    elapsed = reply.elapsed
                    if reply.rtt_included:
                        elapsed = wire_elapsed(ndata, elapsed, rtt_min[i])
                    win = obs_win[i]
                    win[0] += ndata
                    win[1] += elapsed
                    # flush on the first-ever sample (ends probe mode
                    # promptly — it is a serial, RTT-stripped reading) or
                    # once the window holds enough streaming time for a
                    # stable rate
                    if est[i].value <= 0.0 or win[1] >= self.OBS_WINDOW_S:
                        if win[1] > 0.0:
                            est[i].observe(win[0], win[1])
                        win[0], win[1] = 0, 0.0
                    if hedge_q:
                        observe_latency(i, ndata, elapsed)
                    if sink is None:
                        if not zero_copy:
                            buf[start:start + ndata] = reply.data
                    elif sink_writable is not None:
                        sink_commit(offset + start, ndata)
                    else:
                        sink(offset + start, reply.data)
                except BaseException:
                    # e.g. the user-supplied sink raised (disk full): the
                    # bytes were NOT delivered — reclaim the whole range
                    # and settle the in-flight count before propagating
                    await _reclaim(start, length, ban, count=False)
                    raise
                settled_won = False
                lost_hedge = None
                async with lock:
                    outstanding.pop(start, None)
                    if start in settled:
                        # a hedge beat this body to completion: its
                        # claim is already settled — heal the winner's
                        # bytes over this landing and count nothing
                        # toward progress (the full duplicate body is
                        # pure hedge waste)
                        _heal_settled(start)
                        reqs_per[name] += 1
                        hedge_wasted += ndata
                        settled_won = True
                        cond.notify_all()
                    else:
                        bytes_per[name] += ndata
                        reqs_per[name] += 1
                        done_bytes += ndata
                        inflight -= length
                        # the owner landed first: any still-running
                        # duplicate of this range can no longer win the
                        # race (the claim it would settle is gone) — so
                        # cancel it NOW rather than let a whole losing
                        # body stream to completion.  Mirror image of
                        # the winning hedge aborting its owner.
                        lost_hedge = hedged.get(start)
                        if ndata < length:   # truncated: short range —
                            # the tail re-enters the pool atomically with
                            # the inflight decrement so no peer can exit
                            # between
                            heapq.heappush(
                                pool, (start + ndata, length - ndata, ban))
                            pooled += length - ndata
                            cond.notify_all()
                        elif inflight <= 0:
                            cond.notify_all()
                if lost_hedge is not None and not lost_hedge[2].broken:
                    # break the loser's connection: its pending read
                    # turns into a prompt ConnectionError charging only
                    # the bytes it really landed (``partial_bytes``),
                    # and its worker reconnects without failure-budget
                    # cost (``hedge_broke``)
                    hedge_broke.add(lost_hedge[1])
                    lost_hedge[2].abort()
                if settled_won:
                    continue
                if journal is not None:
                    # committed: journal the interval (buffered append;
                    # fsync at the journal's checkpoint interval)
                    journal.record(offset + start, ndata, crc)
                if (tuner is not None and done_bytes < size
                        and not tune_state["busy"]
                        and done_bytes - tune_state["bytes"] >= tune_every):
                    # fire-and-forget: the triggering lane keeps fetching
                    # while the tuner (possibly jit-compiling) runs in
                    # the executor.  The busy flag is claimed HERE,
                    # synchronously, so no second lane can schedule a
                    # competing task (and overwrite the task ref the
                    # end-of-fetch drain awaits) before this one starts.
                    tune_state["busy"] = True
                    tune_state["task"] = asyncio.ensure_future(
                        maybe_retune())

        async def worker(i: int):
            """Per-replica supervisor: owns the connection, runs
            ``pipeline_depth`` lanes over it, and on failure re-pools are
            already done lane-side — it just counts the failure, backs
            off (capped exponential + jitter), reconnects, and respawns
            the lanes."""
            name = self.replicas[i].name
            failures = 0
            try:
                while True:
                    async with lock:
                        if fresh + pooled <= 0 and inflight <= 0:
                            return
                    conn = self._make_conn(self.replicas[i])
                    conn_of[i] = conn
                    lanes = [asyncio.ensure_future(pipe_lane(i, conn))
                             for _ in range(self.pipeline_depth)]
                    try:
                        outcomes = await asyncio.gather(
                            *lanes, return_exceptions=True)
                    finally:
                        for t in lanes:
                            t.cancel()
                        await asyncio.gather(*lanes, return_exceptions=True)
                        await conn.close()
                        for sample in conn.take_rtt_samples():
                            observe_rtt(i, sample)
                    fatal = [o for o in outcomes
                             if isinstance(o, BaseException)]
                    if fatal:
                        raise fatal[0]
                    if "corrupt-dead" in outcomes:
                        # retired for integrity (already in ``failed``)
                        return
                    if "broken" not in outcomes:
                        return
                    if i in hedge_broke:
                        # the break was a deliberate hedge cancellation,
                        # not a replica failure: reconnect straight away
                        # without charging the failure budget
                        hedge_broke.discard(i)
                        continue
                    failures += 1
                    if failures >= self.max_failures:
                        if name not in failed:
                            failed.append(name)
                        return
                    retries_per[name] += 1
                    self._on_retry(name)
                    if self.retry_after > 0:
                        # capped exponential backoff with ±50% jitter:
                        # repeated failures probe ever less often, and
                        # decorrelated delays keep N clients' reconnect
                        # storms from synchronizing on a recovering mirror
                        delay = min(self.retry_after * (2 ** (failures - 1)),
                                    self.retry_backoff_cap)
                        delay *= 0.5 + self._rng.random()
                        await asyncio.sleep(delay)
            finally:
                # parked peers key takeability off the live-replica set
                # (see ``alive``) — they must recheck when it shrinks
                async with lock:
                    alive.discard(i)
                    if avail[i] is not None:
                        # a dead peer's advertisement no longer counts:
                        # drop it from the union so its exclusive spans
                        # re-open to full replicas (the death-fallback)
                        avail[i] = []
                        _recompute_union()
                        cov_stamp[0] = time.monotonic()
                    cond.notify_all()

        async def _refresh_coverage(j: int) -> None:
            """Background poller for partial mirror ``j``: HEAD its
            advertisement every ``coverage_refresh_s`` on a throwaway
            connection (never the worker's data connection — a poll must
            not serialize behind a streaming body) and publish changes
            under the lock.  A missing header on a 200 means the peer now
            serves the whole window; 404/410 (the peer unbound its
            buffer) clears its coverage so nothing new is packed onto
            it."""
            rep = self.replicas[j]
            while True:
                async with lock:
                    if j not in alive or (fresh + pooled <= 0
                                          and inflight <= 0):
                        return
                runs = None
                conn = self._make_conn(rep)
                try:
                    code, headers = await conn.head()
                    if code == 200:
                        raw = headers.get("x-available-ranges")
                        if raw is None:
                            runs = [(0, size)]
                        else:
                            runs = []
                            for lo, hi in _parse_ranges_header(raw):
                                s_ = max(lo - offset, 0)
                                e_ = min(hi + 1 - offset, size)
                                if e_ > s_:
                                    runs.append((s_, e_))
                    elif code in (404, 410):
                        runs = []
                except (OSError, ValueError, asyncio.IncompleteReadError):
                    pass
                finally:
                    await conn.close()
                if runs is not None and runs != avail[j]:
                    async with lock:
                        if j in alive:
                            avail[j] = runs
                            _recompute_union()
                            cov_stamp[0] = time.monotonic()
                            cond.notify_all()
                await asyncio.sleep(refresh_s)

        workers = [asyncio.ensure_future(worker(i))
                   for i in range(len(self.replicas))]
        refreshers = [asyncio.ensure_future(_refresh_coverage(j))
                      for j in partial_idx]
        clock = asyncio.ensure_future(_stall_clock()) if hedge_q else None
        try:
            await asyncio.gather(*workers)
        except BaseException:
            # a fatal error (sink raise, cancellation) must not leave
            # sibling workers streaming into the buffer after fetch()
            # has already raised — cancel and drain them first
            for t in workers:
                t.cancel()
            await asyncio.gather(*workers, return_exceptions=True)
            task = tune_state["task"]
            if task is not None and not task.done():
                task.cancel()
            if journal is not None:
                journal.sync()
            raise
        finally:
            for t in refreshers:
                t.cancel()
            if refreshers:
                await asyncio.gather(*refreshers, return_exceptions=True)
            if clock is not None:
                clock.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await clock
        t_end = time.monotonic()
        # settle an in-flight tuner update BEFORE any raise, so no task
        # outlives the event loop: drain it on success (its adoption
        # isn't lost; transfer time excludes it), cancel it on failure
        task = tune_state["task"]
        if task is not None and not task.done():
            if done_bytes == size:
                await task
            else:
                task.cancel()
                try:
                    await task
                except asyncio.CancelledError:
                    pass
        if journal is not None:
            # everything committed so far is durable before we either
            # report success or raise (an incomplete transfer's journal
            # is exactly what the resume path replays)
            journal.sync()
        if done_bytes != size:
            raise TransferIncompleteError(
                f"transfer incomplete: {done_bytes}/{size} bytes "
                f"(failed replicas: {failed})",
                done_bytes=done_bytes, expected_bytes=size,
                failed_replicas=failed)
        if retunes > 0:
            # adaptation persists: the next fetch starts from the tuned
            # geometry instead of re-learning from the defaults.  Guarded
            # on actual adoptions — a tuner that never fired must not pin
            # this transfer's size-derived defaults onto future ones.
            self._params_arg = params_box[0]
        report = TransferReport(
            total_bytes=size, elapsed=t_end - t0,
            bytes_per_replica=bytes_per, requests_per_replica=reqs_per,
            failed_replicas=failed, refetched_ranges=refetched,
            retunes=retunes,
            observed_throughputs={
                r.name: float(est[i].value)
                for i, r in enumerate(self.replicas)
            },
            observed_rtts={
                r.name: float(rtt_min[i])
                for i, r in enumerate(self.replicas)
            },
            retries_per_replica=retries_per,
            corrupt_ranges=corrupt_per,
            resumed_bytes=resumed_bytes,
            resume_verify_seconds=resume_verify,
            hedges_issued=hedges_issued,
            hedges_won=hedges_won,
            hedge_wasted_bytes=hedge_wasted,
        )
        self.last_report = report
        return buf, report

    async def blob_size(self) -> int:
        """HEAD the first healthy replica for the blob size."""
        for r in self.replicas:
            conn = _Conn(r, read_timeout=self.read_timeout)
            try:
                code, headers = await conn.head()
                if code == 200:
                    return int(headers["content-length"])
            except (OSError, ValueError, KeyError):
                continue
            finally:
                await conn.close()
        raise IOError("no replica answered HEAD")


def fetch_blob(replicas: Sequence[Replica], size: Optional[int] = None,
               **kw) -> tuple[bytes, TransferReport]:
    """Synchronous convenience wrapper."""
    client = MDTPClient(replicas, **kw)

    async def run():
        nonlocal size
        if size is None:
            size = await client.blob_size()
        return await client.fetch(size)

    buf, report = asyncio.run(run())
    return bytes(buf), report
