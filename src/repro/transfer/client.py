"""Asyncio multi-source transfer client (the real MDTP runtime).

No aiohttp in this environment — this is a raw-socket HTTP/1.1 client on
asyncio's ``loop.sock_*`` primitives with:

* one persistent connection per replica (paper §III-A: avoid TCP slow-start
  and session re-establishment),
* **depth-k request pipelining** per connection: the next Range request is
  issued while the previous body is still streaming, so steady-state
  chunks do not pay a request RTT each (the CDTP-style overlap of request
  issue with in-flight body streaming — see PAPERS.md),
* a **zero-copy receive path**: the destination ``bytearray`` is
  preallocated and bodies are ``sock_recv_into`` memoryview slices of it —
  no per-chunk ``bytes`` materialization and no assembly copy,
* byte-range requests sized by the SAME allocator the simulator uses
  (``repro.core.chunking`` — single source of truth),
* per-chunk throughput observation feeding the next allocation (RTT bias
  removed at the observation point — see :func:`wire_elapsed`),
* **end-to-end integrity**: every range's CRC32 (the server's
  ``X-Range-Checksum`` header) is verified off the event loop as bodies
  land; a mismatching range is atomically re-pooled tagged "not this
  replica" so it re-fetches from an alternate mirror, and a chronically
  corrupt replica is retired like a dead one,
* **crash-resume**: ``fetch(resume=journal)`` replays an append-only
  :class:`~repro.transfer.journal.ResumeJournal`, re-verifies journaled
  range checksums against the destination, and requests only the
  uncovered intervals,
* failure handling: a replica that errors mid-chunk — or stalls past the
  per-read inactivity timeout — is retired (or retried with capped
  exponential backoff after ``retry_after``) and every range it still
  owes, including all pipelined in-flight requests, is atomically
  re-pooled for surviving peers (the checkpoint-restore path's fault
  tolerance).

Sink contract
-------------
``fetch(size, sink=...)`` accepts either:

* a callable ``sink(start, view)`` — ``view`` is a ``memoryview`` that is
  only valid DURING the call (the backing buffer is per-chunk scratch);
  a sink that wants to keep the bytes must copy before returning, or
* an object with ``writable(start, length) -> memoryview`` and
  ``commit(start, nbytes)`` — the client reads the socket directly into
  the returned view and calls ``commit`` once the bytes landed, so the
  path from socket to the sink's buffer is copy-free
  (``repro.checkpoint.manager._StreamingRestore`` implements this).

The client is transport-generic: anything exposing ``fetch_range`` works
(tests use the in-process ``RangeServer``; production would point at real
mirrors).
"""

from __future__ import annotations

import asyncio
import contextlib
import random
import time
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Optional, Sequence

from repro.core.chunking import ChunkParams, default_chunk_params
from repro.core.throughput import make_estimator, rtt_corrected_bandwidth
from repro.transfer.journal import merge_intervals
from repro.transfer.sched import ChunkScheduler, defaults as sched_defaults
# _Conn/_RangeReply re-exported here: the data pipeline and the fleet
# manager import them from this module (their historical home)
from repro.transfer.transport import _Conn, _RangeReply, _crc32_async

__all__ = ["Replica", "ClientOptions", "TransferReport", "MDTPClient",
           "NoTelemetryError", "TransferIncompleteError", "fetch_blob",
           "wire_elapsed", "DEFAULT_PIPELINE_DEPTH"]

#: default per-connection request pipeline depth.  2 keeps a request on
#: the wire while the previous body streams (the RTT-hiding that matters)
#: at minimal client-side concurrency — important because lane tasks
#: share one event loop and a loaded host inflates their scheduling
#: delays, which distorts throughput observations.  High-RTT paths gain
#: another ~10-20% from depth 4 (see benchmarks/dataplane_bench.py);
#: tune per deployment via ``MDTPClient(pipeline_depth=...)``.
DEFAULT_PIPELINE_DEPTH = sched_defaults.PIPELINE_DEPTH

#: endgame re-poll cadence (s) for lanes parked with hedging enabled: a
#: grayed-out mirror produces NO events to wake a parked lane (that is
#: the failure mode hedging exists for), so idle endgame lanes re-check
#: for straggling in-flight ranges on this period instead of waiting on
#: a notification that will never come.
_HEDGE_POLL_S = sched_defaults.HEDGE_POLL_S


class NoTelemetryError(RuntimeError):
    """``retune()`` had no usable observations to re-plan from (no
    completed fetch yet, or every replica failed/went unobserved).

    A dedicated type so callers that tolerate missing telemetry (the
    checkpoint-restore wave loop) don't have to catch blanket
    ``RuntimeError`` — which would also swallow real failures like
    jax's ``XlaRuntimeError`` from the fused sweep itself.
    """


class TransferIncompleteError(IOError):
    """``fetch()`` could not deliver every byte (all replicas failed or
    were retired for corruption before the pool drained).

    A dedicated type — previously this surfaced as a bare ``IOError``,
    and before that a short buffer could silently escape — so callers
    can distinguish "the transfer is incomplete, retry/resume it" from
    unrelated I/O failures.  Subclasses ``IOError`` for compatibility.
    """

    def __init__(self, message: str, *, done_bytes: int = 0,
                 expected_bytes: int = 0,
                 failed_replicas: Sequence[str] = ()):
        super().__init__(message)
        self.done_bytes = done_bytes
        self.expected_bytes = expected_bytes
        self.failed_replicas = list(failed_replicas)


@dataclass(frozen=True)
class Replica:
    host: str
    port: int
    path: str              # HTTP path of the blob on this mirror
    #: True = a PARTIAL peer mirror (a restoring node serving what it has
    #: so far): the client queries its ``X-Available-Ranges`` coverage,
    #: keeps refreshing it in the background, and only packs chunks the
    #: peer actually holds.  False (default) = an ordinary full mirror.
    mirror: bool = False

    @property
    def name(self) -> str:
        return f"{self.host}:{self.port}"


@dataclass(frozen=True)
class ClientOptions:
    """Consolidated :class:`MDTPClient` configuration.

    What used to be 15 bare constructor kwargs, grouped by concern.  The
    bare kwargs still work (``MDTPClient(reps, pipeline_depth=3)`` —
    they are folded into an options instance, overriding it field by
    field), so existing call sites don't change; new code should prefer
    ``MDTPClient(reps, options=ClientOptions(...))``.
    """

    # -- allocation & estimation ------------------------------------------
    #: chunk geometry; None = size-derived defaults per fetch.
    params: Optional[ChunkParams] = None
    #: throughput estimator kind (``repro.core.throughput``).
    estimator: str = "ewma"
    ewma_alpha: float = 0.5
    #: default online tuner (``repro.core.online`` contract: an object
    #: with ``update(telemetry) -> ChunkParams | None``) applied to every
    #: ``fetch`` unless overridden per call.
    tuner: object = None

    # -- pipeline / zero-copy data plane ----------------------------------
    #: concurrent pipelined requests per replica connection (>= 1;
    #: 1 = the serial request-response data plane).
    pipeline_depth: int = DEFAULT_PIPELINE_DEPTH
    #: False = legacy copy path (bodies materialize as ``bytes`` and are
    #: copied into place) — kept as the benchmark baseline and an escape
    #: hatch; the default receives into the destination buffer.
    zero_copy: bool = True
    #: emulated request-path delay per request (see ``_Conn``).
    request_latency: float = 0.0
    #: False = legacy half-duplex connections (request writes serialize
    #: inline behind the write lock instead of draining through the
    #: independent writer coroutine) — kept as the benchmark baseline
    #: the duplex win-guard measures against.
    duplex: bool = True

    # -- integrity / retry / timeout --------------------------------------
    #: verify each range's CRC32 against the server's
    #: ``X-Range-Checksum`` header and re-fetch mismatches from an
    #: alternate mirror.  Servers that don't send the header are simply
    #: not verified (no error).
    verify_integrity: bool = True
    #: seconds before retrying a failed replica (0 = retire immediately).
    retry_after: float = 0.0
    #: connection/corruption failures before a replica is retired.
    max_failures: int = 3
    #: per-read inactivity timeout (seconds; 0 disables) applied to every
    #: connection — see ``_Conn.read_timeout``.
    read_timeout: float = 30.0
    #: ceiling (seconds) on the exponential dead-replica retry backoff:
    #: attempt k waits ``min(retry_after * 2**(k-1), cap)`` scaled by
    #: ±50% jitter so reconnect storms decorrelate.
    retry_backoff_cap: float = 5.0

    # -- endgame hedging ---------------------------------------------------
    #: straggler quantile for speculative endgame duplicates (0 disables;
    #: see the ``MDTPClient`` docs for the full trigger conditions).
    hedge_quantile: float = 0.0
    #: hard cap on hedge waste as a fraction of the transfer size.
    hedge_waste_frac: float = sched_defaults.HEDGE_WASTE_FRAC

    # -- peer mirrors ------------------------------------------------------
    #: background coverage-refresh cadence (seconds) for partial peer
    #: replicas (``Replica.mirror``): how often each peer's
    #: ``X-Available-Ranges`` is re-queried during a fetch.
    coverage_refresh_s: float = 0.05

    # -- misc --------------------------------------------------------------
    #: randomness source for reconnect-backoff jitter — pass a seeded
    #: ``random.Random`` to make chaos-test retry timing reproducible;
    #: None = the module-global generator.
    rng: Optional[random.Random] = None


def _parse_ranges_header(raw: str) -> list:
    """``X-Available-Ranges`` value -> list of inclusive ``(lo, hi)``
    pairs (empty list for an empty advertisement)."""
    out = []
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        lo_s, _, hi_s = part.partition("-")
        out.append((int(lo_s), int(hi_s)))
    return out


@dataclass
class TransferReport:
    total_bytes: int
    elapsed: float
    bytes_per_replica: dict
    requests_per_replica: dict
    failed_replicas: list
    refetched_ranges: int
    #: number of mid-transfer tuner adoptions (``fetch(tuner=...)``) — 0
    #: for un-tuned transfers.
    retunes: int = 0
    #: final per-replica estimator values (bytes/s; 0 = never observed) —
    #: the live inputs the autotuner re-tunes chunk sizes from.  These are
    #: WIRE rates: the per-request RTT bias is already removed at the
    #: observation point (:func:`wire_elapsed`), so consumers must not
    #: apply ``rtt_corrected_bandwidth`` again.
    observed_throughputs: dict = field(default_factory=dict)
    #: measured per-replica request RTT in seconds (min over connect time
    #: and idle-pipe header turnarounds; 0 = never measured).  Feeds
    #: ``retune`` so the simulated sweep uses live latencies, not a
    #: guessed constant.
    observed_rtts: dict = field(default_factory=dict)
    #: per-replica count of connection-level retries (reconnect after a
    #: break/stall, with capped exponential backoff between attempts).
    retries_per_replica: dict = field(default_factory=dict)
    #: per-replica count of ranges that failed checksum verification and
    #: were re-fetched from an alternate mirror.
    corrupt_ranges: dict = field(default_factory=dict)
    #: bytes satisfied from the resume journal instead of the wire
    #: (``fetch(resume=...)``); 0 for fresh transfers.
    resumed_bytes: int = 0
    #: seconds spent re-verifying journaled range checksums during resume
    #: replay (large records hash in the executor); 0.0 for fresh fetches.
    resume_verify_seconds: float = 0.0
    #: endgame hedges (``hedge_quantile`` > 0): speculative duplicate
    #: fetches issued for straggling in-flight ranges, and how many beat
    #: their original copy to completion.
    hedges_issued: int = 0
    hedges_won: int = 0
    #: duplicated bytes the losing copies cost.  Cancellation is
    #: symmetric — whichever side lands first breaks the other's
    #: connection — so each losing copy is charged the bytes it actually
    #: received before the race resolved, not its whole range.
    hedge_wasted_bytes: int = 0

    @property
    def throughput(self) -> float:
        return self.total_bytes / self.elapsed if self.elapsed > 0 else 0.0


def wire_elapsed(nbytes: int, elapsed: float, rtt: float) -> float:
    """Strip the request RTT from a serial chunk observation.

    A request issued on an idle pipe spans ``rtt + nbytes / wire_rate``
    seconds, so feeding ``(nbytes, elapsed)`` straight into an estimator
    under-states the wire rate — badly for small chunks on high-RTT paths.
    A *pipelined* request's elapsed starts when its body starts streaming
    and needs no correction; this helper is applied only to observations
    flagged as RTT-inclusive.  Delegates the guard logic (no RTT sample,
    implied non-positive wire time) to
    :func:`repro.core.throughput.rtt_corrected_bandwidth`, returning the
    elapsed unchanged when the correction is impossible.
    """
    if elapsed <= 0.0 or nbytes <= 0:
        return elapsed
    corrected = rtt_corrected_bandwidth(nbytes / elapsed, rtt, float(nbytes))
    return nbytes / corrected if corrected > 0.0 else elapsed


class MDTPClient:
    """Downloads one blob from N replicas with MDTP adaptive chunking."""

    def __init__(
        self,
        replicas: Sequence[Replica],
        params: Optional[ChunkParams] = None,
        options: Optional[ClientOptions] = None,
        **kw,
    ):
        """``options`` is the consolidated configuration
        (:class:`ClientOptions`, grouped and documented there); any bare
        keyword from the historical 15-kwarg constructor is still
        accepted and overrides the corresponding options field — the
        compatibility shim that keeps every existing call site (and the
        fleet manager's ``**client_kw`` forwarding) working unchanged.
        An unknown keyword raises ``TypeError`` exactly as before."""
        if options is None:
            try:
                options = ClientOptions(**kw)
            except TypeError as e:
                raise TypeError(f"MDTPClient: {e}") from None
        elif kw:
            options = _dc_replace(options, **kw)
        if params is not None:
            options = _dc_replace(options, params=params)
        #: the resolved configuration (read-only snapshot).
        self.options = options
        self.replicas = list(replicas)
        self._params_arg = options.params
        self._estimator = options.estimator
        self._alpha = options.ewma_alpha
        self.retry_after = options.retry_after
        self.max_failures = options.max_failures
        self.tuner = options.tuner
        self.pipeline_depth = max(int(options.pipeline_depth), 1)
        self.zero_copy = options.zero_copy
        self.request_latency = options.request_latency
        self.duplex = options.duplex
        self.verify_integrity = options.verify_integrity
        self.read_timeout = options.read_timeout
        self.retry_backoff_cap = options.retry_backoff_cap
        #: endgame hedging (0 disables): once the residual drops below
        #: ~2 allocator rounds, an idle lane speculatively duplicates an
        #: in-flight range whose owner's per-byte latency EWMA sits at or
        #: above this fleet quantile (or whose range has aged well past
        #: the owner's own expected service time — the grayed-out-mirror
        #: case, where the EWMA goes stale).  First completion wins; the
        #: loser is cancelled/discarded with byte accounting on the
        #: report (``hedges_issued`` / ``hedges_won`` /
        #: ``hedge_wasted_bytes``).  Applies only when assembling
        #: in-memory (``sink=None``): hedge bodies land in private
        #: scratch, never the destination, so a losing or corrupt copy
        #: cannot touch committed bytes.
        self.hedge_quantile = float(options.hedge_quantile)
        #: hard cap on hedge waste as a fraction of the transfer size: a
        #: hedge is only issued while committed waste plus every
        #: in-flight hedge's reserved length stays under this budget —
        #: each race can waste at most its own range, whichever side
        #: loses, so ``hedge_wasted_bytes <= hedge_waste_frac * size``
        #: holds by construction.
        self.hedge_waste_frac = float(options.hedge_waste_frac)
        self.coverage_refresh_s = float(options.coverage_refresh_s)
        self._rng = options.rng if options.rng is not None else random
        #: report of the most recent ``fetch`` (None before the first one).
        self.last_report: Optional[TransferReport] = None
        #: set to a list to record the next fetch's scheduler decision
        #: trace (``repro.transfer.sched.replay`` re-drives it; the
        #: decision-parity test in tests/test_sched.py uses this hook).
        self._sched_trace: Optional[list] = None

    #: fallback request RTT (s) for replicas that never produced a sample —
    #: ~WAN RTT between FABRIC sites, matching the simulator scenarios.
    DEFAULT_RTT = sched_defaults.DEFAULT_RTT

    #: minimum contiguous streaming time (s) aggregated into one
    #: throughput observation — see the observation-window comment in
    #: ``fetch``.
    OBS_WINDOW_S = sched_defaults.OBS_WINDOW_S

    def retune(self, file_size: int, **autotune_kw):
        """Re-tune chunk sizes from the last transfer's live observations.

        Runs the fused on-device grid sweep (``repro.core.autotune`` — one
        compiled call for the whole (C, L) × seed lattice) against the
        per-replica throughputs AND measured request RTTs observed during
        the previous ``fetch`` and adopts the winning ``ChunkParams`` for
        subsequent transfers.  Typical use: between checkpoint-restore
        waves, where mirror conditions drift but the replica set is stable.

        The client's own ``pipeline_depth`` is passed to the sweep (unless
        overridden) so the simulated request-latency amortization matches
        what this runtime actually does on the wire; likewise an observed
        corruption rate (re-fetched ranges / requests) is folded in so the
        sweep's (C, L) pays the same re-fetch overhead the wire did.

        Returns the ``AutotuneResult``; raises if no transfer has been
        observed yet or no replica produced a throughput sample.
        """
        from repro.core.autotune import autotune_chunk_params

        if self.last_report is None:
            raise NoTelemetryError("retune() needs a completed fetch() first")
        # Replicas with no sample (failed / never dispatched) are excluded,
        # mirroring how fetch() retires them — a 0-throughput entry would
        # otherwise dominate every simulated grid point.  RTTs stay aligned
        # with the surviving bandwidth entries.  Estimates are already wire
        # rates (the RTT bias is stripped per observation, see
        # ``wire_elapsed``), so they feed the sweep directly.
        rep = self.last_report
        bw, rtts = [], []
        for r in self.replicas:
            b = rep.observed_throughputs.get(r.name, 0.0)
            if b <= 0.0:
                continue
            rtt = rep.observed_rtts.get(r.name, 0.0)
            bw.append(b)
            rtts.append(rtt if rtt > 0.0 else self.DEFAULT_RTT)
        if not bw:
            raise NoTelemetryError("no throughput observations to retune from")
        autotune_kw.setdefault("rtt", rtts)
        autotune_kw.setdefault("pipeline_depth", self.pipeline_depth)
        total_reqs = sum(rep.requests_per_replica.values())
        total_corrupt = sum(rep.corrupt_ranges.values())
        if total_corrupt > 0 and total_reqs > 0:
            autotune_kw.setdefault(
                "corruption_rate", min(total_corrupt / total_reqs, 0.5))
            # a single seed sees one fault realization; average a few
            autotune_kw.setdefault("n_seeds", 4)
        res = autotune_chunk_params(bw, file_size=int(file_size),
                                    **autotune_kw)
        self._params_arg = res.params
        return res

    def adopt_params(self, params: ChunkParams) -> None:
        """Adopt chunk geometry for subsequent transfers.

        The public hook for external re-tuning loops (e.g. the
        checkpoint-restore wave loop feeding an online tuner between
        waves); ``fetch(tuner=...)`` and ``retune`` adopt internally.
        """
        self._params_arg = params

    def _make_conn(self, replica: Replica) -> "_Conn":
        """Connection factory — subclasses may translate offsets (the data
        pipeline's virtual-blob client) or wrap requests (the fleet
        manager's capped, telemetry-fed connections)."""
        return _Conn(replica, request_latency=self.request_latency,
                     read_timeout=self.read_timeout, duplex=self.duplex)

    def _allocation_throughputs(self, est_values: list) -> list:
        """Per-replica throughput vector the allocator sizes chunks from.

        Default: this transfer's own estimator values.  The fleet manager
        (``repro.transfer.manager``) overrides this to pack each round
        into *residual* replica capacity — fleet bandwidth minus what
        other concurrent transfers are consuming — so co-scheduled
        transfers don't all plan as if they owned the mirrors.
        """
        return est_values

    def _on_corruption(self, name: str) -> None:
        """Integrity-failure hook: called once per checksum-mismatched
        range, outside the transfer lock.  The fleet manager overrides
        this to feed per-replica corruption counters into the
        ``FleetModel`` so chronically corrupt replicas are deprioritized
        fleet-wide, not just within this transfer."""

    def _on_retry(self, name: str) -> None:
        """Connection-retry hook: called once per reconnect-with-backoff
        attempt (a break, stall, or reset that the worker survives).  The
        fleet manager overrides this to feed retry counts into the
        ``FleetModel``'s probation thresholds — a replica that keeps
        costing reconnects goes on probation fleet-wide."""

    async def fetch(self, size: int, sink=None, *, offset: int = 0,
                    tuner=None, tune_interval_bytes: Optional[int] = None,
                    resume=None, into: Optional[bytearray] = None,
                    stripe: Optional[tuple] = None,
                    ) -> tuple[Optional[bytearray], TransferReport]:
        """Fetch ``size`` bytes.  ``sink`` (if given) receives ranges as
        they land — see the module docstring for the two sink protocols
        (callable receiving transient memoryviews, or ``writable``/
        ``commit`` for the copy-free path); otherwise an in-memory buffer
        is assembled (and received into directly — zero-copy).  ``into``
        supplies that buffer (``len(into) >= size``) instead of a fresh
        allocation — resume needs the previous attempt's bytes in place.

        ``offset`` shifts every byte-range request (and the ``sink`` start
        offsets) by a constant — a wave of a larger blob fetches
        ``[offset, offset + size)`` while the internal frontier/pool stay
        0-based (the checkpoint-restore wave loop uses this).

        ``resume`` (a :class:`~repro.transfer.journal.ResumeJournal`)
        replays previously committed intervals: each journaled record
        inside this fetch's window is re-verified against the destination
        (its CRC32 — data that never reached stable storage fails and is
        re-fetched), verified bytes are counted done without touching the
        wire, and every NEW committed range is appended to the journal
        (fsync'd at the journal's checkpoint interval).  The journal is
        left open; call ``complete()`` on it after the overall operation
        (which may span several waves) succeeds.

        Raises :class:`TransferIncompleteError` if the surviving replicas
        could not deliver every byte — a short buffer never escapes.

        ``tuner`` (default: the client's ``tuner``) re-tunes chunk
        geometry mid-transfer: every ``tune_interval_bytes`` delivered
        bytes the client snapshots live telemetry (per-replica estimator
        values + measured RTTs, achieved window throughput) into a
        ``repro.core.online.Telemetry`` and adopts whatever ``ChunkParams``
        the tuner returns — workers pick up the new geometry on their next
        allocation.  The tuner runs in a thread-pool executor so its
        (possibly jit-compiling) sweep never stalls the event loop; at
        most one update is in flight at a time.  Adopted params persist on
        the client for subsequent transfers, and ``report.retunes`` counts
        the adoptions.

        ``stripe=(k, n)`` rotates the fresh-byte frontier to start at
        ``size * k // n`` (wrapping) instead of 0.  In a swarm of ``n``
        restorers this de-correlates what each node fetches FIRST, so
        peers become useful sources for each other almost immediately —
        everyone starting at byte 0 would race the origin for the same
        prefix and have nothing to trade.  Purely an ordering hint:
        every byte is still fetched exactly once.

        Replicas flagged ``mirror=True`` are PARTIAL peer mirrors: their
        advertised coverage (``X-Available-Ranges``) is polled in the
        background every ``coverage_refresh_s`` and chunks are packed
        onto a peer only when its advertisement covers them; full
        replicas meanwhile prefer spans no live peer holds yet (origin
        offload).  A fetch whose only surviving sources are partial
        mirrors that cannot cover the remaining bytes gives up with
        :class:`TransferIncompleteError` once their joint coverage has
        been static for a patience window, instead of waiting forever.
        """
        n = len(self.replicas)
        depth = self.pipeline_depth
        est = [make_estimator(self._estimator, self._alpha) for _ in range(n)]
        # per-replica [bytes, seconds] observation windows: back-to-back
        # pipelined replies carry wildly noisy per-reply timings (a body
        # the kernel buffered ahead reads in microseconds, the next one
        # absorbs the wait), but their SUM over a contiguous streaming
        # window is exact — so samples are aggregated until the window
        # holds enough signal, then fed to the estimator as one reading
        obs_win = [[0, 0.0] for _ in range(n)]
        zero_copy = self.zero_copy
        if sink is not None and into is not None:
            raise TypeError("into= only applies when assembling in-memory "
                            "(sink is None)")
        if into is not None and len(into) < size:
            raise ValueError(f"into buffer ({len(into)} B) smaller than "
                             f"transfer size ({size} B)")
        buf = (into if into is not None else bytearray(size)) \
            if sink is None else None
        sink_writable = getattr(sink, "writable", None)
        sink_commit = getattr(sink, "commit", None)
        if (sink_writable is None) != (sink_commit is None):
            raise TypeError(
                "zero-copy sinks must provide BOTH writable() and commit()")

        verify = self.verify_integrity
        journal = resume
        need_crc = verify or journal is not None

        # the decision brain: every allocation, hedge, and repool choice
        # lives in the sans-I/O ``ChunkScheduler`` (repro.transfer.sched)
        # — this method is transport glue that drives it under ``lock``
        # and performs the I/O its results prescribe.  Scratch-buffer
        # hedges need a readable destination to commit to, so hedging is
        # in-memory-assembly only (see __init__).
        sched = ChunkScheduler(
            size, [r.mirror for r in self.replicas],
            params=self._params_arg or default_chunk_params(size),
            depth=depth,
            hedge_quantile=self.hedge_quantile if sink is None else 0.0,
            hedge_waste_frac=self.hedge_waste_frac,
            default_rtt=self.DEFAULT_RTT,
            max_failures=self.max_failures,
            coverage_refresh_s=self.coverage_refresh_s,
            stripe=stripe, trace=self._sched_trace)
        hedge_q = sched.hedge_quantile
        refresh_s = sched.refresh_s

        lock = asyncio.Lock()
        #: signalled whenever reclaimed work appears or in-flight bytes
        #: drain to zero — a lane with nothing to draw parks here instead
        #: of polling (it must stay alive while peers owe ranges: if a
        #: peer's replica dies, its range returns to the pool and needs a
        #: surviving taker — the mirror-death fault-tolerance contract).
        cond = asyncio.Condition(lock)
        resumed_bytes = 0
        resume_verify = 0.0

        if journal is not None:
            # Replay: every journaled record inside this window whose
            # bytes still verify is covered; everything else re-fetches.
            # Verification needs a readable destination — the assembly
            # buffer or a writable() sink view; callable sinks can't be
            # read back, so their records are trusted as journaled.
            def _view_of(abs_start: int, nb: int):
                if buf is not None:
                    lo = abs_start - offset
                    return memoryview(buf)[lo:lo + nb]
                if sink_writable is not None:
                    return sink_writable(abs_start, nb)
                return None

            verified: list[tuple[int, int]] = []
            t_verify = time.monotonic()
            for s_abs, nb, rcrc in journal.records():
                if s_abs < offset or s_abs + nb > offset + size:
                    continue
                v = _view_of(s_abs, nb)
                if v is not None and rcrc is not None \
                        and await _crc32_async(v) != rcrc:
                    continue
                verified.append((s_abs - offset, nb))
            resume_verify = time.monotonic() - t_verify
            covered = merge_intervals(verified)
            resumed_bytes = sched.seed_resume(covered)
            if sink_commit is not None:
                # drive the sink's covered-interval accounting so resumed
                # regions materialize exactly like freshly landed ones
                for s_, n_ in covered:
                    sink_commit(offset + s_, n_)

        t0 = time.monotonic()

        tuner = tuner if tuner is not None else self.tuner
        retunes = 0
        # telemetry cadence: a handful of updates per transfer by default,
        # but never finer than a couple of large chunks' worth of signal
        tune_every = tune_interval_bytes or max(
            size // 8, 2 * sched.params.large_chunk)
        tune_state = {"bytes": sched.done_bytes, "t": t0, "busy": False,
                      "task": None}

        def _failed_names() -> list:
            """Retired replica names in retirement order, deduped — the
            report and the giving-up error are name-keyed while the
            scheduler tracks indices."""
            names: list = []
            for k in sched.failed:
                nm = self.replicas[k].name
                if nm not in names:
                    names.append(nm)
            return names

        def _telemetry_bandwidths() -> tuple:
            """Full-fleet positional wire-rate vector for ``Telemetry``:
            estimator values (already RTT-de-biased at observation time),
            dead replicas zeroed in place."""
            bad = set(_failed_names())
            return tuple(
                0.0 if r.name in bad else float(est[i].value)
                for i, r in enumerate(self.replicas))

        async def maybe_retune():
            """Snapshot telemetry and let the tuner re-plan (at most one
            update in flight — the trigger site claims the busy flag
            BEFORE scheduling, so a second trigger can't race in between;
            runs in an executor so jit compiles inside the tuner don't
            stall the event loop)."""
            nonlocal retunes
            try:
                try:
                    from repro.core.online import Telemetry

                    now = time.monotonic()
                    window_bytes = sched.done_bytes - tune_state["bytes"]
                    window_t = max(now - tune_state["t"], 1e-9)
                    telemetry = Telemetry(
                        bandwidth=_telemetry_bandwidths(),
                        rtt=tuple(float(x) for x in sched.rtt_min),
                        remaining_bytes=float(size - sched.done_bytes),
                        measured_throughput=window_bytes / window_t,
                        elapsed=now - t0,
                    )
                    loop = asyncio.get_running_loop()
                    new = await loop.run_in_executor(None, tuner.update,
                                                     telemetry)
                except asyncio.CancelledError:
                    raise
                except Exception:
                    # a failing tuner path (the lazy online import in a
                    # jax-less deployment, a bad jit compile, a tuner
                    # bug) must never fail a transfer whose bytes are
                    # flowing fine — keep the current geometry, carry on
                    new = None
                tune_state["bytes"] = sched.done_bytes
                tune_state["t"] = time.monotonic()
                if new is not None:
                    sched.adopt_params(new)
                    retunes += 1
            finally:
                tune_state["busy"] = False

        # -- endgame-hedging transport state ------------------------------
        #: start -> the connection streaming a duplicate of that range
        #: (what an owner that lands first breaks to cancel the race).
        hedge_conns: dict = {}
        #: owner indices whose connection was broken ON PURPOSE to cancel
        #: a lost race — the worker reconnects without charging its
        #: failure budget.
        hedge_broke: set = set()
        #: replica index -> the connection its worker currently runs
        #: lanes on (so a winning hedge can break the loser's connection
        #: and turn its pending read into a prompt error).
        conn_of: dict = {}

        async def _stall_clock() -> None:
            """Heartbeat feeding the scheduler's stall meter: each sleep
            should wake after ``_HEDGE_POLL_S``; waking well past twice
            that means the event loop (and so every lane) was starved,
            and the overshoot is time stolen from ALL owners at once,
            not evidence against any one of them."""
            prev = time.monotonic()
            while True:
                await asyncio.sleep(_HEDGE_POLL_S)
                t = time.monotonic()
                if t - prev > 2.0 * _HEDGE_POLL_S:
                    sched.add_stall((t - prev) - _HEDGE_POLL_S)
                prev = t

        def _abort_hedge(start: int, hedger) -> None:
            """Actively cancel a doomed duplicate the scheduler flagged:
            breaking its connection turns the pending read into a prompt
            ConnectionError charging only the bytes it really landed,
            and ``hedge_broke`` lets its worker reconnect without
            failure-budget cost."""
            if hedger is None:
                return
            c = hedge_conns.get(start)
            if c is not None and not c.broken:
                hedge_broke.add(hedger)
                c.abort()

        async def _reclaim(start: int, length: int, ban: frozenset, *,
                           count: bool, lost: int = 0) -> None:
            """Return an owed range to the scheduler atomically, waking
            parked lanes, then perform whatever healing/cancellation it
            prescribes (a settled range heals the winner's bytes back; a
            duplicate still racing the reclaimed range is aborted)."""
            async with lock:
                res = sched.on_reclaim(start, length, ban,
                                       count=count, lost=lost)
                if res.heal is not None and buf is not None:
                    buf[start:start + len(res.heal)] = res.heal
                cond.notify_all()
            _abort_hedge(start, res.cancel_hedger)

        async def hedge_fetch(j: int, conn: "_Conn", start: int,
                              length: int, owner: int,
                              ban: frozenset) -> Optional[str]:
            """Speculatively duplicate an in-flight range onto replica
            ``j``, into PRIVATE scratch — never the destination, so a
            corrupt or losing body cannot touch committed bytes.  First
            completion wins (``sched.on_hedge_result`` adjudicates), and
            cancellation is symmetric: a winning hedge breaks the
            loser's connection, while an owner that lands first breaks
            THIS one.  Returns a lane outcome to propagate, or None to
            carry on."""
            name = self.replicas[j].name
            scratch = bytearray(length)
            try:
                reply = await conn.fetch_range(
                    offset + start, offset + start + length - 1,
                    into=memoryview(scratch) if zero_copy else None)
            except (ConnectionError, OSError,
                    asyncio.IncompleteReadError) as e:
                # broken mid-copy — usually the owner landing first and
                # cancelling this race.  Whatever the duplicate DID land
                # is real duplicated traffic and charges the waste meter.
                async with lock:
                    sched.on_hedge_abandon(
                        start, wasted=getattr(e, "partial_bytes", 0))
                    hedge_conns.pop(start, None)
                return "broken"
            except BaseException:
                async with lock:
                    sched.on_hedge_abandon(start)
                    hedge_conns.pop(start, None)
                raise
            ndata = reply.nbytes
            for sample in conn.take_rtt_samples():
                sched.observe_rtt(j, sample)
            body = scratch[:ndata] if zero_copy else reply.data
            crc = await _crc32_async(body) if need_crc else None
            if verify and reply.crc32 is not None and crc != reply.crc32:
                async with lock:
                    dead = sched.on_hedge_corrupt(j, start)
                    hedge_conns.pop(start, None)
                self._on_corruption(name)
                if dead:
                    conn.broken = True
                    return "corrupt-dead"
                return None
            sched.observe_latency(j, ndata, reply.elapsed)
            o_conn = None
            async with lock:
                res = sched.on_hedge_result(j, start, length, ndata, body)
                hedge_conns.pop(start, None)
                if res.won:
                    # hedge wins: commit from scratch; the scheduler
                    # keeps the bytes so a late-landing loser body can
                    # be healed back over
                    if buf is not None:
                        buf[start:start + ndata] = body
                    o_conn = conn_of.get(res.cancel_owner)
                    if journal is not None:
                        journal.record(offset + start, ndata, crc)
                    cond.notify_all()
            if o_conn is not None and not o_conn.broken:
                # actively cancel the loser: breaking its connection
                # turns the pending read into a prompt ConnectionError
                # instead of waiting out the straggler
                hedge_broke.add(res.cancel_owner)
                o_conn.abort()
            return None

        async def pipe_lane(i: int, conn: "_Conn") -> str:
            """One pipelined request lane on replica ``i``'s shared
            connection.  Up to ``pipeline_depth`` lanes run per replica;
            their concurrent ``fetch_range`` calls are what keeps k
            requests on the wire.  Returns ``"done"`` when the transfer
            has no work left, ``"broken"`` on a connection failure (the
            owed range is already back in the pool), ``"corrupt-dead"``
            when this replica crossed the corruption cap and was
            retired."""
            name = self.replicas[i].name

            async def _park() -> None:
                """Wait for pool/in-flight changes; with hedging on (or
                partial mirrors in play) wake periodically anyway — a
                grayed-out straggler generates no events, and a peer
                whose coverage went static fires no notifications either,
                so only a poll can spot an aging range or conclude the
                remaining work is uncoverable."""
                if not hedge_q and not sched.partial_idx:
                    await cond.wait()
                    return
                with contextlib.suppress(asyncio.TimeoutError):
                    await asyncio.wait_for(
                        cond.wait(),
                        _HEDGE_POLL_S if hedge_q else refresh_s)

            while True:
                if conn.broken:
                    # a sibling lane hit the failure first; don't draw
                    # work a doomed request would just bounce back
                    return "broken"
                hedge = None
                async with lock:
                    while True:
                        if conn.broken:
                            # woke from cond.wait to a sibling's failure:
                            # don't draw a range a doomed send would just
                            # bounce back (and spuriously count as
                            # refetched)
                            return "broken"
                        if sched.remaining <= 0:
                            if sched.inflight <= 0:
                                return "done"
                            hedge = sched.pick_hedge(i)
                            if hedge is not None:
                                break
                            await _park()
                            continue
                        if not sched.can_draw(i):
                            # nothing this replica may serve right now —
                            # park until the pool or an advertisement
                            # changes (or hedge a straggler meanwhile)...
                            # unless no possible source remains.
                            if sched.hopeless():
                                cond.notify_all()
                                return "done"
                            hedge = sched.pick_hedge(i)
                            if hedge is not None:
                                break
                            await _park()
                            continue
                        break
                    if hedge is not None:
                        h_start, h_len, h_owner, h_ban = hedge
                        sched.on_hedge_issue(i, h_start, h_len)
                        hedge_conns[h_start] = conn
                if hedge is not None:
                    outcome = await hedge_fetch(i, conn, h_start, h_len,
                                                h_owner, h_ban)
                    if outcome is not None:
                        return outcome
                    continue
                async with lock:
                    if conn.broken:
                        return "broken"
                    if sched.remaining <= 0:
                        continue
                    if not sched.can_draw(i):
                        continue
                    want = sched.next_want(
                        i, self._allocation_throughputs(
                            [e.value for e in est]))
                    if want <= 0:
                        return "done"
                    asn = sched.on_assign(i, want)
                    if asn is None:
                        # the pool/advertisement shifted between the two
                        # lock sections — go around and re-evaluate
                        continue
                    start, length, ban, prog = asn
                # destination: straight into the assembly buffer / the
                # sink's own storage (zero-copy), or per-chunk scratch
                # for callable sinks / the legacy copy path.  A raising
                # ``writable()`` must reclaim like any other failure —
                # the range is already counted in flight.
                try:
                    if sink is None:
                        mv = (memoryview(buf)[start:start + length]
                              if zero_copy else None)
                    elif sink_writable is not None:
                        mv = sink_writable(offset + start, length)
                    else:
                        mv = (memoryview(bytearray(length))
                              if zero_copy else None)
                except BaseException:
                    await _reclaim(start, length, ban, count=False)
                    raise
                try:
                    reply = await conn.fetch_range(
                        offset + start, offset + start + length - 1,
                        into=mv, progress=prog)
                except (ConnectionError, OSError,
                        asyncio.IncompleteReadError) as e:
                    await _reclaim(start, length, ban, count=True,
                                   lost=getattr(e, "partial_bytes", 0))
                    return "broken"
                except BaseException:
                    # cancellation / unexpected error: release the range
                    # so peers waiting on in-flight work aren't stranded
                    await _reclaim(start, length, ban, count=False)
                    raise
                try:
                    ndata = reply.nbytes
                    for sample in conn.take_rtt_samples():
                        sched.observe_rtt(i, sample)
                    crc = None
                    if need_crc:
                        # off the event loop for big bodies; the range is
                        # exclusively ours until committed or re-pooled,
                        # so hashing it unlocked is safe
                        crc = await _crc32_async(reply.data)
                    if (verify and reply.crc32 is not None
                            and crc != reply.crc32):
                        # corrupt body: the bytes never count — the
                        # scheduler re-pools the WHOLE range tagged "not
                        # this replica" (or heals a settled one), and we
                        # abort any duplicate it says is doomed
                        async with lock:
                            res = sched.on_corrupt(i, start, length, ban,
                                                   ndata)
                            if res.heal is not None and buf is not None:
                                buf[start:start + len(res.heal)] = \
                                    res.heal
                            cond.notify_all()
                        _abort_hedge(start, res.cancel_hedger)
                        self._on_corruption(name)
                        if res.dead:
                            # chronically corrupt = retired, like a dead
                            # mirror; breaking the shared conn stops
                            # sibling lanes too
                            conn.broken = True
                            return "corrupt-dead"
                        continue
                    # estimators track the WIRE rate: serial observations
                    # have their request RTT stripped here, pipelined ones
                    # already measure pure body-streaming time.  Encoded
                    # bodies count WIRE bytes (the framed payload), not
                    # decoded bytes — coverage/commit below still moves in
                    # decoded bytes, which is exactly the split that keeps
                    # compression from double-counting as bandwidth.
                    nwire = reply.wire_bytes
                    elapsed = reply.elapsed
                    if reply.rtt_included:
                        elapsed = wire_elapsed(nwire, elapsed,
                                               sched.rtt_min[i])
                    win = obs_win[i]
                    win[0] += nwire
                    win[1] += elapsed
                    # flush on the first-ever sample (ends probe mode
                    # promptly — it is a serial, RTT-stripped reading) or
                    # once the window holds enough streaming time for a
                    # stable rate
                    if est[i].value <= 0.0 or win[1] >= self.OBS_WINDOW_S:
                        if win[1] > 0.0:
                            est[i].observe(win[0], win[1])
                        win[0], win[1] = 0, 0.0
                    if hedge_q:
                        sched.observe_latency(i, ndata, elapsed)
                    if sink is None:
                        if not zero_copy:
                            buf[start:start + ndata] = reply.data
                    elif sink_writable is not None:
                        sink_commit(offset + start, ndata)
                    else:
                        sink(offset + start, reply.data)
                except BaseException:
                    # e.g. the user-supplied sink raised (disk full): the
                    # bytes were NOT delivered — reclaim the whole range
                    # and settle the in-flight count before propagating
                    await _reclaim(start, length, ban, count=False)
                    raise
                async with lock:
                    res = sched.on_commit(i, start, length, ban, ndata)
                    if res.heal is not None and buf is not None:
                        # a hedge beat this body to completion: heal the
                        # winner's bytes over this landing (the duplicate
                        # is pure hedge waste)
                        buf[start:start + len(res.heal)] = res.heal
                    if res.wake:
                        cond.notify_all()
                _abort_hedge(start, res.cancel_hedger)
                if res.settled_won:
                    continue
                if journal is not None:
                    # committed: journal the interval (buffered append;
                    # fsync at the journal's checkpoint interval)
                    journal.record(offset + start, ndata, crc)
                if (tuner is not None and sched.done_bytes < size
                        and not tune_state["busy"]
                        and sched.done_bytes - tune_state["bytes"]
                        >= tune_every):
                    # fire-and-forget: the triggering lane keeps fetching
                    # while the tuner (possibly jit-compiling) runs in
                    # the executor.  The busy flag is claimed HERE,
                    # synchronously, so no second lane can schedule a
                    # competing task (and overwrite the task ref the
                    # end-of-fetch drain awaits) before this one starts.
                    tune_state["busy"] = True
                    tune_state["task"] = asyncio.ensure_future(
                        maybe_retune())

        async def worker(i: int):
            """Per-replica supervisor: owns the connection, runs
            ``pipeline_depth`` lanes over it, and on failure re-pools are
            already done lane-side — it just counts the failure, backs
            off (capped exponential + jitter), reconnects, and respawns
            the lanes."""
            name = self.replicas[i].name
            failures = 0
            try:
                while True:
                    async with lock:
                        if sched.finished:
                            return
                    conn = self._make_conn(self.replicas[i])
                    conn_of[i] = conn
                    lanes = [asyncio.ensure_future(pipe_lane(i, conn))
                             for _ in range(self.pipeline_depth)]
                    try:
                        outcomes = await asyncio.gather(
                            *lanes, return_exceptions=True)
                    finally:
                        for t in lanes:
                            t.cancel()
                        await asyncio.gather(*lanes, return_exceptions=True)
                        await conn.close()
                        for sample in conn.take_rtt_samples():
                            sched.observe_rtt(i, sample)
                    fatal = [o for o in outcomes
                             if isinstance(o, BaseException)]
                    if fatal:
                        raise fatal[0]
                    if "corrupt-dead" in outcomes:
                        # retired for integrity (already marked failed)
                        return
                    if "broken" not in outcomes:
                        return
                    if i in hedge_broke:
                        # the break was a deliberate hedge cancellation,
                        # not a replica failure: reconnect straight away
                        # without charging the failure budget
                        hedge_broke.discard(i)
                        continue
                    failures += 1
                    if failures >= self.max_failures:
                        sched.mark_failed(i)
                        return
                    sched.on_retry(i)
                    self._on_retry(name)
                    if self.retry_after > 0:
                        # capped exponential backoff with ±50% jitter:
                        # repeated failures probe ever less often, and
                        # decorrelated delays keep N clients' reconnect
                        # storms from synchronizing on a recovering mirror
                        delay = min(self.retry_after * (2 ** (failures - 1)),
                                    self.retry_backoff_cap)
                        delay *= 0.5 + self._rng.random()
                        await asyncio.sleep(delay)
            finally:
                # parked peers key takeability off the live-replica set —
                # they must recheck when it shrinks, and a dead peer's
                # advertisement no longer counts toward the union
                async with lock:
                    sched.on_replica_death(i)
                    cond.notify_all()

        async def _refresh_coverage(j: int) -> None:
            """Background poller for partial mirror ``j``: HEAD its
            advertisement every ``coverage_refresh_s`` on a throwaway
            connection (never the worker's data connection — a poll must
            not serialize behind a streaming body) and publish changes
            under the lock.  A missing header on a 200 means the peer now
            serves the whole window; 404/410 (the peer unbound its
            buffer) clears its coverage so nothing new is packed onto
            it."""
            rep = self.replicas[j]
            while True:
                async with lock:
                    if not sched.is_alive(j) or sched.finished:
                        return
                runs = None
                conn = self._make_conn(rep)
                try:
                    code, headers = await conn.head()
                    if code == 200:
                        raw = headers.get("x-available-ranges")
                        if raw is None:
                            runs = [(0, size)]
                        else:
                            runs = []
                            for lo, hi in _parse_ranges_header(raw):
                                s_ = max(lo - offset, 0)
                                e_ = min(hi + 1 - offset, size)
                                if e_ > s_:
                                    runs.append((s_, e_))
                    elif code in (404, 410):
                        runs = []
                except (OSError, ValueError, asyncio.IncompleteReadError):
                    pass
                finally:
                    await conn.close()
                if runs is not None and runs != sched.coverage_of(j):
                    async with lock:
                        if sched.is_alive(j) \
                                and sched.on_coverage_update(j, runs):
                            cond.notify_all()
                await asyncio.sleep(refresh_s)

        workers = [asyncio.ensure_future(worker(i))
                   for i in range(len(self.replicas))]
        refreshers = [asyncio.ensure_future(_refresh_coverage(j))
                      for j in sched.partial_idx]
        clock = asyncio.ensure_future(_stall_clock()) if hedge_q else None
        try:
            await asyncio.gather(*workers)
        except BaseException:
            # a fatal error (sink raise, cancellation) must not leave
            # sibling workers streaming into the buffer after fetch()
            # has already raised — cancel and drain them first
            for t in workers:
                t.cancel()
            await asyncio.gather(*workers, return_exceptions=True)
            task = tune_state["task"]
            if task is not None and not task.done():
                task.cancel()
            if journal is not None:
                journal.sync()
            raise
        finally:
            for t in refreshers:
                t.cancel()
            if refreshers:
                await asyncio.gather(*refreshers, return_exceptions=True)
            if clock is not None:
                clock.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await clock
        t_end = time.monotonic()
        # settle an in-flight tuner update BEFORE any raise, so no task
        # outlives the event loop: drain it on success (its adoption
        # isn't lost; transfer time excludes it), cancel it on failure
        task = tune_state["task"]
        if task is not None and not task.done():
            if sched.done_bytes == size:
                await task
            else:
                task.cancel()
                try:
                    await task
                except asyncio.CancelledError:
                    pass
        if journal is not None:
            # everything committed so far is durable before we either
            # report success or raise (an incomplete transfer's journal
            # is exactly what the resume path replays)
            journal.sync()
        failed = _failed_names()
        if sched.done_bytes != size:
            raise TransferIncompleteError(
                f"transfer incomplete: {sched.done_bytes}/{size} bytes "
                f"(failed replicas: {failed})",
                done_bytes=sched.done_bytes, expected_bytes=size,
                failed_replicas=failed)
        if retunes > 0:
            # adaptation persists: the next fetch starts from the tuned
            # geometry instead of re-learning from the defaults.  Guarded
            # on actual adoptions — a tuner that never fired must not pin
            # this transfer's size-derived defaults onto future ones.
            self._params_arg = sched.params
        # per-index scheduler counters fold into the report's name-keyed
        # dicts (duplicate names aggregate, as they always did)
        bytes_per = {r.name: 0 for r in self.replicas}
        reqs_per = {r.name: 0 for r in self.replicas}
        retries_per = {r.name: 0 for r in self.replicas}
        corrupt_per = {r.name: 0 for r in self.replicas}
        for i, r in enumerate(self.replicas):
            bytes_per[r.name] += sched.bytes_per[i]
            reqs_per[r.name] += sched.reqs_per[i]
            retries_per[r.name] += sched.retries_per[i]
            corrupt_per[r.name] += sched.corrupt_per[i]
        report = TransferReport(
            total_bytes=size, elapsed=t_end - t0,
            bytes_per_replica=bytes_per, requests_per_replica=reqs_per,
            failed_replicas=failed, refetched_ranges=sched.refetched,
            retunes=retunes,
            observed_throughputs={
                r.name: float(est[i].value)
                for i, r in enumerate(self.replicas)
            },
            observed_rtts={
                r.name: float(sched.rtt_min[i])
                for i, r in enumerate(self.replicas)
            },
            retries_per_replica=retries_per,
            corrupt_ranges=corrupt_per,
            resumed_bytes=resumed_bytes,
            resume_verify_seconds=resume_verify,
            hedges_issued=sched.hedges_issued,
            hedges_won=sched.hedges_won,
            hedge_wasted_bytes=sched.hedge_wasted,
        )
        self.last_report = report
        return buf, report

    async def blob_size(self) -> int:
        """HEAD the first healthy replica for the blob size."""
        for r in self.replicas:
            conn = _Conn(r, read_timeout=self.read_timeout)
            try:
                code, headers = await conn.head()
                if code == 200:
                    return int(headers["content-length"])
            except (OSError, ValueError, KeyError):
                continue
            finally:
                await conn.close()
        raise IOError("no replica answered HEAD")


def fetch_blob(replicas: Sequence[Replica], size: Optional[int] = None,
               **kw) -> tuple[bytes, TransferReport]:
    """Synchronous convenience wrapper."""
    client = MDTPClient(replicas, **kw)

    async def run():
        nonlocal size
        if size is None:
            size = await client.blob_size()
        return await client.fetch(size)

    buf, report = asyncio.run(run())
    return bytes(buf), report
