"""Asyncio multi-source transfer client (the real MDTP runtime).

No aiohttp in this environment — this is a raw-socket HTTP/1.1 client on
``asyncio`` streams with:

* one persistent connection per replica (paper §III-A: avoid TCP slow-start
  and session re-establishment),
* byte-range requests sized by the SAME allocator the simulator uses
  (``repro.core.chunking`` — single source of truth),
* per-chunk throughput observation feeding the next allocation,
* failure handling: a replica that errors mid-chunk is retired (or retried
  after ``retry_after``) and its unfinished range is re-queued — the
  checkpoint-restore path's fault tolerance.

The client is transport-generic: anything exposing ``fetch_range`` works
(tests use the in-process ``RangeServer``; production would point at real
mirrors).
"""

from __future__ import annotations

import asyncio
import heapq
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.chunking import ChunkParams, default_chunk_params, next_chunk_size
from repro.core.throughput import make_estimator, rtt_corrected_bandwidth

__all__ = ["Replica", "TransferReport", "MDTPClient", "NoTelemetryError",
           "fetch_blob"]


class NoTelemetryError(RuntimeError):
    """``retune()`` had no usable observations to re-plan from (no
    completed fetch yet, or every replica failed/went unobserved).

    A dedicated type so callers that tolerate missing telemetry (the
    checkpoint-restore wave loop) don't have to catch blanket
    ``RuntimeError`` — which would also swallow real failures like
    jax's ``XlaRuntimeError`` from the fused sweep itself.
    """


@dataclass(frozen=True)
class Replica:
    host: str
    port: int
    path: str              # HTTP path of the blob on this mirror

    @property
    def name(self) -> str:
        return f"{self.host}:{self.port}"


@dataclass
class TransferReport:
    total_bytes: int
    elapsed: float
    bytes_per_replica: dict
    requests_per_replica: dict
    failed_replicas: list
    refetched_ranges: int
    #: number of mid-transfer tuner adoptions (``fetch(tuner=...)``) — 0
    #: for un-tuned transfers.
    retunes: int = 0
    #: final per-replica estimator values (bytes/s; 0 = never observed) —
    #: the live inputs the autotuner re-tunes chunk sizes from.
    observed_throughputs: dict = field(default_factory=dict)
    #: measured per-replica request RTT in seconds (min over connect time
    #: and header turnarounds; 0 = never measured).  Feeds ``retune`` so
    #: the simulated sweep uses live latencies, not a guessed constant.
    observed_rtts: dict = field(default_factory=dict)

    @property
    def throughput(self) -> float:
        return self.total_bytes / self.elapsed if self.elapsed > 0 else 0.0


def _mean_chunk_bytes(bytes_per: dict, reqs_per: dict, name: str) -> float:
    """Average request size a replica served (0.0 when unknown) — the
    chunk-scale input of :func:`rtt_corrected_bandwidth`."""
    reqs = reqs_per.get(name, 0)
    if reqs <= 0:
        return 0.0
    return bytes_per.get(name, 0) / reqs


def _corrected_bandwidths(replicas, est_values, rtt_min, failed,
                          bytes_per, reqs_per) -> tuple:
    """Full-fleet positional bandwidth vector for ``Telemetry``, with each
    live estimate RTT-bias corrected (``rtt_corrected_bandwidth``) from
    that replica's measured request RTT and mean served chunk size.  Dead
    replicas keep their slot as 0.0; replicas with no RTT sample or no
    completed request pass through uncorrected (the correction is
    impossible, not merely inaccurate)."""
    out = []
    for i, r in enumerate(replicas):
        if r.name in failed:
            out.append(0.0)
            continue
        out.append(rtt_corrected_bandwidth(
            float(est_values[i]), float(rtt_min[i]),
            _mean_chunk_bytes(bytes_per, reqs_per, r.name)))
    return tuple(out)


class _Conn:
    """One persistent HTTP/1.1 connection.

    Collects per-connection RTT samples: the TCP connect time on session
    establishment, then the request-write → status-line turnaround of
    every range request.  Consumers drain ``take_rtt_samples()`` and
    min-aggregate — the minimum turnaround is the standard queuing-free
    RTT proxy (the connect sample matters: header turnarounds include
    server think time).
    """

    def __init__(self, replica: Replica):
        self.replica = replica
        self.reader: Optional[asyncio.StreamReader] = None
        self.writer: Optional[asyncio.StreamWriter] = None
        self._rtt_samples: list[float] = []

    def take_rtt_samples(self) -> list[float]:
        samples, self._rtt_samples = self._rtt_samples, []
        return samples

    async def connect(self):
        t0 = time.monotonic()
        self.reader, self.writer = await asyncio.open_connection(
            self.replica.host, self.replica.port)
        self._rtt_samples.append(time.monotonic() - t0)

    async def close(self):
        if self.writer is not None:
            self.writer.close()
            try:
                await self.writer.wait_closed()
            except Exception:
                pass

    async def fetch_range(self, start: int, end: int) -> bytes:
        """GET bytes [start, end] inclusive over the persistent session."""
        if self.writer is None:
            await self.connect()
        req = (f"GET {self.replica.path} HTTP/1.1\r\n"
               f"Host: {self.replica.host}\r\n"
               f"Range: bytes={start}-{end}\r\n"
               f"Connection: keep-alive\r\n\r\n")
        t_send = time.monotonic()
        self.writer.write(req.encode())
        await self.writer.drain()
        # status line + headers; first line back measures the header
        # turnaround (request RTT + server think time)
        status = await self.reader.readline()
        self._rtt_samples.append(time.monotonic() - t_send)
        if not status:
            raise ConnectionError("connection closed")
        code = int(status.split()[1])
        headers = {}
        while True:
            line = await self.reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode().partition(":")
            headers[k.strip().lower()] = v.strip()
        if code not in (200, 206):
            raise ConnectionError(f"HTTP {code}")
        n = int(headers["content-length"])
        body = await self.reader.readexactly(n)
        return body


class MDTPClient:
    """Downloads one blob from N replicas with MDTP adaptive chunking."""

    def __init__(
        self,
        replicas: Sequence[Replica],
        params: Optional[ChunkParams] = None,
        estimator: str = "ewma",
        ewma_alpha: float = 0.5,
        retry_after: float = 0.0,
        max_failures: int = 3,
        tuner=None,
    ):
        self.replicas = list(replicas)
        self._params_arg = params
        self._estimator = estimator
        self._alpha = ewma_alpha
        self.retry_after = retry_after
        self.max_failures = max_failures
        #: default online tuner (``repro.core.online`` contract: an object
        #: with ``update(telemetry) -> ChunkParams | None``) applied to
        #: every ``fetch`` unless overridden per call.
        self.tuner = tuner
        #: report of the most recent ``fetch`` (None before the first one).
        self.last_report: Optional[TransferReport] = None

    #: fallback request RTT (s) for replicas that never produced a sample —
    #: ~WAN RTT between FABRIC sites, matching the simulator scenarios.
    DEFAULT_RTT = 0.03

    def retune(self, file_size: int, **autotune_kw):
        """Re-tune chunk sizes from the last transfer's live observations.

        Runs the fused on-device grid sweep (``repro.core.autotune`` — one
        compiled call for the whole (C, L) × seed lattice) against the
        per-replica throughputs AND measured request RTTs observed during
        the previous ``fetch`` and adopts the winning ``ChunkParams`` for
        subsequent transfers.  Typical use: between checkpoint-restore
        waves, where mirror conditions drift but the replica set is stable.

        Returns the ``AutotuneResult``; raises if no transfer has been
        observed yet or no replica produced a throughput sample.
        """
        from repro.core.autotune import autotune_chunk_params

        if self.last_report is None:
            raise NoTelemetryError("retune() needs a completed fetch() first")
        # Replicas with no sample (failed / never dispatched) are excluded,
        # mirroring how fetch() retires them — a 0-throughput entry would
        # otherwise dominate every simulated grid point.  RTTs stay aligned
        # with the surviving bandwidth entries.  Estimates are RTT-bias
        # corrected (the per-request estimator's window spans the request
        # round-trip, under-stating the wire rate) so the simulated sweep
        # plans against the path's actual capacity.
        rep = self.last_report
        bw, rtts = [], []
        for r in self.replicas:
            b = rep.observed_throughputs.get(r.name, 0.0)
            if b <= 0.0:
                continue
            rtt = rep.observed_rtts.get(r.name, 0.0)
            bw.append(rtt_corrected_bandwidth(
                b, rtt, _mean_chunk_bytes(rep.bytes_per_replica,
                                          rep.requests_per_replica, r.name)))
            rtts.append(rtt if rtt > 0.0 else self.DEFAULT_RTT)
        if not bw:
            raise NoTelemetryError("no throughput observations to retune from")
        autotune_kw.setdefault("rtt", rtts)
        res = autotune_chunk_params(bw, file_size=int(file_size),
                                    **autotune_kw)
        self._params_arg = res.params
        return res

    def adopt_params(self, params: ChunkParams) -> None:
        """Adopt chunk geometry for subsequent transfers.

        The public hook for external re-tuning loops (e.g. the
        checkpoint-restore wave loop feeding an online tuner between
        waves); ``fetch(tuner=...)`` and ``retune`` adopt internally.
        """
        self._params_arg = params

    def _make_conn(self, replica: Replica) -> "_Conn":
        """Connection factory — subclasses may translate offsets (the data
        pipeline's virtual-blob client)."""
        return _Conn(replica)

    def _allocation_throughputs(self, est_values: list) -> list:
        """Per-replica throughput vector the allocator sizes chunks from.

        Default: this transfer's own estimator values.  The fleet manager
        (``repro.transfer.manager``) overrides this to pack each round
        into *residual* replica capacity — fleet bandwidth minus what
        other concurrent transfers are consuming — so co-scheduled
        transfers don't all plan as if they owned the mirrors.
        """
        return est_values

    async def fetch(self, size: int, sink=None, *, offset: int = 0,
                    tuner=None, tune_interval_bytes: Optional[int] = None,
                    ) -> tuple[bytearray, TransferReport]:
        """Fetch ``size`` bytes.  ``sink(start, data)`` (if given) receives
        chunks as they land (streaming to disk); otherwise an in-memory
        buffer is assembled.

        ``offset`` shifts every byte-range request (and the ``sink`` start
        offsets) by a constant — a wave of a larger blob fetches
        ``[offset, offset + size)`` while the internal cursor/pool stay
        0-based (the checkpoint-restore wave loop uses this).

        ``tuner`` (default: the client's ``tuner``) re-tunes chunk
        geometry mid-transfer: every ``tune_interval_bytes`` delivered
        bytes the client snapshots live telemetry (per-replica estimator
        values + measured RTTs, achieved window throughput) into a
        ``repro.core.online.Telemetry`` and adopts whatever ``ChunkParams``
        the tuner returns — workers pick up the new geometry on their next
        allocation.  The tuner runs in a thread-pool executor so its
        (possibly jit-compiling) sweep never stalls the event loop; at
        most one update is in flight at a time.  Adopted params persist on
        the client for subsequent transfers, and ``report.retunes`` counts
        the adoptions.
        """
        params_box = [self._params_arg or default_chunk_params(size)]
        n = len(self.replicas)
        est = [make_estimator(self._estimator, self._alpha) for _ in range(n)]
        buf = bytearray(size) if sink is None else None

        cursor = 0
        # reclaimed (start, len) min-heap keyed on range start (ranges never
        # overlap) — push/pop are O(log P), vs the O(P log P) full re-sort
        # the old list paid on every failure/short-read
        pool: list[tuple[int, int]] = []
        bytes_per = {r.name: 0 for r in self.replicas}
        reqs_per = {r.name: 0 for r in self.replicas}
        rtt_min = [0.0] * n                      # 0 = no sample yet
        failed: list[str] = []
        refetched = 0
        lock = asyncio.Lock()
        done_bytes = 0
        t0 = time.monotonic()

        tuner = tuner if tuner is not None else self.tuner
        retunes = 0
        # telemetry cadence: a handful of updates per transfer by default,
        # but never finer than a couple of large chunks' worth of signal
        tune_every = tune_interval_bytes or max(
            size // 8, 2 * params_box[0].large_chunk)
        tune_state = {"bytes": 0, "t": t0, "busy": False, "task": None}

        async def maybe_retune():
            """Snapshot telemetry and let the tuner re-plan (at most one
            update in flight — the trigger site claims the busy flag
            BEFORE scheduling, so a second trigger can't race in between;
            runs in an executor so jit compiles inside the tuner don't
            stall the event loop)."""
            nonlocal retunes
            try:
                try:
                    from repro.core.online import Telemetry

                    now = time.monotonic()
                    window_bytes = done_bytes - tune_state["bytes"]
                    window_t = max(now - tune_state["t"], 1e-9)
                    telemetry = Telemetry(
                        bandwidth=_corrected_bandwidths(
                            self.replicas, [e.value for e in est], rtt_min,
                            failed, bytes_per, reqs_per),
                        rtt=tuple(float(x) for x in rtt_min),
                        remaining_bytes=float(size - done_bytes),
                        measured_throughput=window_bytes / window_t,
                        elapsed=now - t0,
                    )
                    loop = asyncio.get_running_loop()
                    new = await loop.run_in_executor(None, tuner.update,
                                                     telemetry)
                except asyncio.CancelledError:
                    raise
                except Exception:
                    # a failing tuner path (the lazy online import in a
                    # jax-less deployment, a bad jit compile, a tuner
                    # bug) must never fail a transfer whose bytes are
                    # flowing fine — keep the current geometry, carry on
                    new = None
                tune_state["bytes"] = done_bytes
                tune_state["t"] = time.monotonic()
                if new is not None:
                    params_box[0] = new
                    retunes += 1
            finally:
                tune_state["busy"] = False

        # bytes currently on the wire somewhere; a worker that sees no
        # unassigned bytes must NOT exit while another worker still owes a
        # range — if that worker's replica dies, the reclaimed range needs
        # a surviving taker (the mirror-death fault-tolerance contract).
        inflight = 0

        async def allocate(nbytes: int) -> tuple[int, int]:
            nonlocal cursor, inflight
            async with lock:
                if pool:
                    s, ln = pool[0]
                    take = min(ln, nbytes)
                    if take == ln:
                        heapq.heappop(pool)
                    else:
                        # shrunk head keeps its heap position (start grows)
                        heapq.heapreplace(pool, (s + take, ln - take))
                    inflight += take
                    return s, take
                take = min(nbytes, size - cursor)
                s = cursor
                cursor += take
                inflight += take
                return s, take

        def observe_rtt(i: int, sample: float) -> None:
            if sample > 0.0:
                rtt_min[i] = (sample if rtt_min[i] <= 0.0
                              else min(rtt_min[i], sample))

        async def worker(i: int):
            nonlocal done_bytes, refetched, inflight
            conn = self._make_conn(self.replicas[i])
            failures = 0
            while True:
                async with lock:
                    remaining = (size - cursor) + sum(l for _, l in pool)
                    outstanding = inflight
                if remaining <= 0:
                    if outstanding <= 0:
                        break
                    # nothing to draw NOW, but a peer still owes a range:
                    # if its replica dies the range returns to the pool
                    # and this worker must be alive to take it over
                    await asyncio.sleep(0.005)
                    continue
                want = next_chunk_size(
                    i, self._allocation_throughputs([e.value for e in est]),
                    params_box[0], remaining)
                if want <= 0:
                    break
                start, length = await allocate(want)
                if length == 0:
                    await asyncio.sleep(0)
                    continue
                t_req = time.monotonic()
                try:
                    data = await conn.fetch_range(
                        offset + start, offset + start + length - 1)
                except (ConnectionError, OSError, asyncio.IncompleteReadError):
                    async with lock:
                        heapq.heappush(pool, (start, length))
                        inflight -= length
                        refetched += 1
                    failures += 1
                    await conn.close()
                    conn = self._make_conn(self.replicas[i])
                    if failures >= self.max_failures:
                        failed.append(self.replicas[i].name)
                        break
                    if self.retry_after > 0:
                        await asyncio.sleep(self.retry_after)
                    continue
                except BaseException:
                    # cancellation / unexpected error: release the range so
                    # peers waiting on in-flight work aren't stranded
                    async with lock:
                        heapq.heappush(pool, (start, length))
                        inflight -= length
                    raise
                try:
                    elapsed = time.monotonic() - t_req
                    est[i].observe(len(data), elapsed)
                    for sample in conn.take_rtt_samples():
                        observe_rtt(i, sample)
                    if sink is None:
                        buf[start:start + len(data)] = data
                    else:
                        sink(offset + start, data)
                except BaseException:
                    # e.g. the user-supplied sink raised (disk full): the
                    # bytes were NOT delivered — reclaim the whole range
                    # and settle the in-flight count before propagating
                    async with lock:
                        heapq.heappush(pool, (start, length))
                        inflight -= length
                    raise
                async with lock:
                    bytes_per[self.replicas[i].name] += len(data)
                    reqs_per[self.replicas[i].name] += 1
                    done_bytes += len(data)
                    inflight -= length
                    if len(data) < length:   # truncated: short range — the
                        # tail re-enters the pool atomically with the
                        # inflight decrement so no peer can exit between
                        heapq.heappush(
                            pool, (start + len(data), length - len(data)))
                if (tuner is not None and done_bytes < size
                        and not tune_state["busy"]
                        and done_bytes - tune_state["bytes"] >= tune_every):
                    # fire-and-forget: the triggering worker keeps
                    # fetching while the tuner (possibly jit-compiling)
                    # runs in the executor.  The busy flag is claimed
                    # HERE, synchronously, so no second worker can
                    # schedule a competing task (and overwrite the task
                    # ref the end-of-fetch drain awaits) before this one
                    # starts running.
                    tune_state["busy"] = True
                    tune_state["task"] = asyncio.ensure_future(
                        maybe_retune())
            await conn.close()

        try:
            await asyncio.gather(*(worker(i)
                                   for i in range(len(self.replicas))))
        except BaseException:
            task = tune_state["task"]
            if task is not None and not task.done():
                task.cancel()
            raise
        t_end = time.monotonic()
        # settle an in-flight tuner update BEFORE any raise, so no task
        # outlives the event loop: drain it on success (its adoption
        # isn't lost; transfer time excludes it), cancel it on failure
        task = tune_state["task"]
        if task is not None and not task.done():
            if done_bytes == size:
                await task
            else:
                task.cancel()
                try:
                    await task
                except asyncio.CancelledError:
                    pass
        if done_bytes != size:
            raise IOError(
                f"transfer incomplete: {done_bytes}/{size} bytes "
                f"(failed replicas: {failed})")
        if retunes > 0:
            # adaptation persists: the next fetch starts from the tuned
            # geometry instead of re-learning from the defaults.  Guarded
            # on actual adoptions — a tuner that never fired must not pin
            # this transfer's size-derived defaults onto future ones.
            self._params_arg = params_box[0]
        report = TransferReport(
            total_bytes=size, elapsed=t_end - t0,
            bytes_per_replica=bytes_per, requests_per_replica=reqs_per,
            failed_replicas=failed, refetched_ranges=refetched,
            retunes=retunes,
            observed_throughputs={
                r.name: float(est[i].value)
                for i, r in enumerate(self.replicas)
            },
            observed_rtts={
                r.name: float(rtt_min[i])
                for i, r in enumerate(self.replicas)
            },
        )
        self.last_report = report
        return buf, report

    async def blob_size(self) -> int:
        """HEAD the first healthy replica for the blob size."""
        for r in self.replicas:
            conn = _Conn(r)
            try:
                await conn.connect()
                req = (f"HEAD {r.path} HTTP/1.1\r\nHost: {r.host}\r\n"
                       f"Connection: keep-alive\r\n\r\n")
                conn.writer.write(req.encode())
                await conn.writer.drain()
                status = await conn.reader.readline()
                code = int(status.split()[1])
                headers = {}
                while True:
                    line = await conn.reader.readline()
                    if line in (b"\r\n", b"\n", b""):
                        break
                    k, _, v = line.decode().partition(":")
                    headers[k.strip().lower()] = v.strip()
                if code == 200:
                    return int(headers["content-length"])
            except (OSError, ValueError):
                continue
            finally:
                await conn.close()
        raise IOError("no replica answered HEAD")


def fetch_blob(replicas: Sequence[Replica], size: Optional[int] = None,
               **kw) -> tuple[bytes, TransferReport]:
    """Synchronous convenience wrapper."""
    client = MDTPClient(replicas, **kw)

    async def run():
        nonlocal size
        if size is None:
            size = await client.blob_size()
        return await client.fetch(size)

    buf, report = asyncio.run(run())
    return bytes(buf), report
