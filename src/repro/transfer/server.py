"""Threaded HTTP/1.1 range server (no external deps).

Serves files (or in-memory blobs) with:
  * ``Range: bytes=a-b`` support (206 Partial Content) — the substrate MDTP
    requests ride on,
  * persistent connections (keep-alive) — the paper's one-session-per-server
    requirement,
  * optional per-connection bandwidth throttling and response latency, so
    integration tests can reproduce heterogeneous replicas on localhost.

This is the replica-store stand-in for the data pipeline and the
checkpoint mirror in tests/examples.
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

__all__ = ["RangeServer", "Throttle"]


@dataclass
class Throttle:
    bytes_per_s: float = 0.0      # 0 = unthrottled
    latency_s: float = 0.0        # added before each response
    chunk: int = 64 * 1024


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-range/1.0"

    def log_message(self, *a):   # silence
        pass

    def _blob(self) -> Optional[bytes]:
        return self.server.blobs.get(self.path)  # type: ignore[attr-defined]

    def do_HEAD(self):
        blob = self._blob()
        if blob is None:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Length", str(len(blob)))
        self.send_header("Accept-Ranges", "bytes")
        self.end_headers()

    def do_GET(self):
        blob = self._blob()
        if blob is None:
            self.send_error(404)
            return
        throttle: Throttle = self.server.throttle  # type: ignore[attr-defined]
        if throttle.latency_s > 0:
            time.sleep(throttle.latency_s)
        rng = self.headers.get("Range")
        if rng and rng.startswith("bytes="):
            try:
                lo_s, hi_s = rng[len("bytes="):].split("-", 1)
                lo = int(lo_s)
                hi = int(hi_s) if hi_s else len(blob) - 1
            except ValueError:
                self.send_error(416)
                return
            hi = min(hi, len(blob) - 1)
            if lo > hi:
                self.send_error(416)
                return
            body = blob[lo:hi + 1]
            self.send_response(206)
            self.send_header("Content-Range",
                             f"bytes {lo}-{hi}/{len(blob)}")
        else:
            body = blob
            self.send_response(200)
        self.send_header("Content-Length", str(len(body)))
        self.send_header("Accept-Ranges", "bytes")
        self.end_headers()
        if throttle.bytes_per_s > 0:
            sent = 0
            t0 = time.monotonic()
            while sent < len(body):
                piece = body[sent:sent + throttle.chunk]
                self.wfile.write(piece)
                sent += len(piece)
                target = sent / throttle.bytes_per_s
                sleep = target - (time.monotonic() - t0)
                if sleep > 0:
                    time.sleep(sleep)
        else:
            self.wfile.write(body)


class RangeServer:
    """In-process replica server.  Register blobs or files by path."""

    def __init__(self, throttle: Optional[Throttle] = None):
        self._srv = ThreadingHTTPServer(("127.0.0.1", 0), _Handler)
        self._srv.blobs = {}                      # type: ignore[attr-defined]
        self._srv.throttle = throttle or Throttle()  # type: ignore[attr-defined]
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)

    @property
    def port(self) -> int:
        return self._srv.server_address[1]

    @property
    def address(self) -> tuple[str, int]:
        return ("127.0.0.1", self.port)

    def add_blob(self, path: str, data: bytes) -> None:
        if not path.startswith("/"):
            path = "/" + path
        self._srv.blobs[path] = data              # type: ignore[attr-defined]

    def add_file(self, path: str, filename: str) -> None:
        with open(filename, "rb") as f:
            self.add_blob(path, f.read())

    def start(self) -> "RangeServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
