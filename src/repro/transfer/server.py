"""Threaded HTTP/1.1 range server (no external deps).

Serves files (or in-memory blobs) with:
  * ``Range: bytes=a-b`` support (206 Partial Content) — the substrate MDTP
    requests ride on, served as ``memoryview`` windows over the registered
    blob (no per-range or per-throttle-piece body copies),
  * persistent connections (keep-alive) — the paper's one-session-per-server
    requirement,
  * optional per-connection bandwidth throttling and response latency, so
    integration tests can reproduce heterogeneous replicas on localhost,
  * an ``X-Range-Checksum`` CRC32 trailer-in-header so clients can verify
    every range end-to-end, and
  * an optional :class:`FaultPolicy` that injects bit-flips, truncations,
    stalls, garbage headers and connection resets — the chaos harness the
    robustness tests and benchmarks drive.

This is the replica-store stand-in for the data pipeline and the
checkpoint mirror in tests/examples.
"""

from __future__ import annotations

import random
import socket
import threading
import time
import zlib
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.transfer import codec

__all__ = ["RangeServer", "Throttle", "FaultPolicy"]


@dataclass
class Throttle:
    bytes_per_s: float = 0.0      # 0 = unthrottled
    latency_s: float = 0.0        # added before each response
    chunk: int = 64 * 1024
    #: True = token-bucket pacing on bytes alone: every ``chunk`` written
    #: buys an unconditional ``chunk / bytes_per_s`` sleep, never reduced
    #: by measured elapsed time.  The default (False) compensates for
    #: wall-clock already spent, which keeps the NET rate at the target on
    #: an idle box but lets a loaded box erase the sleeps entirely —
    #: exactly the regime where two mirrors' relative rates invert and
    #: throughput-proportionality tests flake.  Deterministic pacing makes
    #: each mirror's service time >= bytes / rate regardless of load, so
    #: rate *ratios* between mirrors are schedule-independent (host load
    #: can only add the same additive overhead to both sides).
    deterministic: bool = False
    #: True = ``bytes_per_s`` bounds the SERVER's aggregate egress, not
    #: each connection's.  Per-connection pacing (the default) gives N
    #: concurrent clients N× the rate — fine for modelling per-path
    #: bottlenecks, but a broadcast origin's uplink is a shared pipe:
    #: with ``shared=True`` every handler thread reserves its piece's
    #: wire time on one server-wide clock (deterministic token bucket,
    #: implies the ``deterministic`` guarantees), so N clients split the
    #: rate instead of multiplying it.
    shared: bool = False


@dataclass
class FaultPolicy:
    """Probabilistic per-range fault injection for chaos testing.

    Each GET draws independently from a seeded RNG shared by all handler
    threads, so a fixed seed gives a reproducible fault *sequence* for a
    deterministic request order (and a reproducible fault *rate* always).
    At most one fault fires per request; precedence when several rates are
    set: reset > garbage > truncate > stall > corrupt.

    The checksum header is always computed over the pristine bytes, so a
    bit-flipped body is detectable by the client — that is the point.
    """

    corrupt_rate: float = 0.0    #: flip bytes in the body (headers intact)
    truncate_rate: float = 0.0   #: full Content-Length, short body, sever
    stall_rate: float = 0.0      #: sleep ``stall_s`` mid-body
    garbage_rate: float = 0.0    #: malformed status line, then sever
    reset_rate: float = 0.0      #: sever the connection before responding
    stall_s: float = 5.0
    seed: int = 0


def _format_ranges(intervals) -> str:
    """``X-Available-Ranges`` wire form: comma-joined inclusive
    ``lo-hi`` pairs (Range-header syntax), empty when nothing is
    covered yet."""
    return ",".join(f"{s}-{s + n - 1}" for s, n in intervals if n > 0)


def _covers(intervals, lo: int, hi: int) -> bool:
    """True when ``[lo, hi]`` (inclusive) lies inside one covered
    interval — ``intervals`` is sorted disjoint ``(start, nbytes)``."""
    for s, n in intervals:
        if s <= lo and hi < s + n:
            return True
        if s > lo:
            break
    return False


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-range/1.0"

    def log_message(self, *a):   # silence
        pass

    def setup(self):
        super().setup()
        with self.server.gauge_lock:          # type: ignore[attr-defined]
            self.server.open_conns.add(       # type: ignore[attr-defined]
                self.connection)

    def finish(self):
        with self.server.gauge_lock:          # type: ignore[attr-defined]
            self.server.open_conns.discard(   # type: ignore[attr-defined]
                self.connection)
        super().finish()

    def _lookup(self):
        """Resolve the request path: ``(buffer, total, covered_fn)``.
        ``covered_fn`` is None for ordinary (fully-present) blobs; for
        partial mirrors it returns the currently covered ``(start,
        nbytes)`` intervals (the mirrored sink's live accounting)."""
        blob = self.server.blobs.get(self.path)  # type: ignore[attr-defined]
        if blob is not None:
            return blob, len(blob), None
        part = self.server.partials.get(          # type: ignore[attr-defined]
            self.path)
        if part is not None:
            return part
        return None

    def do_HEAD(self):
        centry = self.server.compressed.get(  # type: ignore[attr-defined]
            self.path)
        if centry is not None:
            # size discovery speaks DECODED bytes: the store's framing is
            # a transfer encoding, invisible to coverage planning
            self.send_response(200)
            self.send_header("Content-Length", str(centry[0].total))
            self.send_header("Accept-Ranges", "bytes")
            self.end_headers()
            return
        entry = self._lookup()
        if entry is None:
            self.send_error(404)
            return
        _buf, total, covered_fn = entry
        self.send_response(200)
        self.send_header("Content-Length", str(total))
        self.send_header("Accept-Ranges", "bytes")
        if covered_fn is not None:
            # the interval query: a HEAD doubles as "what do you have?"
            self.send_header("X-Available-Ranges",
                             _format_ranges(covered_fn()))
        self.end_headers()

    def do_GET(self):
        srv = self.server
        with srv.gauge_lock:                      # type: ignore[attr-defined]
            srv.concurrent += 1                   # type: ignore[attr-defined]
            srv.peak_concurrent = max(            # type: ignore[attr-defined]
                srv.peak_concurrent, srv.concurrent)
        self._gauge_held = True
        try:
            self._serve_get()
        except (BrokenPipeError, ConnectionResetError):
            # the client gave up mid-body (stall timeout, kill) — the
            # handler thread must not die noisily for that
            self.close_connection = True
        finally:
            self._gauge_release()

    def _gauge_release(self) -> None:
        """Close this request's concurrency-gauge window (idempotent).

        Called just BEFORE the final body write, not when the handler
        unwinds: the moment the last byte is handed to the kernel the
        client can read it, release its in-flight slot, and race its
        next request onto the wire — while this thread waits on the GIL
        to run its bookkeeping.  Anything left on this side of that
        write registers as request overlap the client never created.
        The throttle pays service time in sleeps before each write, so
        the gauge window still spans the full paced service (the
        per-replica in-flight cap witness measures SERVICE overlap,
        not handler-thread lifetime)."""
        if getattr(self, "_gauge_held", False):
            self._gauge_held = False
            with self.server.gauge_lock:          # type: ignore[attr-defined]
                self.server.concurrent -= 1       # type: ignore[attr-defined]

    def _draw_fault(self) -> Optional[str]:
        faults: Optional[FaultPolicy] = (
            self.server.faults)                   # type: ignore[attr-defined]
        if faults is None:
            return None
        with self.server.fault_lock:              # type: ignore[attr-defined]
            rng: random.Random = (
                self.server.fault_rng)            # type: ignore[attr-defined]
            for kind, rate in (
                ("reset", faults.reset_rate),
                ("garbage", faults.garbage_rate),
                ("truncate", faults.truncate_rate),
                ("stall", faults.stall_rate),
                ("corrupt", faults.corrupt_rate),
            ):
                if rate > 0.0 and rng.random() < rate:
                    counts = (
                        self.server.fault_counts)  # type: ignore[attr-defined]
                    counts[kind] = counts.get(kind, 0) + 1
                    return kind
        return None

    def _sever(self) -> None:
        """Abruptly cut the TCP stream (the reset/garbage/truncate tail)."""
        self.close_connection = True
        try:
            self.connection.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass

    def _account(self, n: int) -> None:
        with self.server.gauge_lock:              # type: ignore[attr-defined]
            self.server.served_bytes += n         # type: ignore[attr-defined]

    def _refuse_uncovered(self, covered_fn) -> None:
        """416 for a range the mirror does not (yet) hold, advertising
        what it DOES hold so the client can re-plan without a HEAD.  A
        plain keep-alive response — coverage only grows, so the same
        connection is worth retrying on."""
        self.send_response(416)
        self.send_header("Content-Length", "0")
        self.send_header("X-Available-Ranges",
                         _format_ranges(covered_fn()))
        self.end_headers()

    def _serve_get(self):
        centry = self.server.compressed.get(  # type: ignore[attr-defined]
            self.path)
        if centry is not None:
            self._serve_compressed(centry)
            return
        entry = self._lookup()
        if entry is None:
            self.send_error(404)
            return
        blob, total, covered_fn = entry
        throttle: Throttle = self.server.throttle  # type: ignore[attr-defined]
        if throttle.latency_s > 0:
            time.sleep(throttle.latency_s)
        rng = self.headers.get("Range")
        if rng and rng.startswith("bytes="):
            try:
                lo_s, hi_s = rng[len("bytes="):].split("-", 1)
                lo = int(lo_s)
                hi = int(hi_s) if hi_s else total - 1
            except ValueError:
                self.send_error(416)
                return
            hi = min(hi, total - 1)
            if lo > hi:
                self.send_error(416)
                return
            if covered_fn is not None and not _covers(covered_fn(), lo, hi):
                self._refuse_uncovered(covered_fn)
                return
            # memoryview slice: no per-range body copy — ranges (and the
            # throttle pieces below) are windows over the registered blob.
            # For partial mirrors the slice is safe under the concurrent
            # restore: covered bytes are committed-immutable, and the
            # coverage check above pinned this range inside them.
            body = memoryview(blob)[lo:hi + 1]
            status = 206
            content_range = f"bytes {lo}-{hi}/{total}"
        else:
            if covered_fn is not None and not _covers(
                    covered_fn(), 0, total - 1):
                self._refuse_uncovered(covered_fn)
                return
            body = memoryview(blob)[:total]
            status = 200
            content_range = None

        fault = self._draw_fault()
        if fault == "reset":
            self._sever()
            return
        if fault == "garbage":
            try:
                self.wfile.write(b"HTTX/9.9 000 NOT-HTTP\r\n\r\n")
                self.wfile.flush()
            except OSError:
                pass
            self._sever()
            return

        # checksum of the PRISTINE range — computed before any corruption
        # is applied, so a flipped bit downstream is detectable
        crc = (zlib.crc32(body)
               if self.server.checksums else None)  # type: ignore[attr-defined]

        truncate_at = None
        if fault == "truncate":
            # correct headers, short body: the worst kind of short read
            truncate_at = max(1, len(body) // 2)
        stall_at = None
        if fault == "stall":
            stall_at = len(body) // 2
        if fault == "corrupt":
            faults: FaultPolicy = (
                self.server.faults)               # type: ignore[attr-defined]
            corrupted = bytearray(body)
            with self.server.fault_lock:          # type: ignore[attr-defined]
                frng: random.Random = (
                    self.server.fault_rng)        # type: ignore[attr-defined]
                nflips = max(1, len(corrupted) // (256 * 1024))
                for _ in range(nflips):
                    corrupted[frng.randrange(len(corrupted))] ^= 0xFF
            body = memoryview(bytes(corrupted))

        self.send_response(status)
        if content_range is not None:
            self.send_header("Content-Range", content_range)
        self.send_header("Content-Length", str(len(body)))
        self.send_header("Accept-Ranges", "bytes")
        if crc is not None:
            self.send_header("X-Range-Checksum", f"crc32:{crc:08x}")
        self.end_headers()

        limit = truncate_at if truncate_at is not None else len(body)
        if throttle.bytes_per_s > 0:
            sent = 0
            t0 = time.monotonic()
            while sent < limit:
                piece = body[sent:min(sent + throttle.chunk, limit)]
                if stall_at is not None and sent >= stall_at:
                    time.sleep(self.server.faults.stall_s)  # type: ignore
                    stall_at = None
                if throttle.shared:
                    # server-wide token bucket: reserve this piece's wire
                    # time on the shared egress clock, then sleep until
                    # the reservation matures.  N concurrent connections
                    # thereby SPLIT ``bytes_per_s`` (each piece queues
                    # behind every previously reserved piece) instead of
                    # each enjoying it — a broadcast origin's fixed
                    # uplink.  Deterministic by construction: total
                    # service time >= bytes / rate regardless of load.
                    srv = self.server
                    with srv.shared_lock:     # type: ignore[attr-defined]
                        now = time.monotonic()
                        due = max(
                            srv.shared_free,  # type: ignore[attr-defined]
                            now) + len(piece) / throttle.bytes_per_s
                        srv.shared_free = due  # type: ignore[attr-defined]
                    wait = due - time.monotonic()
                    if wait > 0:
                        time.sleep(wait)
                elif throttle.deterministic:
                    # bytes-only token bucket: every piece pays its wire
                    # time up front, unconditionally — host load cannot
                    # erase the pacing.  Sleeping BEFORE the write means
                    # the requester sees the last byte only after the
                    # full paced duration (and the handler exits the
                    # moment it lands, keeping the concurrency gauge
                    # honest).
                    time.sleep(len(piece) / throttle.bytes_per_s)
                if sent + len(piece) >= limit:
                    self._gauge_release()
                self.wfile.write(piece)
                sent += len(piece)
                self._account(len(piece))
                if not (throttle.deterministic or throttle.shared):
                    target = sent / throttle.bytes_per_s
                    sleep = target - (time.monotonic() - t0)
                    if sleep > 0:
                        time.sleep(sleep)
        else:
            if stall_at is not None and stall_at > 0:
                self.wfile.write(body[:stall_at])
                self._account(stall_at)
                time.sleep(self.server.faults.stall_s)  # type: ignore
                self._gauge_release()
                self.wfile.write(body[stall_at:limit])
                self._account(limit - stall_at)
            else:
                if stall_at is not None:
                    time.sleep(self.server.faults.stall_s)  # type: ignore
                self._gauge_release()
                self.wfile.write(body[:limit])
                self._account(limit)
        if truncate_at is not None:
            self._sever()

    def _serve_compressed(self, centry) -> None:
        """Serve a range from a block-compressed store.

        The request and every byte-addressed header (``Range``,
        ``Content-Range``, the checksum) speak DECODED coordinates; the
        body is the framed compressed payload covering the span (whole
        blocks — see :mod:`repro.transfer.codec`) and ``Content-Length``
        is its WIRE length.  The checksum covers the pristine decoded
        range, so the client verifies integrity post-inflate — end to
        end across the codec.  Throttling and the served-bytes gauge
        meter wire bytes: a compressed store on a throttled uplink is
        exactly how compression buys goodput.  The chaos matrix
        (``FaultPolicy``) exercises the identity path; no faults are
        injected here."""
        store, raw = centry
        throttle: Throttle = self.server.throttle  # type: ignore[attr-defined]
        if throttle.latency_s > 0:
            time.sleep(throttle.latency_s)
        total = store.total
        rng = self.headers.get("Range")
        if rng and rng.startswith("bytes="):
            try:
                lo_s, hi_s = rng[len("bytes="):].split("-", 1)
                lo = int(lo_s)
                hi = int(hi_s) if hi_s else total - 1
            except ValueError:
                self.send_error(416)
                return
            hi = min(hi, total - 1)
            if lo > hi:
                self.send_error(416)
                return
            status = 206
            content_range = f"bytes {lo}-{hi}/{total}"
        else:
            lo, hi = 0, total - 1
            status = 200
            content_range = None
        body = memoryview(store.encode_range(lo, hi))
        crc = (zlib.crc32(memoryview(raw)[lo:hi + 1])
               if self.server.checksums else None)  # type: ignore[attr-defined]
        self.send_response(status)
        if content_range is not None:
            self.send_header("Content-Range", content_range)
        self.send_header("Content-Length", str(len(body)))
        self.send_header("Accept-Ranges", "bytes")
        self.send_header("X-Range-Encoding",
                         codec.encoding_header(store.block_size))
        if crc is not None:
            self.send_header("X-Range-Checksum", f"crc32:{crc:08x}")
        self.end_headers()
        self._write_paced(body)

    def _write_paced(self, body) -> None:
        """Throttled write of one fault-free body — the same pacing
        modes as the identity path (compensating, deterministic
        token-bucket, shared egress clock), metering wire bytes."""
        throttle: Throttle = self.server.throttle  # type: ignore[attr-defined]
        limit = len(body)
        if throttle.bytes_per_s <= 0:
            self._gauge_release()
            self.wfile.write(body)
            self._account(limit)
            return
        sent = 0
        t0 = time.monotonic()
        while sent < limit:
            piece = body[sent:min(sent + throttle.chunk, limit)]
            if throttle.shared:
                srv = self.server
                with srv.shared_lock:     # type: ignore[attr-defined]
                    now = time.monotonic()
                    due = max(
                        srv.shared_free,  # type: ignore[attr-defined]
                        now) + len(piece) / throttle.bytes_per_s
                    srv.shared_free = due  # type: ignore[attr-defined]
                wait = due - time.monotonic()
                if wait > 0:
                    time.sleep(wait)
            elif throttle.deterministic:
                time.sleep(len(piece) / throttle.bytes_per_s)
            if sent + len(piece) >= limit:
                self._gauge_release()
            self.wfile.write(piece)
            sent += len(piece)
            self._account(len(piece))
            if not (throttle.deterministic or throttle.shared):
                target = sent / throttle.bytes_per_s
                sleep = target - (time.monotonic() - t0)
                if sleep > 0:
                    time.sleep(sleep)


class RangeServer:
    """In-process replica server.  Register blobs or files by path."""

    def __init__(
        self,
        throttle: Optional[Throttle] = None,
        faults: Optional[FaultPolicy] = None,
        checksums: bool = True,
    ):
        self._srv = ThreadingHTTPServer(("127.0.0.1", 0), _Handler)
        self._srv.blobs = {}                      # type: ignore[attr-defined]
        #: path -> (buffer, total, covered_fn): partial mirrors (see
        #: ``add_partial``)
        self._srv.partials = {}                   # type: ignore[attr-defined]
        #: path -> (BlockStore, raw): block-compressed blobs (see
        #: ``add_compressed_blob``)
        self._srv.compressed = {}                 # type: ignore[attr-defined]
        self._srv.throttle = throttle or Throttle()  # type: ignore[attr-defined]
        self._srv.shared_lock = threading.Lock()  # type: ignore[attr-defined]
        #: shared-egress reservation clock (``Throttle.shared``): the
        #: monotonic instant the server's uplink is next free.
        self._srv.shared_free = 0.0               # type: ignore[attr-defined]
        self._srv.checksums = checksums           # type: ignore[attr-defined]
        self._srv.faults = faults                 # type: ignore[attr-defined]
        self._srv.fault_rng = random.Random(      # type: ignore[attr-defined]
            faults.seed if faults else 0)
        self._srv.fault_lock = threading.Lock()   # type: ignore[attr-defined]
        self._srv.fault_counts = {}               # type: ignore[attr-defined]
        self._srv.gauge_lock = threading.Lock()   # type: ignore[attr-defined]
        self._srv.concurrent = 0                  # type: ignore[attr-defined]
        self._srv.peak_concurrent = 0             # type: ignore[attr-defined]
        self._srv.served_bytes = 0                # type: ignore[attr-defined]
        self._srv.open_conns = set()              # type: ignore[attr-defined]
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)

    @property
    def port(self) -> int:
        return self._srv.server_address[1]

    @property
    def peak_concurrent_requests(self) -> int:
        """High-water mark of simultaneously in-flight GETs — the
        server-side witness for per-replica in-flight-cap tests."""
        return self._srv.peak_concurrent          # type: ignore[attr-defined]

    @property
    def served_bytes(self) -> int:
        """Body bytes actually written to clients (post-truncation) —
        the served-byte accounting resume tests rely on."""
        return self._srv.served_bytes             # type: ignore[attr-defined]

    @property
    def fault_counts(self) -> dict:
        """How many faults of each kind have fired (by name)."""
        return dict(self._srv.fault_counts)       # type: ignore[attr-defined]

    @property
    def address(self) -> tuple[str, int]:
        return ("127.0.0.1", self.port)

    def set_faults(self, faults: Optional[FaultPolicy]) -> None:
        """Swap the fault policy at runtime (None disables injection);
        the RNG is reseeded so a fresh policy starts a fresh sequence."""
        self._srv.faults = faults                 # type: ignore[attr-defined]
        self._srv.fault_rng = random.Random(      # type: ignore[attr-defined]
            faults.seed if faults else 0)

    def set_throttle(self, throttle: Optional[Throttle]) -> None:
        """Swap the throttle at runtime (None = unthrottled) — the real-
        socket mirror of ``ServerSpec.degrade_at``: each handler snapshots
        the throttle per request, so an in-flight range finishes at the
        old rate and every SUBSEQUENT range is served at the new one
        (gray degradation, connection never breaks)."""
        self._srv.throttle = throttle or Throttle()  # type: ignore[attr-defined]

    def add_blob(self, path: str, data: bytes) -> None:
        if not path.startswith("/"):
            path = "/" + path
        self._srv.blobs[path] = data              # type: ignore[attr-defined]

    def add_partial(self, path: str, buffer, covered, total=None) -> None:
        """Mount a partially-populated ``buffer`` as a read-only mirror.

        ``covered`` is a zero-arg callable returning the currently
        covered ``(start, nbytes)`` intervals (sorted, disjoint — e.g. a
        :class:`repro.transfer.Sink`'s ``covered_intervals``).  HEADs
        advertise the live coverage via ``X-Available-Ranges``; a GET
        for bytes outside it is refused with 416 (carrying the same
        header) rather than served short.  The buffer may still be
        filling: committed bytes must be immutable, which is exactly the
        transfer sinks' write-once contract.
        """
        if not path.startswith("/"):
            path = "/" + path
        total = len(buffer) if total is None else int(total)
        self._srv.partials[path] = (              # type: ignore[attr-defined]
            buffer, total, covered)

    def add_compressed_blob(self, path: str, data: bytes,
                            block_size: int = codec.DEFAULT_BLOCK) -> None:
        """Register ``data`` served from a block-compressed store: GETs
        answer decoded-coordinate ranges with framed compressed bodies
        (``X-Range-Encoding``) — fewer wire bytes for the same data.
        The pristine blob is kept alongside for checksums; compression
        happens once, here, not per request."""
        if not path.startswith("/"):
            path = "/" + path
        self._srv.compressed[path] = (            # type: ignore[attr-defined]
            codec.compress_blocks(data, block_size), data)

    def remove_path(self, path: str) -> None:
        """Unregister a blob or partial mirror (subsequent requests
        404).  In-flight handlers finish from their own references."""
        if not path.startswith("/"):
            path = "/" + path
        self._srv.blobs.pop(path, None)           # type: ignore[attr-defined]
        self._srv.partials.pop(path, None)        # type: ignore[attr-defined]
        self._srv.compressed.pop(path, None)      # type: ignore[attr-defined]

    def add_file(self, path: str, filename: str) -> None:
        with open(filename, "rb") as f:
            self.add_blob(path, f.read())

    def add_compressed_file(self, path: str, filename: str,
                            block_size: int = codec.DEFAULT_BLOCK) -> None:
        """``add_file`` into the block-compressed store — how a
        checkpoint mirror serves ``data.bin`` compressed."""
        with open(filename, "rb") as f:
            self.add_compressed_blob(path, f.read(), block_size)

    def start(self) -> "RangeServer":
        self._thread.start()
        return self

    def kill_connections(self) -> None:
        """Forcibly sever every established client connection (the
        streams, not the listener): ``stop()`` only halts the accept
        loop, while handler threads keep serving persistent sessions to
        completion.  Mirror-death tests use this to cut a connection
        with pipelined requests still in flight."""
        with self._srv.gauge_lock:                # type: ignore[attr-defined]
            conns = list(self._srv.open_conns)    # type: ignore[attr-defined]
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass

    def stop(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
