"""Threaded HTTP/1.1 range server (no external deps).

Serves files (or in-memory blobs) with:
  * ``Range: bytes=a-b`` support (206 Partial Content) — the substrate MDTP
    requests ride on, served as ``memoryview`` windows over the registered
    blob (no per-range or per-throttle-piece body copies),
  * persistent connections (keep-alive) — the paper's one-session-per-server
    requirement,
  * optional per-connection bandwidth throttling and response latency, so
    integration tests can reproduce heterogeneous replicas on localhost.

This is the replica-store stand-in for the data pipeline and the
checkpoint mirror in tests/examples.
"""

from __future__ import annotations

import socket
import threading
import time
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

__all__ = ["RangeServer", "Throttle"]


@dataclass
class Throttle:
    bytes_per_s: float = 0.0      # 0 = unthrottled
    latency_s: float = 0.0        # added before each response
    chunk: int = 64 * 1024
    #: True = token-bucket pacing on bytes alone: every ``chunk`` written
    #: buys an unconditional ``chunk / bytes_per_s`` sleep, never reduced
    #: by measured elapsed time.  The default (False) compensates for
    #: wall-clock already spent, which keeps the NET rate at the target on
    #: an idle box but lets a loaded box erase the sleeps entirely —
    #: exactly the regime where two mirrors' relative rates invert and
    #: throughput-proportionality tests flake.  Deterministic pacing makes
    #: each mirror's service time >= bytes / rate regardless of load, so
    #: rate *ratios* between mirrors are schedule-independent (host load
    #: can only add the same additive overhead to both sides).
    deterministic: bool = False


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-range/1.0"

    def log_message(self, *a):   # silence
        pass

    def setup(self):
        super().setup()
        with self.server.gauge_lock:          # type: ignore[attr-defined]
            self.server.open_conns.add(       # type: ignore[attr-defined]
                self.connection)

    def finish(self):
        with self.server.gauge_lock:          # type: ignore[attr-defined]
            self.server.open_conns.discard(   # type: ignore[attr-defined]
                self.connection)
        super().finish()

    def _blob(self) -> Optional[bytes]:
        return self.server.blobs.get(self.path)  # type: ignore[attr-defined]

    def do_HEAD(self):
        blob = self._blob()
        if blob is None:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Length", str(len(blob)))
        self.send_header("Accept-Ranges", "bytes")
        self.end_headers()

    def do_GET(self):
        srv = self.server
        with srv.gauge_lock:                      # type: ignore[attr-defined]
            srv.concurrent += 1                   # type: ignore[attr-defined]
            srv.peak_concurrent = max(            # type: ignore[attr-defined]
                srv.peak_concurrent, srv.concurrent)
        try:
            self._serve_get()
        finally:
            with srv.gauge_lock:                  # type: ignore[attr-defined]
                srv.concurrent -= 1               # type: ignore[attr-defined]

    def _serve_get(self):
        blob = self._blob()
        if blob is None:
            self.send_error(404)
            return
        throttle: Throttle = self.server.throttle  # type: ignore[attr-defined]
        if throttle.latency_s > 0:
            time.sleep(throttle.latency_s)
        rng = self.headers.get("Range")
        if rng and rng.startswith("bytes="):
            try:
                lo_s, hi_s = rng[len("bytes="):].split("-", 1)
                lo = int(lo_s)
                hi = int(hi_s) if hi_s else len(blob) - 1
            except ValueError:
                self.send_error(416)
                return
            hi = min(hi, len(blob) - 1)
            if lo > hi:
                self.send_error(416)
                return
            # memoryview slice: no per-range body copy — ranges (and the
            # throttle pieces below) are windows over the registered blob
            body = memoryview(blob)[lo:hi + 1]
            self.send_response(206)
            self.send_header("Content-Range",
                             f"bytes {lo}-{hi}/{len(blob)}")
        else:
            body = memoryview(blob)
            self.send_response(200)
        self.send_header("Content-Length", str(len(body)))
        self.send_header("Accept-Ranges", "bytes")
        self.end_headers()
        if throttle.bytes_per_s > 0:
            sent = 0
            t0 = time.monotonic()
            while sent < len(body):
                piece = body[sent:sent + throttle.chunk]
                if throttle.deterministic:
                    # bytes-only token bucket: every piece pays its wire
                    # time up front, unconditionally — host load cannot
                    # erase the pacing.  Sleeping BEFORE the write means
                    # the requester sees the last byte only after the
                    # full paced duration (and the handler exits the
                    # moment it lands, keeping the concurrency gauge
                    # honest).
                    time.sleep(len(piece) / throttle.bytes_per_s)
                self.wfile.write(piece)
                sent += len(piece)
                if not throttle.deterministic:
                    target = sent / throttle.bytes_per_s
                    sleep = target - (time.monotonic() - t0)
                    if sleep > 0:
                        time.sleep(sleep)
        else:
            self.wfile.write(body)


class RangeServer:
    """In-process replica server.  Register blobs or files by path."""

    def __init__(self, throttle: Optional[Throttle] = None):
        self._srv = ThreadingHTTPServer(("127.0.0.1", 0), _Handler)
        self._srv.blobs = {}                      # type: ignore[attr-defined]
        self._srv.throttle = throttle or Throttle()  # type: ignore[attr-defined]
        self._srv.gauge_lock = threading.Lock()   # type: ignore[attr-defined]
        self._srv.concurrent = 0                  # type: ignore[attr-defined]
        self._srv.peak_concurrent = 0             # type: ignore[attr-defined]
        self._srv.open_conns = set()              # type: ignore[attr-defined]
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)

    @property
    def port(self) -> int:
        return self._srv.server_address[1]

    @property
    def peak_concurrent_requests(self) -> int:
        """High-water mark of simultaneously in-flight GETs — the
        server-side witness for per-replica in-flight-cap tests."""
        return self._srv.peak_concurrent          # type: ignore[attr-defined]

    @property
    def address(self) -> tuple[str, int]:
        return ("127.0.0.1", self.port)

    def add_blob(self, path: str, data: bytes) -> None:
        if not path.startswith("/"):
            path = "/" + path
        self._srv.blobs[path] = data              # type: ignore[attr-defined]

    def add_file(self, path: str, filename: str) -> None:
        with open(filename, "rb") as f:
            self.add_blob(path, f.read())

    def start(self) -> "RangeServer":
        self._thread.start()
        return self

    def kill_connections(self) -> None:
        """Forcibly sever every established client connection (the
        streams, not the listener): ``stop()`` only halts the accept
        loop, while handler threads keep serving persistent sessions to
        completion.  Mirror-death tests use this to cut a connection
        with pipelined requests still in flight."""
        with self._srv.gauge_lock:                # type: ignore[attr-defined]
            conns = list(self._srv.open_conns)    # type: ignore[attr-defined]
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass

    def stop(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
