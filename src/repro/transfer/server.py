"""Threaded HTTP/1.1 range server (no external deps).

Serves files (or in-memory blobs) with:
  * ``Range: bytes=a-b`` support (206 Partial Content) — the substrate MDTP
    requests ride on, served as ``memoryview`` windows over the registered
    blob (no per-range or per-throttle-piece body copies),
  * persistent connections (keep-alive) — the paper's one-session-per-server
    requirement,
  * optional per-connection bandwidth throttling and response latency, so
    integration tests can reproduce heterogeneous replicas on localhost,
  * an ``X-Range-Checksum`` CRC32 trailer-in-header so clients can verify
    every range end-to-end, and
  * an optional :class:`FaultPolicy` that injects bit-flips, truncations,
    stalls, garbage headers and connection resets — the chaos harness the
    robustness tests and benchmarks drive.

This is the replica-store stand-in for the data pipeline and the
checkpoint mirror in tests/examples.
"""

from __future__ import annotations

import random
import socket
import threading
import time
import zlib
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

__all__ = ["RangeServer", "Throttle", "FaultPolicy"]


@dataclass
class Throttle:
    bytes_per_s: float = 0.0      # 0 = unthrottled
    latency_s: float = 0.0        # added before each response
    chunk: int = 64 * 1024
    #: True = token-bucket pacing on bytes alone: every ``chunk`` written
    #: buys an unconditional ``chunk / bytes_per_s`` sleep, never reduced
    #: by measured elapsed time.  The default (False) compensates for
    #: wall-clock already spent, which keeps the NET rate at the target on
    #: an idle box but lets a loaded box erase the sleeps entirely —
    #: exactly the regime where two mirrors' relative rates invert and
    #: throughput-proportionality tests flake.  Deterministic pacing makes
    #: each mirror's service time >= bytes / rate regardless of load, so
    #: rate *ratios* between mirrors are schedule-independent (host load
    #: can only add the same additive overhead to both sides).
    deterministic: bool = False


@dataclass
class FaultPolicy:
    """Probabilistic per-range fault injection for chaos testing.

    Each GET draws independently from a seeded RNG shared by all handler
    threads, so a fixed seed gives a reproducible fault *sequence* for a
    deterministic request order (and a reproducible fault *rate* always).
    At most one fault fires per request; precedence when several rates are
    set: reset > garbage > truncate > stall > corrupt.

    The checksum header is always computed over the pristine bytes, so a
    bit-flipped body is detectable by the client — that is the point.
    """

    corrupt_rate: float = 0.0    #: flip bytes in the body (headers intact)
    truncate_rate: float = 0.0   #: full Content-Length, short body, sever
    stall_rate: float = 0.0      #: sleep ``stall_s`` mid-body
    garbage_rate: float = 0.0    #: malformed status line, then sever
    reset_rate: float = 0.0      #: sever the connection before responding
    stall_s: float = 5.0
    seed: int = 0


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-range/1.0"

    def log_message(self, *a):   # silence
        pass

    def setup(self):
        super().setup()
        with self.server.gauge_lock:          # type: ignore[attr-defined]
            self.server.open_conns.add(       # type: ignore[attr-defined]
                self.connection)

    def finish(self):
        with self.server.gauge_lock:          # type: ignore[attr-defined]
            self.server.open_conns.discard(   # type: ignore[attr-defined]
                self.connection)
        super().finish()

    def _blob(self) -> Optional[bytes]:
        return self.server.blobs.get(self.path)  # type: ignore[attr-defined]

    def do_HEAD(self):
        blob = self._blob()
        if blob is None:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Length", str(len(blob)))
        self.send_header("Accept-Ranges", "bytes")
        self.end_headers()

    def do_GET(self):
        srv = self.server
        with srv.gauge_lock:                      # type: ignore[attr-defined]
            srv.concurrent += 1                   # type: ignore[attr-defined]
            srv.peak_concurrent = max(            # type: ignore[attr-defined]
                srv.peak_concurrent, srv.concurrent)
        self._gauge_held = True
        try:
            self._serve_get()
        except (BrokenPipeError, ConnectionResetError):
            # the client gave up mid-body (stall timeout, kill) — the
            # handler thread must not die noisily for that
            self.close_connection = True
        finally:
            self._gauge_release()

    def _gauge_release(self) -> None:
        """Close this request's concurrency-gauge window (idempotent).

        Called just BEFORE the final body write, not when the handler
        unwinds: the moment the last byte is handed to the kernel the
        client can read it, release its in-flight slot, and race its
        next request onto the wire — while this thread waits on the GIL
        to run its bookkeeping.  Anything left on this side of that
        write registers as request overlap the client never created.
        The throttle pays service time in sleeps before each write, so
        the gauge window still spans the full paced service (the
        per-replica in-flight cap witness measures SERVICE overlap,
        not handler-thread lifetime)."""
        if getattr(self, "_gauge_held", False):
            self._gauge_held = False
            with self.server.gauge_lock:          # type: ignore[attr-defined]
                self.server.concurrent -= 1       # type: ignore[attr-defined]

    def _draw_fault(self) -> Optional[str]:
        faults: Optional[FaultPolicy] = (
            self.server.faults)                   # type: ignore[attr-defined]
        if faults is None:
            return None
        with self.server.fault_lock:              # type: ignore[attr-defined]
            rng: random.Random = (
                self.server.fault_rng)            # type: ignore[attr-defined]
            for kind, rate in (
                ("reset", faults.reset_rate),
                ("garbage", faults.garbage_rate),
                ("truncate", faults.truncate_rate),
                ("stall", faults.stall_rate),
                ("corrupt", faults.corrupt_rate),
            ):
                if rate > 0.0 and rng.random() < rate:
                    counts = (
                        self.server.fault_counts)  # type: ignore[attr-defined]
                    counts[kind] = counts.get(kind, 0) + 1
                    return kind
        return None

    def _sever(self) -> None:
        """Abruptly cut the TCP stream (the reset/garbage/truncate tail)."""
        self.close_connection = True
        try:
            self.connection.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass

    def _account(self, n: int) -> None:
        with self.server.gauge_lock:              # type: ignore[attr-defined]
            self.server.served_bytes += n         # type: ignore[attr-defined]

    def _serve_get(self):
        blob = self._blob()
        if blob is None:
            self.send_error(404)
            return
        throttle: Throttle = self.server.throttle  # type: ignore[attr-defined]
        if throttle.latency_s > 0:
            time.sleep(throttle.latency_s)
        rng = self.headers.get("Range")
        if rng and rng.startswith("bytes="):
            try:
                lo_s, hi_s = rng[len("bytes="):].split("-", 1)
                lo = int(lo_s)
                hi = int(hi_s) if hi_s else len(blob) - 1
            except ValueError:
                self.send_error(416)
                return
            hi = min(hi, len(blob) - 1)
            if lo > hi:
                self.send_error(416)
                return
            # memoryview slice: no per-range body copy — ranges (and the
            # throttle pieces below) are windows over the registered blob
            body = memoryview(blob)[lo:hi + 1]
            status = 206
            content_range = f"bytes {lo}-{hi}/{len(blob)}"
        else:
            body = memoryview(blob)
            status = 200
            content_range = None

        fault = self._draw_fault()
        if fault == "reset":
            self._sever()
            return
        if fault == "garbage":
            try:
                self.wfile.write(b"HTTX/9.9 000 NOT-HTTP\r\n\r\n")
                self.wfile.flush()
            except OSError:
                pass
            self._sever()
            return

        # checksum of the PRISTINE range — computed before any corruption
        # is applied, so a flipped bit downstream is detectable
        crc = (zlib.crc32(body)
               if self.server.checksums else None)  # type: ignore[attr-defined]

        truncate_at = None
        if fault == "truncate":
            # correct headers, short body: the worst kind of short read
            truncate_at = max(1, len(body) // 2)
        stall_at = None
        if fault == "stall":
            stall_at = len(body) // 2
        if fault == "corrupt":
            faults: FaultPolicy = (
                self.server.faults)               # type: ignore[attr-defined]
            corrupted = bytearray(body)
            with self.server.fault_lock:          # type: ignore[attr-defined]
                frng: random.Random = (
                    self.server.fault_rng)        # type: ignore[attr-defined]
                nflips = max(1, len(corrupted) // (256 * 1024))
                for _ in range(nflips):
                    corrupted[frng.randrange(len(corrupted))] ^= 0xFF
            body = memoryview(bytes(corrupted))

        self.send_response(status)
        if content_range is not None:
            self.send_header("Content-Range", content_range)
        self.send_header("Content-Length", str(len(body)))
        self.send_header("Accept-Ranges", "bytes")
        if crc is not None:
            self.send_header("X-Range-Checksum", f"crc32:{crc:08x}")
        self.end_headers()

        limit = truncate_at if truncate_at is not None else len(body)
        if throttle.bytes_per_s > 0:
            sent = 0
            t0 = time.monotonic()
            while sent < limit:
                piece = body[sent:min(sent + throttle.chunk, limit)]
                if stall_at is not None and sent >= stall_at:
                    time.sleep(self.server.faults.stall_s)  # type: ignore
                    stall_at = None
                if throttle.deterministic:
                    # bytes-only token bucket: every piece pays its wire
                    # time up front, unconditionally — host load cannot
                    # erase the pacing.  Sleeping BEFORE the write means
                    # the requester sees the last byte only after the
                    # full paced duration (and the handler exits the
                    # moment it lands, keeping the concurrency gauge
                    # honest).
                    time.sleep(len(piece) / throttle.bytes_per_s)
                if sent + len(piece) >= limit:
                    self._gauge_release()
                self.wfile.write(piece)
                sent += len(piece)
                self._account(len(piece))
                if not throttle.deterministic:
                    target = sent / throttle.bytes_per_s
                    sleep = target - (time.monotonic() - t0)
                    if sleep > 0:
                        time.sleep(sleep)
        else:
            if stall_at is not None and stall_at > 0:
                self.wfile.write(body[:stall_at])
                self._account(stall_at)
                time.sleep(self.server.faults.stall_s)  # type: ignore
                self._gauge_release()
                self.wfile.write(body[stall_at:limit])
                self._account(limit - stall_at)
            else:
                if stall_at is not None:
                    time.sleep(self.server.faults.stall_s)  # type: ignore
                self._gauge_release()
                self.wfile.write(body[:limit])
                self._account(limit)
        if truncate_at is not None:
            self._sever()


class RangeServer:
    """In-process replica server.  Register blobs or files by path."""

    def __init__(
        self,
        throttle: Optional[Throttle] = None,
        faults: Optional[FaultPolicy] = None,
        checksums: bool = True,
    ):
        self._srv = ThreadingHTTPServer(("127.0.0.1", 0), _Handler)
        self._srv.blobs = {}                      # type: ignore[attr-defined]
        self._srv.throttle = throttle or Throttle()  # type: ignore[attr-defined]
        self._srv.checksums = checksums           # type: ignore[attr-defined]
        self._srv.faults = faults                 # type: ignore[attr-defined]
        self._srv.fault_rng = random.Random(      # type: ignore[attr-defined]
            faults.seed if faults else 0)
        self._srv.fault_lock = threading.Lock()   # type: ignore[attr-defined]
        self._srv.fault_counts = {}               # type: ignore[attr-defined]
        self._srv.gauge_lock = threading.Lock()   # type: ignore[attr-defined]
        self._srv.concurrent = 0                  # type: ignore[attr-defined]
        self._srv.peak_concurrent = 0             # type: ignore[attr-defined]
        self._srv.served_bytes = 0                # type: ignore[attr-defined]
        self._srv.open_conns = set()              # type: ignore[attr-defined]
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)

    @property
    def port(self) -> int:
        return self._srv.server_address[1]

    @property
    def peak_concurrent_requests(self) -> int:
        """High-water mark of simultaneously in-flight GETs — the
        server-side witness for per-replica in-flight-cap tests."""
        return self._srv.peak_concurrent          # type: ignore[attr-defined]

    @property
    def served_bytes(self) -> int:
        """Body bytes actually written to clients (post-truncation) —
        the served-byte accounting resume tests rely on."""
        return self._srv.served_bytes             # type: ignore[attr-defined]

    @property
    def fault_counts(self) -> dict:
        """How many faults of each kind have fired (by name)."""
        return dict(self._srv.fault_counts)       # type: ignore[attr-defined]

    @property
    def address(self) -> tuple[str, int]:
        return ("127.0.0.1", self.port)

    def set_faults(self, faults: Optional[FaultPolicy]) -> None:
        """Swap the fault policy at runtime (None disables injection);
        the RNG is reseeded so a fresh policy starts a fresh sequence."""
        self._srv.faults = faults                 # type: ignore[attr-defined]
        self._srv.fault_rng = random.Random(      # type: ignore[attr-defined]
            faults.seed if faults else 0)

    def set_throttle(self, throttle: Optional[Throttle]) -> None:
        """Swap the throttle at runtime (None = unthrottled) — the real-
        socket mirror of ``ServerSpec.degrade_at``: each handler snapshots
        the throttle per request, so an in-flight range finishes at the
        old rate and every SUBSEQUENT range is served at the new one
        (gray degradation, connection never breaks)."""
        self._srv.throttle = throttle or Throttle()  # type: ignore[attr-defined]

    def add_blob(self, path: str, data: bytes) -> None:
        if not path.startswith("/"):
            path = "/" + path
        self._srv.blobs[path] = data              # type: ignore[attr-defined]

    def add_file(self, path: str, filename: str) -> None:
        with open(filename, "rb") as f:
            self.add_blob(path, f.read())

    def start(self) -> "RangeServer":
        self._thread.start()
        return self

    def kill_connections(self) -> None:
        """Forcibly sever every established client connection (the
        streams, not the listener): ``stop()`` only halts the accept
        loop, while handler threads keep serving persistent sessions to
        completion.  Mirror-death tests use this to cut a connection
        with pipelined requests still in flight."""
        with self._srv.gauge_lock:                # type: ignore[attr-defined]
            conns = list(self._srv.open_conns)    # type: ignore[attr-defined]
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass

    def stop(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
