"""The transfer layer's destination contract, as an explicit protocol.

Historically ``MDTPClient.fetch(sink=...)`` accepted two duck-typed
shapes — a bare callable ``sink(start, view)`` receiving transient
memoryviews, and an object with ``writable``/``commit`` for the
zero-copy path — and consumers (the client, the fleet manager, the
checkpoint restore) each re-described the contract in prose.  This
module promotes it to one typed :class:`Sink` protocol:

* ``writable(start, length) -> memoryview`` — a view of the
  destination for ``[start, start + length)``; the client reads socket
  bytes straight into it (zero-copy),
* ``commit(start, nbytes)`` — the first ``nbytes`` of that range
  landed and verified; account for them,
* ``covered_intervals() -> [(start, nbytes), ...]`` — the committed
  coverage as sorted disjoint pairs.  This is what makes a sink
  **mirrorable**: a ``PeerMirror`` mounts the sink on a ``RangeServer``
  and advertises exactly these intervals (``X-Available-Ranges``) to
  other restoring nodes.

All three implementations here share one interval-merge implementation
(:func:`repro.transfer.journal.claim_interval`) with the resume journal
and the streaming checkpoint restore, so a mirror's advertisement has a
single source of truth no matter which sink backs it.

``CallableSink`` adapts the legacy callable shape to the protocol: the
wrapped callable still receives transient views (copy if you keep
them), but the adapter buffers each range in scratch so the zero-copy
receive path and the coverage accessor work.  Note the scratch is
per-range and released on commit — a ``CallableSink`` cannot back a
peer mirror (nothing is retained to serve) and cannot be CRC-verified
by the resume replay; use :class:`BufferSink` or the streaming restore
sink for those.
"""

from __future__ import annotations

from typing import Callable, Protocol, runtime_checkable

from repro.transfer.journal import claim_interval

__all__ = ["Sink", "BufferSink", "CallableSink"]


@runtime_checkable
class Sink(Protocol):
    """Destination contract for :meth:`repro.transfer.MDTPClient.fetch`.

    Ranges may arrive out of order, and deliveries may overlap or
    repeat (retries, speculative re-fetches) — implementations must
    treat ``commit`` as idempotent per byte.  ``covered_intervals``
    must be safe to call from other threads while the transfer is in
    flight: a peer mirror's server threads read it to build the
    ``X-Available-Ranges`` advertisement.
    """

    def writable(self, start: int, length: int) -> memoryview:
        """A writable view of the destination for ``[start, start +
        length)``; socket bytes are received directly into it."""
        ...

    def commit(self, start: int, nbytes: int) -> None:
        """``nbytes`` at ``start`` landed (already written via
        :meth:`writable`); account for them."""
        ...

    def covered_intervals(self) -> list:
        """Committed coverage as sorted disjoint ``(start, nbytes)``
        pairs."""
        ...


class BufferSink:
    """A preallocated in-memory destination implementing :class:`Sink`.

    The swarm-restore building block: each restoring node lands its
    blob here and mounts the same object on a ``PeerMirror`` — committed
    bytes are immutable thereafter, so server threads may read them
    concurrently with the ongoing transfer.
    """

    def __init__(self, size: int):
        self._buf = bytearray(size)
        self._covered: list[tuple[int, int]] = []    # disjoint [s, e)
        #: re-delivered byte count (overlapping/duplicate commits)
        self.duplicate_bytes = 0

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def total_bytes(self) -> int:
        return len(self._buf)

    def writable(self, start: int, length: int) -> memoryview:
        return memoryview(self._buf)[start:start + length]

    def commit(self, start: int, nbytes: int) -> None:
        if nbytes <= 0:
            return
        fresh = claim_interval(self._covered, start, start + nbytes)
        self.duplicate_bytes += nbytes - sum(e - s for s, e in fresh)

    def covered_intervals(self) -> list[tuple[int, int]]:
        return [(s, e - s) for s, e in list(self._covered)]

    def __bytes__(self) -> bytes:
        return bytes(self._buf)

    @property
    def view(self) -> memoryview:
        """Read/write view of the whole buffer (what a mirror serves)."""
        return memoryview(self._buf)


class CallableSink:
    """Adapt a legacy callable ``sink(start, view)`` to :class:`Sink`.

    ``writable`` hands the client a per-range scratch buffer; ``commit``
    forwards the landed bytes to the callable as a transient view (valid
    only during the call, exactly like the legacy direct path) and then
    releases the scratch.  Coverage is tracked so protocol-typed
    consumers can introspect progress, but nothing is retained — see the
    module docstring for what that rules out.
    """

    #: scratch-backed: ``writable(0, total)`` is NOT the landed bytes, so
    #: a :class:`~repro.transfer.mirror.PeerMirror` refuses to mount one
    #: (it would advertise coverage over a zero-filled buffer).
    mirrorable = False

    def __init__(self, fn: Callable[[int, memoryview], None]):
        self._fn = fn
        self._scratch: dict[int, bytearray] = {}
        self._covered: list[tuple[int, int]] = []

    def writable(self, start: int, length: int) -> memoryview:
        buf = bytearray(length)
        self._scratch[start] = buf
        return memoryview(buf)

    def commit(self, start: int, nbytes: int) -> None:
        buf = self._scratch.pop(start, None)
        if buf is None or nbytes <= 0:
            return
        self._fn(start, memoryview(buf)[:nbytes])
        claim_interval(self._covered, start, start + nbytes)

    def covered_intervals(self) -> list[tuple[int, int]]:
        return [(s, e - s) for s, e in list(self._covered)]
