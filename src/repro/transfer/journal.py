"""Append-only resume journal for crash-resumable transfers.

pcircle-style checkpointing adapted to a byte-range transfer: instead of
periodically pickling the whole work queue, every *committed* range appends
one small interval record — ``start nbytes crc32`` — to a plain-text log,
fsync'd every ``sync_interval_bytes`` of payload (the checkpoint interval).
A crashed client replays the journal, re-verifies each journaled range
against the destination (the CRC catches data that never made it to stable
storage even though its record did), and requests only the uncovered
intervals.

File format (one record per line, text, order = commit order)::

    {"magic": "mdtp-journal/1", "total": 8388608, "meta": {...}}
    0 262144 3698431063
    262144 524288 193462913
    ...

The header pins the file size and caller metadata (checkpoint step, path):
a journal whose header does not match the transfer being resumed is
discarded rather than trusted.  A torn tail line (crash mid-append) is
detected by parse failure and truncated away on open.

Records may overlap across crash/retry generations; consumers take the
union of the ranges that verify.
"""

from __future__ import annotations

import bisect
import json
import os
from typing import Iterable, Optional

__all__ = [
    "ResumeJournal",
    "claim_interval",
    "merge_intervals",
    "uncovered_intervals",
]

_MAGIC = "mdtp-journal/1"


def merge_intervals(
    intervals: Iterable[tuple[int, int]],
) -> list[tuple[int, int]]:
    """Union of ``(start, length)`` intervals as a sorted disjoint list."""
    spans = sorted((s, s + n) for s, n in intervals if n > 0)
    out: list[tuple[int, int]] = []
    for lo, hi in spans:
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return [(lo, hi - lo) for lo, hi in out]


def claim_interval(
    covered: list[tuple[int, int]], start: int, end: int,
) -> list[tuple[int, int]]:
    """Incrementally merge ``[start, end)`` into ``covered`` in place.

    ``covered`` is a sorted disjoint list of ``(start, end)`` half-open
    pairs (NOT ``(start, length)`` — this is the in-memory incremental
    form; :func:`merge_intervals` is the batch form over length pairs).
    Returns the sub-spans of ``[start, end)`` that were *not* already
    covered, i.e. the bytes this claim newly accounts for.  Claiming an
    already-covered span returns ``[]`` and leaves the list unchanged,
    which makes double commits idempotent for every consumer — the
    resume journal, the streaming-restore sink, and the peer-mirror
    advertisement all share this one implementation.
    """
    if end <= start:
        return []
    lo = bisect.bisect_left(covered, (start,)) - 1
    if lo >= 0 and covered[lo][1] >= start:
        first = lo
    else:
        first = lo + 1
    new: list[tuple[int, int]] = []
    pos = start
    last = first
    while last < len(covered) and covered[last][0] <= end:
        s, e = covered[last]
        if s > pos:
            new.append((pos, s))
        pos = max(pos, e)
        last += 1
    if pos < end:
        new.append((pos, end))
    if new:
        merged_s = min(start, covered[first][0]) if first < last else start
        merged_e = max(end, covered[last - 1][1]) if first < last else end
        covered[first:last] = [(merged_s, merged_e)]
    return new


def uncovered_intervals(
    covered: Iterable[tuple[int, int]], total: int,
) -> list[tuple[int, int]]:
    """Complement of ``covered`` (disjoint, sorted) within ``[0, total)``."""
    out: list[tuple[int, int]] = []
    pos = 0
    for s, n in covered:
        if s > pos:
            out.append((pos, s - pos))
        pos = max(pos, s + n)
    if pos < total:
        out.append((pos, total - pos))
    return out


class ResumeJournal:
    """One transfer's append-only interval log.

    Use :meth:`open` — it validates an existing journal's header against
    the transfer's identity (total size + caller metadata) and either
    resumes appending after the last well-formed record or starts fresh.
    """

    def __init__(
        self,
        path: str,
        total_bytes: int,
        meta: Optional[dict] = None,
        sync_interval_bytes: int = 8 * 1024 * 1024,
    ):
        self.path = path
        self.total_bytes = int(total_bytes)
        self.meta = dict(meta or {})
        self.sync_interval_bytes = int(sync_interval_bytes)
        self._records: list[tuple[int, int, Optional[int]]] = []
        self._file = None
        self._unsynced = 0

    # -- construction -----------------------------------------------------

    @classmethod
    def open(
        cls,
        path: str,
        total_bytes: int,
        meta: Optional[dict] = None,
        sync_interval_bytes: int = 8 * 1024 * 1024,
    ) -> "ResumeJournal":
        """Open for append, replaying prior records if the header matches.

        A missing file, a header mismatch (different size / metadata ⇒ a
        different transfer), or an unreadable header all start a fresh
        journal; a torn tail line is truncated off so later appends stay
        parseable.
        """
        jr = cls(path, total_bytes, meta, sync_interval_bytes)
        good_end = jr._load()
        if good_end is None:
            jr._file = open(path, "w", encoding="ascii")
            jr._file.write(json.dumps(
                {"magic": _MAGIC, "total": jr.total_bytes, "meta": jr.meta},
                sort_keys=True) + "\n")
            jr._file.flush()
            os.fsync(jr._file.fileno())
        else:
            f = open(path, "r+", encoding="ascii")
            f.truncate(good_end)
            f.seek(good_end)
            jr._file = f
        return jr

    def _load(self) -> Optional[int]:
        """Parse an existing journal; returns the byte offset just past the
        last well-formed line, or None if the journal is absent/foreign."""
        try:
            with open(self.path, "r", encoding="ascii") as f:
                raw = f.read()
        except (OSError, UnicodeDecodeError):
            return None
        # a record is only committed once its newline hits the file: the
        # final split element is either "" (clean tail) or a torn append
        # — torn lines can PARSE (a number cut short is still a number,
        # a lost CRC field looks like a crc-less record) so termination,
        # not parseability, is the validity test
        lines = raw.split("\n")
        if len(lines) < 2:
            return None
        try:
            header = json.loads(lines[0])
        except (json.JSONDecodeError, ValueError):
            return None
        if (header.get("magic") != _MAGIC
                or header.get("total") != self.total_bytes
                or header.get("meta") != self.meta):
            return None
        good_end = len(lines[0]) + 1
        for line in lines[1:-1]:
            if not line:
                break
            parts = line.split()
            try:
                start, nbytes = int(parts[0]), int(parts[1])
                crc = int(parts[2]) if len(parts) > 2 else None
                if crc is not None and not (0 <= crc < 2 ** 32):
                    break
            except (ValueError, IndexError):
                break
            if start < 0 or nbytes <= 0 or start + nbytes > self.total_bytes:
                break
            self._records.append((start, nbytes, crc))
            good_end += len(line) + 1
        return good_end

    # -- appending --------------------------------------------------------

    def record(self, start: int, nbytes: int, crc: Optional[int] = None) -> None:
        """Append one committed interval; fsyncs every checkpoint interval."""
        if self._file is None:
            raise ValueError("journal is closed")
        if crc is None:
            self._file.write(f"{start} {nbytes}\n")
        else:
            self._file.write(f"{start} {nbytes} {crc}\n")
        self._records.append((start, nbytes, crc))
        self._unsynced += nbytes
        if self._unsynced >= self.sync_interval_bytes:
            self.sync()

    def sync(self) -> None:
        """Flush + fsync pending records (cheap: the log is tiny)."""
        if self._file is not None:
            self._file.flush()
            os.fsync(self._file.fileno())
            self._unsynced = 0

    # -- reading ----------------------------------------------------------

    def records(self) -> list[tuple[int, int, Optional[int]]]:
        """All records (replayed + appended), in append order."""
        return list(self._records)

    def covered(self) -> list[tuple[int, int]]:
        """Union of all journaled intervals (no CRC verification — callers
        with a readable destination should verify per record instead)."""
        return merge_intervals((s, n) for s, n, _ in self._records)

    # -- lifecycle --------------------------------------------------------

    def close(self) -> None:
        if self._file is not None:
            self.sync()
            self._file.close()
            self._file = None

    def complete(self) -> None:
        """The transfer finished: the journal has no future value."""
        self.close()
        try:
            os.remove(self.path)
        except OSError:
            pass

    def __enter__(self) -> "ResumeJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
