"""Sharded checkpointing with atomic commits and MDTP multi-source restore.

Format (one directory per step):
    step_00001000/
      data.bin        all leaves packed back-to-back (byte offsets in manifest)
      manifest.json   step, leaf paths/shapes/dtypes/offsets; written LAST via
                      tmp+rename => a directory with a manifest is complete.

Packing everything into one blob is deliberate: a restore is then exactly
the paper's problem — one large object, replicated on several mirrors —
and ``restore(..., replicas=...)`` pulls it with MDTP adaptive byte-range
chunking across all mirrors at once (``repro.transfer.MDTPClient``).  After
a node failure or an elastic re-scale this is the path that gets thousands
of hosts back to work; a dead mirror mid-restore just means its range goes
back to the pool (each byte still fetched exactly once).

Elasticity: ``restore`` takes target shardings — leaves are ``device_put``
to whatever mesh the NEW job runs, so restoring 16x16 state onto 2x16x16
(or a reduced salvage mesh) is the same call.

Fault-tolerance inventory (tested in tests/test_checkpoint.py):
  * atomic manifests -> a crashed save never corrupts restore state,
  * keep-last-k GC never deletes the newest complete step,
  * async save thread -> training continues during serialization,
  * multi-source restore tolerates mirror death mid-transfer.
"""

from __future__ import annotations

import bisect
import contextlib
import gc
import json
import os
import shutil
import threading
from dataclasses import dataclass, replace as _dc_replace
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.transfer.client import MDTPClient, NoTelemetryError, Replica
from repro.transfer.journal import ResumeJournal, claim_interval

__all__ = ["CheckpointManager", "RestoreOptions", "save_checkpoint",
           "restore_checkpoint", "latest_step"]

_MANIFEST = "manifest.json"
_DATA = "data.bin"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def _leaf_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(root: str, step: int, state: Any) -> str:
    """Blocking save.  Returns the committed directory."""
    d = _step_dir(root, step)
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, _ = _leaf_paths(state)
    manifest = {"step": step, "format": 1, "leaves": []}
    offset = 0
    with open(os.path.join(tmp, _DATA), "wb") as f:
        for key, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            raw = arr.tobytes()
            manifest["leaves"].append({
                "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "offset": offset, "nbytes": len(raw),
            })
            f.write(raw)
            offset += len(raw)
        f.flush()
        os.fsync(f.fileno())
    manifest["total_bytes"] = offset
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(mpath + ".tmp", mpath)     # manifest-last commit inside tmp
    if os.path.exists(d):
        shutil.rmtree(d)
    os.rename(tmp, d)                    # atomic publish
    return d


def latest_step(root: str) -> Optional[int]:
    """Newest step with a COMPLETE manifest (crashed saves are ignored)."""
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, _MANIFEST)):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
    return max(steps) if steps else None


class _StreamingRestore:
    """Range sink for ``MDTPClient.fetch``: overlap network with H2D.

    Ranges land in a preallocated buffer, and the moment the last byte of
    a leaf's range arrives that leaf is ``device_put`` — so host→device
    transfers of early leaves run while later leaves are still on the
    wire, instead of serially after the whole blob is buffered.

    Implements the client's **zero-copy sink protocol**
    (``writable(start, length) -> memoryview`` + ``commit(start,
    nbytes)``): the transfer layer receives socket bytes directly into
    this sink's preallocated blob buffer, so the restore path is
    copy-free from socket to leaf buffer (the only remaining move is the
    inherent host→device ``device_put``).  The legacy ``sink(start,
    data)`` callable is kept (write-then-commit) for callers that hold
    their own bytes.

    Deliveries may **overlap or repeat**: the sink tracks covered byte
    intervals and only decrements per-leaf countdowns for bytes seen for
    the first time, so a duplicated or partially-overlapping range (a
    retried wave, a speculative re-fetch, a buggy transport) can neither
    double-materialize a leaf nor drive a countdown negative.  The normal
    client path still delivers each byte exactly once — the interval set
    then holds one entry per contiguous landed region and costs O(log n)
    per call.
    """

    def __init__(self, manifest: dict, like: Any,
                 shardings: Optional[Any] = None,
                 spool_path: Optional[str] = None):
        self._covered: list[tuple[int, int]] = []   # disjoint [s, e), sorted
        self.duplicate_bytes = 0                    # re-delivered byte count
        leaves, self._treedef = _leaf_paths(like)
        by_key = {e["key"]: e for e in manifest["leaves"]}
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        total = int(manifest["total_bytes"])
        self.total_bytes = total
        self._mmap = None
        self._spool_file = None
        if spool_path is None or total == 0:
            self._buf = bytearray(total)
        else:
            # crash-resumable restore: the landing buffer is a file-backed
            # mmap, so bytes that reached the page cache (and were then
            # journaled + fsync'd by the client) survive a process death.
            # An existing spool's content is preserved — the resume path
            # re-verifies journaled CRCs against exactly these bytes.
            import mmap

            f = open(spool_path, "a+b")
            try:
                f.seek(0, os.SEEK_END)
                if f.tell() != total:
                    f.truncate(total)
                self._mmap = mmap.mmap(f.fileno(), total)
            except BaseException:
                f.close()
                raise
            self._spool_file = f
            self._buf = self._mmap
        self._out: list = [None] * len(leaves)
        # slots ordered by blob offset for bisect lookup of landed ranges
        order = sorted(
            range(len(leaves)), key=lambda i: by_key[leaves[i][0]]["offset"])
        self._entries = []
        self._remaining = []
        self._slot_of = []
        self._shards = []
        self._starts = []
        for i in order:
            e = by_key[leaves[i][0]]
            self._entries.append(e)
            self._remaining.append(int(e["nbytes"]))
            self._slot_of.append(i)
            self._shards.append(shard_leaves[i])
            self._starts.append(int(e["offset"]))
        # zero-byte leaves (empty arrays) have nothing on the wire
        for j, rem in enumerate(self._remaining):
            if rem == 0:
                self._materialize(j)

    def _claim_new(self, start: int, end: int) -> list[tuple[int, int]]:
        """Merge ``[start, end)`` into the covered set; return only the
        subspans that were not already covered (first-time bytes).  The
        merge itself is ``journal.claim_interval`` — the same code that
        backs the resume journal, so the peer-mirror advertisement
        (:meth:`covered_intervals`) has exactly one source of truth."""
        return claim_interval(self._covered, start, end)

    def covered_intervals(self) -> list[tuple[int, int]]:
        """Committed coverage as sorted disjoint ``(start, nbytes)`` pairs
        — the :class:`repro.transfer.Sink` accessor a peer mirror
        advertises over the wire.  Safe to call from server threads while
        the restore is still streaming: the covered list only ever grows,
        and each commit replaces it with a single atomic slice assign."""
        return [(s, e - s) for s, e in list(self._covered)]

    def writable(self, start: int, length: int) -> memoryview:
        """Zero-copy destination for ``[start, start + length)``: the
        transfer layer reads socket bytes straight into this view, then
        calls :meth:`commit` for the bytes that actually landed."""
        return memoryview(self._buf)[start:start + length]

    def sink(self, start: int, data) -> None:
        """Legacy byte-delivery path: copy ``data`` (bytes or a transient
        memoryview) into place, then account for it."""
        end = start + len(data)
        if end <= start:
            return
        self._buf[start:end] = data
        self.commit(start, len(data))

    def commit(self, start: int, nbytes: int) -> None:
        """Account for ``nbytes`` landed at ``start`` (already in the
        buffer — via :meth:`writable` or :meth:`sink`)."""
        end = start + nbytes
        if end <= start:
            return
        fresh = self._claim_new(start, end)
        self.duplicate_bytes += (end - start) - sum(e - s for s, e in fresh)
        # Two phases so an exception can't corrupt the accounting: pure
        # counter arithmetic first (cannot throw; coverage is already
        # committed, so a re-delivery after a failure below is a clean
        # duplicate no-op), then the device_puts.  A leaf whose
        # _materialize raises keeps remaining == 0 with its bytes safely
        # in the buffer — finish() retries it from there.
        completed = []
        for span_start, span_end in fresh:
            completed.extend(self._account(span_start, span_end))
        for j in completed:
            self._materialize(j)

    def _account(self, start: int, end: int) -> list[int]:
        """Decrement leaf countdowns for a first-time byte span; return the
        indices of leaves that just completed."""
        completed = []
        j = max(bisect.bisect_right(self._starts, start) - 1, 0)
        while j < len(self._entries) and self._starts[j] < end:
            e = self._entries[j]
            leaf_end = self._starts[j] + int(e["nbytes"])
            overlap = min(end, leaf_end) - max(start, self._starts[j])
            if overlap > 0:
                self._remaining[j] -= overlap
                if self._remaining[j] == 0:
                    completed.append(j)
            j += 1
        return completed

    def _materialize(self, j: int) -> None:
        e = self._entries[j]
        arr = np.frombuffer(
            self._buf, dtype=np.dtype(e["dtype"]),
            count=int(np.prod(e["shape"])) if e["shape"] else 1,
            offset=int(e["offset"])).reshape(e["shape"])
        if self._mmap is not None:
            # device_put may alias aligned host memory on CPU backends;
            # never hand XLA a view of the spool mmap we intend to unmap.
            arr = arr.copy()
        shd = self._shards[j]
        self._out[self._slot_of[j]] = (
            jax.device_put(arr, shd) if shd is not None
            else jax.device_put(arr))

    def finish(self, require_all: bool = True) -> Any:
        """Assemble the restored pytree.  ``require_all=False`` is the
        sharded-restore contract: leaves this host's span never covered
        stay ``None`` in the tree (they belong to other hosts)."""
        missing = [self._entries[j]["key"]
                   for j, r in enumerate(self._remaining) if r != 0]
        if missing and require_all:
            raise IOError(f"restore incomplete, leaves missing bytes: "
                          f"{missing[:5]}")
        # retry any leaf whose earlier device_put failed transiently mid-
        # stream (its bytes are complete in the buffer)
        for j, r in enumerate(self._remaining):
            if r == 0 and self._out[self._slot_of[j]] is None:
                self._materialize(j)
        return jax.tree_util.tree_unflatten(self._treedef, self._out)

    def close(self) -> None:
        """Release the spool mmap (no-op for in-memory restores).  Only
        safe once every materialized leaf is off the buffer — the restore
        path blocks on the device arrays before calling this."""
        if self._mmap is not None:
            try:
                self._mmap.close()
            except BufferError:
                # A transient view (e.g. a writable() slice pinned by a
                # traceback) is still exported; collect and retry, and if
                # one survives even that, leave the map for process exit —
                # the spool is scratch state, leaking it is benign.
                gc.collect()
                with contextlib.suppress(BufferError):
                    self._mmap.close()
            self._mmap = None
        if self._spool_file is not None:
            self._spool_file.close()
            self._spool_file = None


def _rebuild(manifest: dict, blob: bytes, like: Any,
             shardings: Optional[Any] = None) -> Any:
    leaves, treedef = _leaf_paths(like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (key, leaf), shd in zip(leaves, shard_leaves):
        e = by_key[key]
        arr = np.frombuffer(
            blob, dtype=np.dtype(e["dtype"]), count=int(
                np.prod(e["shape"])) if e["shape"] else 1,
            offset=e["offset"]).reshape(e["shape"])
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def _finish_restore(stream: _StreamingRestore, jr, spool: Optional[str],
                    require_all: bool = True):
    """Assemble the restored tree; for resumable restores, retire the
    scratch state (journal + spool) once every leaf is safely on device —
    ``device_put`` dispatch is async, so block before unmapping the spool
    the arrays were read from."""
    state = stream.finish(require_all)
    if jr is not None:
        jax.block_until_ready(state)
        jr.complete()
        stream.close()
        if spool is not None:
            with contextlib.suppress(OSError):
                os.remove(spool)
    return state


@dataclass(frozen=True)
class RestoreOptions:
    """Consolidated tail options for :func:`restore_checkpoint`.

    Groups what used to be a growing tail of bare keyword arguments; the
    bare kwargs still work (a compatibility shim folds them in, explicit
    kwargs overriding the dataclass) so no existing caller changes.

    ``mirror`` is the peer-assisted broadcast hook: a
    ``repro.transfer.PeerMirror`` that is bound to the restore's
    streaming sink as soon as the blob size is known — committed ranges
    become servable to other restoring nodes while this restore is still
    in flight.  For crash-resumable restores (``resume=``) the mirror is
    unbound when the restore ends (the spool mmap dies with it);
    in-memory restores keep serving until the caller stops the mirror.
    """

    tuner: Any = None
    wave_bytes: Optional[int] = None
    manager: Any = None
    resume: Optional[str] = None
    mirror: Any = None
    #: sharded restore: ``(host, plan_or_k)`` — fetch only this host's
    #: span of the blob.  ``plan_or_k`` is a ``repro.transfer.ShardPlan``
    #: or an int K (the plan is then derived here, snapped to manifest
    #: leaf boundaries so every tensor lands whole).  Leaves outside the
    #: span come back ``None``; pair with ``mirror=`` so peers (or a
    #: work-stealing ``fetch_sharded`` fleet) can drain this host's span.
    shard_plan: Any = None


def restore_checkpoint(
    root: str,
    like: Any,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
    replicas: Optional[Sequence[Replica]] = None,
    options: Optional[RestoreOptions] = None,
    *,
    tuner: Any = None,
    wave_bytes: Optional[int] = None,
    manager: Any = None,
    resume: Optional[str] = None,
    mirror: Any = None,
    shard_plan: Any = None,
) -> tuple[Any, int]:
    """Restore (state, step).

    ``like``: a pytree with the target structure (shapes are taken from the
    manifest, so this may be abstract).  ``replicas``: mirror list — when
    given, ``data.bin`` is fetched with MDTP multi-source ranges instead of
    local reads (``root`` is then only used to discover the step if not
    given and may not exist locally), **streamed**: each leaf is
    ``device_put`` as soon as its byte range completes, overlapping the
    network transfer with host→device copies instead of buffering the
    whole blob first.

    ``wave_bytes`` splits the blob fetch into sequential waves of that
    many bytes and **re-tunes chunk geometry between waves** from the
    previous wave's measured per-replica throughput and RTT — a long
    multi-leaf restore then tracks mirror throttles and latency steps
    mid-restore instead of riding its initial (C, L) to the end.  With a
    ``tuner`` (a ``repro.core.online`` policy: ``BanditTuner``,
    ``MCGradTuner``, ``GridTuner``) each wave boundary feeds the tuner
    one telemetry snapshot — exactly one update per wave, so a bandit's
    reward attribution stays aligned with the params the wave actually
    ran under; without one, each boundary runs the client's fused grid
    ``retune`` (skipped quietly when a wave produced no usable
    observations).  A single-fetch restore (no ``wave_bytes``) instead
    passes the tuner to the client's in-transfer telemetry hook.

    ``manager`` (a ``repro.transfer.TransferManager``) routes the
    manifest and blob fetches through a shared fleet: per-replica
    in-flight caps apply across every transfer the manager runs,
    telemetry aggregates into its fleet model, residual-capacity packing
    shapes this restore's rounds, and the geometry this restore adopts
    warm-starts the manager's next transfer.  With a manager that owns a
    tuner (and no explicit ``tuner=``), adaptation happens through the
    manager's shared in-fetch hook and the between-wave grid re-tune is
    skipped — one owner for reward attribution.  An explicit ``tuner=``
    always wins: the manager's hook is silenced for this restore and the
    wave-boundary updates feed the given tuner exactly as without a
    manager.

    ``resume`` (a scratch directory path; replica restores only) makes
    the restore **crash-resumable**: ranges land in a file-backed spool
    (``<resume>/data.spool``) and every committed range is journaled with
    its CRC32 (``<resume>/journal.log``, fsync'd at the journal's
    checkpoint interval).  Re-running the same restore after a crash
    replays the journal, re-verifies each journaled range against the
    spool, and fetches only what is missing — the mirrors serve the
    uncovered bytes, not the whole blob again.  On success both files
    are deleted (a completed restore has nothing to resume).

    ``shard_plan`` (``(host, plan_or_k)``; replica restores only) makes
    this a **sharded** restore: the process fetches only its host's span
    of ``data.bin`` (a ``repro.transfer.ShardPlan``, or an int K from
    which the plan is derived on the spot, snapped to manifest leaf
    boundaries).  Leaves outside the span come back ``None`` — the other
    hosts of the mesh restore them; combine with ``mirror=`` so peers
    can pull this host's span, and see ``repro.transfer.fetch_sharded``
    for the in-process work-stealing orchestration of K such fetches.

    ``options`` (a :class:`RestoreOptions`) is the consolidated form of
    the tail kwargs above plus ``mirror=`` — a
    ``repro.transfer.PeerMirror`` that serves this restore's landed
    ranges to other restoring nodes (peer-assisted broadcast).  Bare
    kwargs keep working and override the dataclass field-for-field.
    """
    opts = options if options is not None else RestoreOptions()
    overrides = {k: v for k, v in {
        "tuner": tuner, "wave_bytes": wave_bytes, "manager": manager,
        "resume": resume, "mirror": mirror,
        "shard_plan": shard_plan}.items() if v is not None}
    if overrides:
        opts = _dc_replace(opts, **overrides)
    tuner, wave_bytes, manager = opts.tuner, opts.wave_bytes, opts.manager
    resume, mirror, shard_plan = opts.resume, opts.mirror, opts.shard_plan

    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {root}")
    d = _step_dir(root, step)

    if replicas:
        base = [Replica(r.host, r.port,
                        r.path.rstrip("/") + f"/step_{step:010d}")
                for r in replicas]
        import asyncio

        @contextlib.asynccontextmanager
        async def client_for(reps):
            """A transfer client for this restore: fleet-managed (shared
            caps/telemetry/params) when a manager is given, standalone
            otherwise.  An explicit ``tuner=`` silences the manager's
            in-fetch hook so wave-boundary updates are the only feed."""
            if manager is not None:
                kw = {"tuner": None} if tuner is not None else {}
                async with manager.session(replicas=reps, **kw) as c:
                    yield c
            else:
                yield MDTPClient(reps)

        # the between-wave fused grid re-tune runs only when nobody else
        # owns adaptation (no explicit tuner, no manager-shared tuner)
        grid_retune = tuner is None and getattr(manager, "tuner", None) is None

        async def run():
            async with client_for(
                    [Replica(r.host, r.port, r.path + "/" + _MANIFEST)
                     for r in base]) as mclient:
                msize = await mclient.blob_size()
                mbuf, _ = await mclient.fetch(msize)
            manifest = json.loads(bytes(mbuf).decode())
            total = int(manifest["total_bytes"])
            lo, hi = 0, total
            if shard_plan is not None:
                # (host, plan-or-K): this process fetches only its span.
                # An int K derives the plan here, snapped to manifest
                # leaf boundaries — every host computes the same cuts
                # from the same manifest, no coordination needed.
                from repro.transfer.shard import (ShardPlan,
                                                  manifest_boundaries,
                                                  plan_shards)

                host, plan = shard_plan
                if not isinstance(plan, ShardPlan):
                    plan = plan_shards(total, int(plan),
                                       manifest_boundaries(manifest))
                lo, hi = plan.span_of(int(host))
            jr = None
            spool = None
            if resume is not None:
                os.makedirs(resume, exist_ok=True)
                spool = os.path.join(resume, "data.spool")
                # the journal is bound to (total, step): a scratch dir
                # left over from a DIFFERENT restore fails the header
                # check and starts fresh instead of poisoning this one
                jr = ResumeJournal.open(
                    os.path.join(resume, "journal.log"),
                    total_bytes=total, meta={"step": int(step)})
            stream = _StreamingRestore(manifest, like, shardings,
                                       spool_path=spool)
            if mirror is not None:
                # peer-assisted broadcast: landed ranges become servable
                # to other restorers while this restore is in flight
                mirror.bind(stream, total)
            try:
                return await _restore_waves(stream, jr, spool, lo, hi,
                                            dclient_factory=lambda: client_for(
                                                [Replica(r.host, r.port,
                                                         r.path + "/" + _DATA)
                                                 for r in base]))
            finally:
                # idempotent: a successful restore already retired these;
                # on failure the journal handle is released with its
                # records flushed (the client syncs on the way out), so a
                # re-run — same process or not — can resume cleanly
                if jr is not None:
                    jr.close()
                if mirror is not None and spool is not None:
                    # the spool mmap dies with the restore — stop serving
                    # from it before it is unmapped (in-memory restores
                    # keep serving; their buffer outlives the call)
                    mirror.unbind()
                stream.close()

        async def _restore_waves(stream, jr, spool, lo, hi, dclient_factory):
            # sharded restores fetch only [lo, hi) of the blob; the rest
            # of the tree stays unmaterialized (require_all=False below)
            span = hi - lo
            require_all = shard_plan is None
            async with dclient_factory() as dclient:
                # the stream object carries the writable/commit zero-copy
                # protocol: ranges are received straight into its buffer
                if not wave_bytes or wave_bytes >= span:
                    if span > 0:
                        await dclient.fetch(span, sink=stream, offset=lo,
                                            tuner=tuner, resume=jr)
                    return _finish_restore(stream, jr, spool, require_all)
                pos = lo
                while pos < hi:
                    n = min(int(wave_bytes), hi - pos)
                    _, report = await dclient.fetch(n, sink=stream,
                                                    offset=pos, resume=jr)
                    pos += n
                    if pos >= hi:
                        break
                    next_wave = min(int(wave_bytes), hi - pos)
                    if tuner is None:
                        if not grid_retune:
                            continue    # the manager's shared tuner owns
                            # adaptation via the in-fetch hook
                        try:
                            dclient.retune(next_wave)
                        except NoTelemetryError:
                            pass    # wave yielded no live observations; a
                            # real sweep failure (XlaRuntimeError) propagates
                    else:
                        # per-wave telemetry snapshot from the wave's report.
                        # The tuner is fed HERE only (not via the client's
                        # in-fetch hook): one update per wave keeps a
                        # bandit's reward attributed to the params the whole
                        # wave actually ran under.
                        from repro.core.online import Telemetry

                        try:
                            new = tuner.update(Telemetry.from_report(
                                report, dclient.replicas, next_wave))
                        except Exception:
                            # same contract as the client's in-transfer hook:
                            # a failing tuner must never fail a restore whose
                            # waves are streaming fine — keep the current
                            # geometry and carry on
                            new = None
                        if new is not None:
                            dclient.adopt_params(new)
            return _finish_restore(stream, jr, spool, require_all)

        return asyncio.run(run()), step

    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    with open(os.path.join(d, _DATA), "rb") as f:
        blob = f.read()
    return _rebuild(manifest, blob, like, shardings), step


@dataclass
class CheckpointManager:
    """Save-every-N with async commit and keep-last-k GC."""

    root: str
    every_steps: int = 100
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, state: Any) -> bool:
        if step % self.every_steps != 0:
            return False
        self.wait()
        # snapshot on the host before handing off (training may mutate)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_state), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, host_state)
        return True

    def _save_and_gc(self, step: int, state: Any) -> None:
        save_checkpoint(self.root, step, state)
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.root, n, _MANIFEST)))
        for s in steps[:-self.keep]:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
