"""Checkpointing: atomic sharded saves + MDTP multi-source elastic restore."""

from .manager import (CheckpointManager, latest_step, restore_checkpoint,
                      save_checkpoint)

__all__ = ["CheckpointManager", "latest_step", "restore_checkpoint",
           "save_checkpoint"]
