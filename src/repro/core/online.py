"""Online (C, L) tuning from live fleet telemetry.

The offline tuners (``repro.core.autotune``) pick chunk geometry ONCE,
before a transfer starts, from whatever bandwidth estimates are at hand.
MDTP's core claim (§IV-V) is that geometry must *adapt* to observed
conditions — and the paper's throttle / added-latency experiments
(Fig. 6/7) are exactly the cases where a one-shot choice goes stale
mid-transfer.  This module closes the loop: tuners that consume live
:class:`Telemetry` snapshots (per-replica throughput + RTT measurements,
achieved aggregate throughput) and emit fresh ``ChunkParams`` while the
bytes are still flowing.

Three tuners, one ``update(telemetry) -> ChunkParams | None`` contract:

:class:`GridTuner`
    Re-runs the fused one-shot grid sweep per update — the ``retune``
    workflow packaged as an online policy (simulation-trusting, no
    memory).

:class:`MCGradTuner` / :func:`tune_chunk_params_mcgrad`
    Jitter-smoothed Monte-Carlo gradient descent.  Transfer time is a
    sawtooth in (C, L): smooth within a fixed round count with downward
    jumps where the file packs into one fewer round, so the single-path
    gradient of ``tune_chunk_params_grad`` sees only the within-basin
    slope and is blind to RTT amortization (the macro trend lives in the
    jumps).  Averaging the **pathwise gradient over a vmapped batch of
    bandwidth/RTT-jitter seeds** randomizes where the jumps fall, so the
    expected loss is a smoothed sawtooth whose slope DOES reflect the
    across-jump trend — one compile for the whole batch, gradients
    included (cf. the hybrid-RL elastic transfer optimizer of
    arXiv:2511.06159, which learns the same signal model-free).

:class:`BanditTuner`
    A discounted-UCB bandit over a small set of (C, L) arms seeded from
    the grid winner.  Unlike the simulators above, its reward is the
    **measured** aggregate throughput of the bytes actually moved under
    each arm — it trusts the fleet, not the model, so it also corrects
    for everything the simulator doesn't capture (server think time,
    client-side scheduling, estimator lag).  Exponential discounting
    (Garivier & Moulines' D-UCB) keeps old rewards from pinning a stale
    arm after conditions change, and an explicit drift detector resets
    all confidence — and re-seeds the arm set from a fresh sweep — when
    observed per-replica bandwidth departs from the scenario the arms
    were planned for (mirror death, throttle, latency step).

Wiring: ``MDTPClient.fetch(..., tuner=...)`` feeds telemetry between
rounds of requests, and ``repro.checkpoint.restore_checkpoint(...,
wave_bytes=...)`` re-tunes between restore waves — see those modules.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .autotune import (
    GradTuneResult,
    _adam_descend,
    _finish_grad_tune,
    _l_floor_for,
    _z_init,
    autotune_chunk_params,
)
from .chunking import DEFAULT_MIN_CHUNK, ChunkParams
from .jax_alloc import ChunkArrays
from .jax_sim import SimConfig, _prep, simulate_scan_core
from .throughput import rtt_corrected_bandwidth

__all__ = [
    "Telemetry",
    "rtt_corrected_bandwidth",
    "tune_chunk_params_mcgrad",
    "GridTuner",
    "MCGradTuner",
    "BanditTuner",
]

#: fallback request RTT (s) for replicas that never produced a sample —
#: matches ``MDTPClient.DEFAULT_RTT`` / the FABRIC WAN scenarios.
_DEFAULT_RTT = 0.03


@dataclass(frozen=True)
class Telemetry:
    """One live snapshot of fleet state, as the transfer layer sees it.

    Per-replica vectors are positional and FULL-fleet (dead or unprobed
    replicas keep their slot with a ``<= 0`` value) so tuners can track
    replica identity across updates — drift detection needs to know that
    *replica 3* died, not that the vector shrank.
    """

    #: per-replica observed throughput, bytes/s (``<= 0`` = dead/unprobed).
    bandwidth: tuple[float, ...]
    #: per-replica measured request RTT, seconds (``<= 0`` = no sample).
    rtt: tuple[float, ...]
    #: bytes still to move in the current transfer (the tuning objective:
    #: pick geometry for the *remainder*, not the original file).
    remaining_bytes: float
    #: aggregate bytes/s achieved since the previous update — the
    #: measured reward the bandit credits to the arm that was in play.
    measured_throughput: float = 0.0
    #: seconds since the transfer started (diagnostics / traces).
    elapsed: float = 0.0

    def live(self, default_rtt: float = _DEFAULT_RTT
             ) -> tuple[list[float], list[float]]:
        """(bandwidth, rtt) lists over live replicas only, RTT gaps filled
        with ``default_rtt`` — the shape the simulators expect."""
        bw, rtts = [], []
        for b, r in zip(self.bandwidth, self.rtt):
            if b <= 0.0:
                continue
            bw.append(float(b))
            rtts.append(float(r) if r > 0.0 else default_rtt)
        return bw, rtts

    @classmethod
    def from_report(cls, report, replicas,
                    remaining_bytes: float) -> "Telemetry":
        """Snapshot a completed transfer's ``TransferReport`` — the one
        canonical report→telemetry encoding (failed replica = 0.0 slot,
        positional full-fleet vectors, unmeasured RTT = 0.0), shared by
        the checkpoint-restore wave loop and any other batch consumer.
        ``observed_throughputs`` are already WIRE rates — the client
        strips the per-request RTT bias at the observation point
        (``repro.transfer.client.wire_elapsed``) — so they pass through
        uncorrected here; applying ``rtt_corrected_bandwidth`` again
        would overstate capacity.  Duck-typed to avoid a core→transfer
        import."""
        bandwidth = []
        for r in replicas:
            if r.name in report.failed_replicas:
                bandwidth.append(0.0)
                continue
            bandwidth.append(float(
                report.observed_throughputs.get(r.name, 0.0)))
        return cls(
            bandwidth=tuple(bandwidth),
            rtt=tuple(float(report.observed_rtts.get(r.name, 0.0))
                      for r in replicas),
            remaining_bytes=float(remaining_bytes),
            measured_throughput=report.throughput,
            elapsed=report.elapsed,
        )


# --------------------------------------------------------------------------
# Jitter-smoothed Monte-Carlo gradient tuning
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _mc_value_and_grad(mode: str, cfg: SimConfig, n_seeds: int):
    """Compiled seed-averaged loss + gradient, cached per static shape.

    ``file_size`` and the z-space floors ride as TRACED arguments, so an
    online tuner re-planning every wave (each wave a different remaining
    byte count) reuses one executable per (mode, config, n_seeds, fleet
    size) instead of recompiling the scan core per update.
    """
    seeds = jnp.arange(max(n_seeds, 1))

    def mc_loss(z, bw, rtt_a, throttle_t, throttle_bw, file_f,
                min_chunk_f, l_floor_f):
        c = min_chunk_f + jnp.exp(z[0])
        l = l_floor_f + jnp.exp(z[1])
        chunk = ChunkArrays(c, l, min_chunk_f)

        def one(seed):
            return simulate_scan_core(
                bw, rtt_a, throttle_t, throttle_bw, seed, chunk, file_f,
                mode=mode, config=cfg,
            ).total_time

        return jnp.mean(jax.vmap(one)(seeds))

    return jax.jit(jax.value_and_grad(mc_loss))


def tune_chunk_params_mcgrad(
    bandwidth: Sequence[float],
    rtt,
    file_size: int,
    init: tuple[float, float] | None = None,
    steps: int = 40,
    lr: float = 0.08,
    n_seeds: int = 8,
    bw_jitter: float = 0.08,
    rtt_jitter: float = 0.25,
    mode: str = "proportional",
    min_chunk: int = DEFAULT_MIN_CHUNK,
    max_rounds: int = 1024,
    grid: Sequence[tuple[int, int]] | None = None,
    pipeline_depth: int = 1,
    loss_rate: float = 0.0,
    corruption_rate: float = 0.0,
    hedge_quantile: float = 0.0,
    decode_bytes_per_s: float = 0.0,
) -> GradTuneResult:
    """Monte-Carlo (C, L) descent on the scan core: one compile, ``n_seeds``
    pathwise gradients averaged per step.

    ``loss_rate`` / ``corruption_rate`` are the fleet's observed per-chunk
    fault probabilities (``SimConfig`` fault axes): faulted chunks burn
    their duration and are re-fetched, a tax that grows with L — the MC
    seed batch already averages over the fault draws, so the smoothed
    gradient prices it in.

    Each seed draws per-chunk lognormal bandwidth jitter (``bw_jitter``)
    and a per-simulation lognormal RTT scale (``rtt_jitter``), so the
    round-count jump positions differ across the batch and the averaged
    loss surface is a smoothed sawtooth — its gradient sees the RTT
    amortization trend that a single deterministic path reports as zero.
    The descent machinery (floor+exp z-space, Adam, best-seen tracking,
    exact-metric never-worse-than-init guarantee) is shared with
    :func:`repro.core.autotune.tune_chunk_params_grad`; only the loss
    differs.  The reported ``predicted_time`` is the *deterministic*
    exact-sizes round-core time of the adopted integer params.
    """
    bw, rtt_a, throttle_t, throttle_bw = _prep(bandwidth, rtt, None, None)
    file_f = jnp.float32(file_size)
    p_fail = loss_rate + corruption_rate
    if init is None:
        seed_res = autotune_chunk_params(
            bandwidth, rtt, int(file_size), grid=grid, mode=mode,
            pipeline_depth=pipeline_depth,
            loss_rate=loss_rate, corruption_rate=corruption_rate,
            hedge_quantile=hedge_quantile,
            decode_bytes_per_s=decode_bytes_per_s,
            n_seeds=4 if p_fail > 0.0 else 1)
        init = (float(seed_res.params.initial_chunk),
                float(seed_res.params.large_chunk))
    l_floor = _l_floor_for(min_chunk, file_size, max_rounds, p_fail)
    cfg = SimConfig(max_rounds=max_rounds, exact_sizes=False,
                    jitter=bw_jitter, rtt_jitter=rtt_jitter,
                    pipeline_depth=pipeline_depth,
                    loss_rate=loss_rate, corruption_rate=corruption_rate,
                    hedge_quantile=hedge_quantile,
                    decode_bytes_per_s=decode_bytes_per_s)
    vg = _mc_value_and_grad(mode, cfg, max(n_seeds, 1))
    vg_args = (bw, rtt_a, throttle_t, throttle_bw, file_f,
               jnp.float32(min_chunk), jnp.float32(l_floor))
    z0 = _z_init(init, min_chunk, l_floor)
    best_z, history = _adam_descend(vg, z0, steps, lr, args=vg_args)
    return _finish_grad_tune(
        vg, vg_args, best_z, history, init, min_chunk, l_floor, mode,
        bw, rtt_a, throttle_t, throttle_bw, file_f, pipeline_depth,
        loss_rate, corruption_rate, hedge_quantile, decode_bytes_per_s)


# --------------------------------------------------------------------------
# Online tuner policies
# --------------------------------------------------------------------------

@dataclass
class GridTuner:
    """Re-run the fused one-shot grid sweep on every update.

    The simplest online policy: trust the simulator, re-plan from the
    latest measurements.  Stateless beyond the adopted params; the
    baseline the smarter tuners must beat.
    """

    mode: str = "proportional"
    grid: Optional[list[tuple[int, int]]] = None
    default_rtt: float = _DEFAULT_RTT
    #: request pipeline depth of the runtime being tuned — keeps the
    #: simulated RTT amortization honest (``SimConfig.pipeline_depth``).
    pipeline_depth: int = 1
    #: observed per-chunk fault probabilities of the fleet being tuned
    #: (``SimConfig.loss_rate`` / ``corruption_rate``) — re-fetch tax.
    loss_rate: float = 0.0
    corruption_rate: float = 0.0
    #: endgame hedging quantile of the client being tuned
    #: (``SimConfig.hedge_quantile``) — hedging trims the straggler tail
    #: the simulator would otherwise charge to large L.
    hedge_quantile: float = 0.0
    #: client-side decode rate for transfer-encoded bodies
    #: (``SimConfig.decode_bytes_per_s``) — the per-chunk compute tax the
    #: compressed-range path pays; 0 = identity encoding.
    decode_bytes_per_s: float = 0.0
    params: Optional[ChunkParams] = None
    updates: int = 0

    def reset(self) -> None:
        self.params, self.updates = None, 0

    def update(self, t: Telemetry) -> Optional[ChunkParams]:
        bw, rtts = t.live(self.default_rtt)
        if not bw or t.remaining_bytes < 2 * DEFAULT_MIN_CHUNK:
            return None
        self.updates += 1
        p_fail = self.loss_rate + self.corruption_rate
        res = autotune_chunk_params(
            bw, rtts, int(t.remaining_bytes), grid=self.grid, mode=self.mode,
            pipeline_depth=self.pipeline_depth,
            loss_rate=self.loss_rate, corruption_rate=self.corruption_rate,
            hedge_quantile=self.hedge_quantile,
            decode_bytes_per_s=self.decode_bytes_per_s,
            n_seeds=4 if p_fail > 0.0 else 1)
        self.params = res.params
        return res.params


@dataclass
class MCGradTuner:
    """Online wrapper around :func:`tune_chunk_params_mcgrad`.

    Warm-starts each descent from the previously adopted params (the
    basin rarely teleports between updates), falling back to an implicit
    grid seed on the first call or after :meth:`reset`.
    """

    steps: int = 25
    lr: float = 0.08
    n_seeds: int = 8
    bw_jitter: float = 0.08
    rtt_jitter: float = 0.25
    mode: str = "proportional"
    min_chunk: int = DEFAULT_MIN_CHUNK
    max_rounds: int = 1024
    default_rtt: float = _DEFAULT_RTT
    grid: Optional[list[tuple[int, int]]] = None
    #: request pipeline depth of the runtime being tuned (see GridTuner).
    pipeline_depth: int = 1
    #: observed per-chunk fault probabilities (see GridTuner).
    loss_rate: float = 0.0
    corruption_rate: float = 0.0
    #: endgame hedging quantile of the client being tuned (see GridTuner).
    hedge_quantile: float = 0.0
    #: client-side decode rate for encoded bodies (see GridTuner).
    decode_bytes_per_s: float = 0.0
    params: Optional[ChunkParams] = None
    updates: int = 0
    last_result: Optional[GradTuneResult] = None

    def reset(self) -> None:
        self.params, self.updates, self.last_result = None, 0, None

    def update(self, t: Telemetry) -> Optional[ChunkParams]:
        bw, rtts = t.live(self.default_rtt)
        if not bw or t.remaining_bytes < 2 * self.min_chunk:
            return None
        self.updates += 1
        init = None
        if self.params is not None:
            init = (float(self.params.initial_chunk),
                    float(self.params.large_chunk))
        res = tune_chunk_params_mcgrad(
            bw, rtts, int(t.remaining_bytes), init=init,
            steps=self.steps, lr=self.lr, n_seeds=self.n_seeds,
            bw_jitter=self.bw_jitter, rtt_jitter=self.rtt_jitter,
            mode=self.mode, min_chunk=self.min_chunk,
            max_rounds=self.max_rounds, grid=self.grid,
            pipeline_depth=self.pipeline_depth,
            loss_rate=self.loss_rate, corruption_rate=self.corruption_rate,
            hedge_quantile=self.hedge_quantile,
            decode_bytes_per_s=self.decode_bytes_per_s)
        self.params, self.last_result = res.params, res
        return res.params


@dataclass
class _Arm:
    params: ChunkParams
    n: float = 0.0      # discounted play count
    s: float = 0.0      # discounted reward sum

    @property
    def mean(self) -> float:
        return self.s / self.n if self.n > 0.0 else 0.0


@dataclass
class BanditTuner:
    """Discounted-UCB bandit over (C, L) arms, rewarded by measured
    throughput.

    Arms are the ``n_arms`` best grid points of a fused sweep run against
    the telemetry at seeding time (the grid winner plus its strongest
    rivals — the simulator proposes, the fleet disposes).  Each update:

    1. credit ``measured_throughput / sum(live bandwidth)`` (utilization,
       clipped to [0, 2]) to the arm that was in play, after discounting
       every arm's statistics by ``gamma`` — old evidence decays, so the
       bandit stays plastic;
    2. check drift: any live replica whose observed bandwidth or measured
       RTT moved more than ``drift_threshold`` (relative) from the
       seeding scenario — or a replica dying/appearing — re-seeds the
       arms from a fresh sweep and zeroes all confidence (the paper's
       throttle/latency-step events invalidate every reward collected
       under the old regime);
    3. play the arm maximizing ``mean + explore * sqrt(log(N) / n)``
       (unplayed arms first, in predicted-time order).
    """

    n_arms: int = 6
    gamma: float = 0.85
    explore: float = 0.4
    drift_threshold: float = 0.6
    mode: str = "proportional"
    grid: Optional[list[tuple[int, int]]] = None
    default_rtt: float = _DEFAULT_RTT
    #: request pipeline depth of the runtime being tuned (see GridTuner) —
    #: shapes the seeding sweep that proposes the arm set.
    pipeline_depth: int = 1
    #: observed per-chunk fault probabilities (see GridTuner) — shape the
    #: seeding sweep; the measured-throughput reward already prices in
    #: real re-fetch waste without them, so they only affect proposals.
    loss_rate: float = 0.0
    corruption_rate: float = 0.0
    #: endgame hedging quantile of the client being tuned (see GridTuner)
    #: — shapes the seeding sweep's straggler-tail model.
    hedge_quantile: float = 0.0
    #: client-side decode rate for encoded bodies (see GridTuner) —
    #: shapes the seeding sweep; the measured-throughput reward already
    #: prices real decode stalls in.
    decode_bytes_per_s: float = 0.0
    arms: list[_Arm] = field(default_factory=list)
    params: Optional[ChunkParams] = None
    updates: int = 0
    drift_resets: int = 0
    _current: Optional[int] = None
    _seed_bw: Optional[tuple[float, ...]] = None
    _seed_rtt: Optional[tuple[float, ...]] = None

    def reset(self) -> None:
        self.arms, self.params, self._current = [], None, None
        self._seed_bw = self._seed_rtt = None
        self.updates = self.drift_resets = 0

    def _seed_arms(self, t: Telemetry) -> Optional[ChunkParams]:
        bw, rtts = t.live(self.default_rtt)
        if not bw or t.remaining_bytes < 2 * DEFAULT_MIN_CHUNK:
            return None
        p_fail = self.loss_rate + self.corruption_rate
        res = autotune_chunk_params(
            bw, rtts, int(t.remaining_bytes), grid=self.grid, mode=self.mode,
            pipeline_depth=self.pipeline_depth,
            loss_rate=self.loss_rate, corruption_rate=self.corruption_rate,
            hedge_quantile=self.hedge_quantile,
            decode_bytes_per_s=self.decode_bytes_per_s,
            n_seeds=4 if p_fail > 0.0 else 1)
        order = np.argsort(res.predicted_times)
        self.arms = []
        seen = set()
        for k in order:
            c, l = res.grid[int(k)]
            if (c, l) in seen:
                continue
            seen.add((c, l))
            self.arms.append(_Arm(ChunkParams(c, l, mode=self.mode)))
            if len(self.arms) >= self.n_arms:
                break
        self._seed_bw = tuple(t.bandwidth)
        self._seed_rtt = tuple(t.rtt)
        self._current = 0
        self.params = self.arms[0].params
        return self.params

    def _drifted(self, t: Telemetry) -> bool:
        ref_bw, ref_rtt = self._seed_bw, self._seed_rtt
        if ref_bw is None:
            return False
        now_bw, now_rtt = tuple(t.bandwidth), tuple(t.rtt)
        if len(now_bw) != len(ref_bw):
            return True
        log_thresh = math.log1p(self.drift_threshold)
        for b0, b1 in zip(ref_bw, now_bw):
            alive0, alive1 = b0 > 0.0, b1 > 0.0
            if alive0 != alive1:
                return True                      # death or resurrection
            if alive0 and abs(math.log(b1 / b0)) > log_thresh:
                return True
        for r0, r1 in zip(ref_rtt, now_rtt):
            # a latency step (paper §VII-C) invalidates rewards exactly
            # like a throttle does; unmeasured RTTs (<= 0) are skipped
            if r0 > 0.0 and r1 > 0.0 and abs(math.log(r1 / r0)) > log_thresh:
                return True
        return False

    def update(self, t: Telemetry) -> Optional[ChunkParams]:
        self.updates += 1
        if not self.arms:
            return self._seed_arms(t)

        # 1) credit the measured reward to the arm that produced it
        if t.measured_throughput > 0.0 and self._current is not None:
            live_sum = sum(b for b in t.bandwidth if b > 0.0)
            reward = min(t.measured_throughput / max(live_sum, 1e-9), 2.0)
            for arm in self.arms:
                arm.n *= self.gamma
                arm.s *= self.gamma
            played = self.arms[self._current]
            played.n += 1.0
            played.s += reward

        # 2) fleet left the scenario the arms were planned for → replan
        if self._drifted(t):
            self.drift_resets += 1
            seeded = self._seed_arms(t)
            if seeded is not None:
                return seeded
            # nothing live to re-plan from: keep playing the old arms

        # 3) discounted UCB selection
        unplayed = [i for i, a in enumerate(self.arms) if a.n <= 1e-9]
        if unplayed:
            self._current = unplayed[0]      # predicted-time order
        else:
            total = sum(a.n for a in self.arms)
            log_n = math.log(max(total, math.e))
            self._current = max(
                range(len(self.arms)),
                key=lambda i: (self.arms[i].mean
                               + self.explore
                               * math.sqrt(log_n / self.arms[i].n)))
        self.params = self.arms[self._current].params
        return self.params
