"""Per-server throughput estimation.

The paper re-measures throughput from every completed chunk and uses the
latest sample directly ("after obtaining the throughput of all servers in
each iteration", §IV-B).  ``LastSample`` reproduces that.  ``Ewma`` is the
beyond-paper option used by the framework's data plane, where shard fetches
are small and bursty enough that a single sample is noisy.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["ThroughputEstimator", "LastSample", "Ewma", "make_estimator",
           "rtt_corrected_bandwidth"]


def rtt_corrected_bandwidth(throughput: float, rtt: float,
                            mean_chunk_bytes: float) -> float:
    """Invert the per-request estimator's RTT bias.

    A client-side estimator observes ``s / (rtt + s / bw)`` per request —
    its elapsed window spans the whole request round-trip, so the reading
    under-states the wire rate, badly for small chunks on high-RTT paths
    (a 40 MB chunk at 70 MB/s behind 0.5 s RTT reads as ~37 MB/s).  With
    the request RTT measured independently (``observed_rtts``) the line
    rate is recoverable: ``bw = s / (s / v - rtt)``.  Tuners fed
    corrected estimates re-plan against the path's actual capacity
    instead of chasing the bias.  Returns ``throughput`` unchanged when
    the correction is impossible (missing RTT/chunk data, or the implied
    on-wire time is non-positive).

    Lives here (not ``repro.core.online``, which re-exports it) so the
    jax-free transfer client can correct its own telemetry without
    importing the jax-backed tuner stack.
    """
    if throughput <= 0.0 or rtt <= 0.0 or mean_chunk_bytes <= 0.0:
        return throughput
    wire_time = mean_chunk_bytes / throughput - rtt
    if wire_time <= 0.0:
        return throughput
    return mean_chunk_bytes / wire_time


class ThroughputEstimator:
    """Tracks one server's observed throughput in bytes/second."""

    def observe(self, nbytes: int, elapsed: float) -> None:
        raise NotImplementedError

    @property
    def value(self) -> float:
        """Current estimate; 0.0 until the first observation."""
        raise NotImplementedError

    def reset(self) -> None:
        raise NotImplementedError


@dataclass
class LastSample(ThroughputEstimator):
    """The paper's estimator: throughput of the most recent chunk."""

    _value: float = 0.0

    def observe(self, nbytes: int, elapsed: float) -> None:
        if elapsed <= 0.0:
            return
        self._value = nbytes / elapsed

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self._value = 0.0


@dataclass
class Ewma(ThroughputEstimator):
    """Exponentially-weighted moving average of chunk throughputs.

    ``alpha`` is the weight of the newest sample.  ``alpha=1.0`` degrades to
    ``LastSample``.
    """

    alpha: float = 0.5
    _value: float = field(default=0.0, repr=False)
    _seen: bool = field(default=False, repr=False)

    def __post_init__(self) -> None:
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")

    def observe(self, nbytes: int, elapsed: float) -> None:
        if elapsed <= 0.0:
            return
        sample = nbytes / elapsed
        if not self._seen:
            self._value = sample
            self._seen = True
        else:
            self._value = self.alpha * sample + (1.0 - self.alpha) * self._value

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self._value = 0.0
        self._seen = False


def make_estimator(kind: str = "last", alpha: float = 0.5) -> ThroughputEstimator:
    if kind == "last":
        return LastSample()
    if kind == "ewma":
        return Ewma(alpha=alpha)
    raise ValueError(f"unknown estimator kind: {kind!r}")
