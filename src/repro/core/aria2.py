"""Aria2 behavioral model for the simulator.

Aria2 is closed over a different codebase (C++), so we model the behaviors
the paper measured rather than linking the tool:

* a fixed *piece* size (aria2's ``min-split-size``, 20 MiB by default);
* at most ``max_connections`` concurrent segments (aria2 ``-s``, default 5),
  one connection per server (aria2 ``-x1`` per-host default);
* a *feedback* URI selector: when a connection needs a server it probes
  unknown mirrors once (it must measure to rank), then always picks the
  fastest known idle mirror.  With six mirrors and five connections the
  steady-state rotation parks the *slowest* mirror idle — exactly what the
  paper measured (Fig. 5a/5b: 83% utilization, slowest replica unused,
  fastest overloaded).  ``explore_unknown=False`` freezes the initial
  URI-order five instead.

This reproduces aria2's two measured pathologies: it leaves slow-replica
capacity on the table, and its fixed pieces pay one idle RTT per 20 MiB.
"""

from __future__ import annotations

from .simulator import Action, Policy, Request, TransferState

__all__ = ["Aria2Policy"]

MB = 1024 * 1024


class Aria2Policy(Policy):
    name = "aria2"

    def __init__(
        self,
        piece_size: int = 20 * MB,
        max_connections: int = 5,
        explore_unknown: bool = True,
    ):
        self.piece_size = piece_size
        self.max_connections = max_connections
        self.explore_unknown = explore_unknown

    def n_connections(self, n_servers: int) -> int:
        return min(self.max_connections, n_servers)

    def reset(self, n_servers: int, file_size: int) -> None:
        self.n_servers = n_servers
        self.speed = [0.0] * n_servers      # feedback estimates
        self.tried = [False] * n_servers
        self.dead = [False] * n_servers
        self.in_use: set[int] = set()
        self._conn_server: dict[int, int] = {}

    def _pick_server(self, conn: int) -> int | None:
        candidates = [
            s for s in range(self.n_servers)
            if s not in self.in_use and not self.dead[s]
        ]
        if not candidates:
            return None
        known = [s for s in candidates if self.tried[s]]
        unknown = [s for s in candidates if not self.tried[s]]
        if known and (not self.explore_unknown or not unknown):
            # feedback selector: fastest known mirror wins
            return max(known, key=lambda s: self.speed[s])
        if unknown:
            # initial assignment follows URI list order
            return unknown[0]
        return None

    def next_action(self, state: TransferState, conn: int, now: float) -> Action:
        if state.unassigned_bytes() <= 0:
            return None
        server = self._pick_server(conn)
        if server is None:
            return None
        self.tried[server] = True
        self.in_use.add(server)
        self._conn_server[conn] = server
        return Request(server, min(self.piece_size, state.unassigned_bytes()))

    def on_complete(
        self, state: TransferState, conn: int, server: int,
        nbytes: int, elapsed: float, now: float, truncated: bool = False,
    ) -> None:
        self.in_use.discard(server)
        self._conn_server.pop(conn, None)
        if truncated or nbytes == 0:
            self.dead[server] = True
            return
        if elapsed > 0:
            self.speed[server] = nbytes / elapsed
