"""MDTP policy (the paper's Algorithm 1) for the discrete-event simulator.

One persistent connection per server (paper §III-A).  Every time a server
becomes free it asks the bin-packing allocator (``repro.core.chunking``) for
its next range size given the latest throughput estimates of all servers.
A server that breaks a connection mid-chunk is marked dead and its
undelivered bytes are rescheduled onto the surviving replicas — behaviour
the paper does not evaluate but the framework's checkpoint-restore path
requires (fault tolerance beyond the paper; flagged by ``retry_after``).
"""

from __future__ import annotations

from typing import Optional

from .chunking import ChunkParams, default_chunk_params, next_chunk_size
from .simulator import Action, Policy, Request, TransferState, Wait
from .throughput import make_estimator

__all__ = ["MDTPPolicy"]


class MDTPPolicy(Policy):
    name = "mdtp"

    def __init__(
        self,
        params: Optional[ChunkParams] = None,
        estimator: str = "last",
        ewma_alpha: float = 0.5,
        retry_after: float = 0.0,
    ):
        """Args:
        params: allocator constants; ``None`` picks paper Table II defaults
          from the file size at ``reset``.
        estimator: ``"last"`` (paper) or ``"ewma"``.
        retry_after: if > 0, a failed server is retried after this many
          seconds instead of being abandoned (for flaky-replica scenarios).
        """
        self._params_arg = params
        self._estimator_kind = estimator
        self._ewma_alpha = ewma_alpha
        self._retry_after = retry_after

    def reset(self, n_servers: int, file_size: int) -> None:
        self.params = self._params_arg or default_chunk_params(file_size)
        self.est = [
            make_estimator(self._estimator_kind, self._ewma_alpha)
            for _ in range(n_servers)
        ]
        self._dead = [False] * n_servers
        self._retry_at = [0.0] * n_servers

    def next_action(self, state: TransferState, conn: int, now: float) -> Action:
        server = conn  # one connection per server
        if self._dead[server]:
            if self._retry_after <= 0.0:
                return None
            if now < self._retry_at[server]:
                if state.unassigned_bytes() <= 0:
                    return None
                return Wait(self._retry_at[server])
            # probe again from scratch
            self._dead[server] = False
            self.est[server].reset()
        remaining = state.unassigned_bytes()
        size = next_chunk_size(
            server, [e.value for e in self.est], self.params, remaining
        )
        if size <= 0:
            return None
        return Request(server, size)

    def on_complete(
        self, state: TransferState, conn: int, server: int,
        nbytes: int, elapsed: float, now: float, truncated: bool = False,
    ) -> None:
        if truncated or nbytes == 0:
            self._dead[server] = True
            self._retry_at[server] = now + self._retry_after
            return
        self.est[server].observe(nbytes, elapsed)
