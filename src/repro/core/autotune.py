"""Automatic chunk-size selection — the paper's §VIII-A future work.

The paper: *"Our future work will explore how to automatically choose these
chunk sizes based on network conditions and file sizes."*  This module does
that with the on-device simulator, and — because chunk geometry is a traced
:class:`~repro.core.jax_alloc.ChunkArrays` input, not a static jit argument
— the **entire** (C, L) × Monte-Carlo-seed sweep is one ``vmap(vmap(...))``
over :func:`~repro.core.jax_sim.simulate_core`: one compile, one device
call, regardless of grid size.  The batched API (:func:`sweep_scenarios` /
:func:`autotune_batch`) stacks a third ``vmap`` over an ``[S, N]``
bandwidth/RTT matrix so thousands of (scenario, C, L, seed) cells evaluate
in a single call.

The framework's data plane calls this with live throughput estimates to
re-tune chunk sizes between transfers (e.g. between checkpoint-restore
waves — ``MDTPClient.retune``), amortizing one device call across
thousands of scenario sims.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import DEFAULT_MIN_CHUNK, MB, ChunkParams
from .jax_alloc import ChunkArrays
from .jax_sim import SimConfig, _prep, simulate_core

__all__ = [
    "AutotuneResult",
    "default_grid",
    "autotune_chunk_params",
    "autotune_batch",
    "sweep_scenarios",
]


@dataclass(frozen=True)
class AutotuneResult:
    params: ChunkParams
    predicted_time: float
    grid: list[tuple[int, int]]          # (C, L) pairs evaluated
    predicted_times: list[float]         # same order as grid

    def as_table(self) -> str:
        lines = ["C(MB),L(MB),predicted_s"]
        for (c, l), t in zip(self.grid, self.predicted_times):
            lines.append(f"{c / MB:g},{l / MB:g},{t:.2f}")
        return "\n".join(lines)


def default_grid() -> list[tuple[int, int]]:
    """Paper Table II's grid: C in {2,4,8,16} MB x L/C ratio in {1.25x ...}.

    Table II lists, per initial size C, large sizes {10C/8, 10C/4, 10C/2,
    10C}/... concretely L in {2.5C, 5C, 10C} plus the paper's chosen 10x
    pairing; we sweep L/C in {2.5, 5, 10, 20}.
    """
    grid = []
    for c_mb in (2, 4, 8, 16):
        for ratio in (2.5, 5.0, 10.0, 20.0):
            grid.append((c_mb * MB, int(c_mb * ratio) * MB))
    return grid


def _sweep_core(bw, rtt, throttle_t, throttle_bw, file_size,
                grid_c, grid_l, grid_min, seeds, *, mode, config):
    """``[G]`` grid × ``[K]`` seeds → ``[G, K]`` total times, one trace.

    Inner vmap over Monte-Carlo seeds, outer vmap over the stacked grid
    axis; every argument of ``simulate_core`` is traced, so this is a
    single jaxpr for any grid.
    """
    def one(c, l, m, seed):
        return simulate_core(
            bw, rtt, throttle_t, throttle_bw, seed,
            ChunkArrays(c, l, m), file_size, mode=mode, config=config,
        ).total_time

    per_seed = jax.vmap(one, in_axes=(None, None, None, 0))
    return jax.vmap(per_seed, in_axes=(0, 0, 0, None))(
        grid_c, grid_l, grid_min, seeds)


def _sweep_core_batch(bw, rtt, throttle_t, throttle_bw, file_size,
                      grid_c, grid_l, grid_min, seeds, *, mode, config):
    """Leading ``[S]`` scenario axis on bandwidth/rtt/throttle/file_size →
    ``[S, G, K]`` times; the third vmap stacked on the same core."""
    f = functools.partial(_sweep_core, mode=mode, config=config)
    return jax.vmap(f, in_axes=(0, 0, 0, 0, 0, None, None, None, None))(
        bw, rtt, throttle_t, throttle_bw, file_size,
        grid_c, grid_l, grid_min, seeds)


#: One compile covers the whole (C, L) × seed sweep; tests assert the cache
#: holds a single entry after an arbitrary-size grid search.
_fused_sweep = jax.jit(_sweep_core, static_argnames=("mode", "config"))

#: Scenario-batched variant — still one compile for the whole lattice.
_fused_sweep_batch = jax.jit(
    _sweep_core_batch, static_argnames=("mode", "config"))


def _grid_arrays(grid) -> tuple[jax.Array, jax.Array, jax.Array]:
    grid_c = jnp.asarray([c for c, _ in grid], jnp.float32)
    grid_l = jnp.asarray([l for _, l in grid], jnp.float32)
    grid_min = jnp.full((len(grid),), DEFAULT_MIN_CHUNK, jnp.float32)
    return grid_c, grid_l, grid_min


def autotune_chunk_params(
    bandwidth: Sequence[float],
    rtt,
    file_size: int,
    grid: Sequence[tuple[int, int]] | None = None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
) -> AutotuneResult:
    """Pick (C, L) minimizing simulated transfer time.

    The whole grid × seed sweep runs as ONE jit-compiled device call
    (chunk sizes are traced inputs riding a vmap axis) — no per-grid-point
    retrace, so wall time is dominated by the simulation itself rather
    than Python dispatch and compilation.

    Args:
      bandwidth: per-server bytes/s estimates (live throughput observations).
      rtt: scalar or per-server request RTT in seconds.
      file_size: bytes.
      grid: candidate (C, L) pairs; default = paper Table II sweep.
      jitter: lognormal sigma; with ``n_seeds > 1`` times are averaged over
        seeds (Monte-Carlo via the inner vmap axis).
    """
    grid = list(grid or default_grid())
    bw, rtt, throttle_t, throttle_bw = _prep(
        bandwidth, rtt, None, None)
    cfg = SimConfig(jitter=jitter)
    grid_c, grid_l, grid_min = _grid_arrays(grid)
    seeds = jnp.arange(max(n_seeds, 1))

    times_gk = _fused_sweep(
        bw, rtt, throttle_t, throttle_bw, jnp.float32(file_size),
        grid_c, grid_l, grid_min, seeds, mode=mode, config=cfg,
    )
    times = np.asarray(jnp.mean(times_gk, axis=1), np.float64)

    best = int(np.argmin(times))
    c, l = grid[best]
    return AutotuneResult(
        params=ChunkParams(initial_chunk=c, large_chunk=l, mode=mode),
        predicted_time=float(times[best]),
        grid=grid,
        predicted_times=[float(t) for t in times],
    )


def sweep_scenarios(
    bandwidth,
    rtt,
    file_size,
    grid: Sequence[tuple[int, int]] | None = None,
    throttle_t=None,
    throttle_bw=None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
) -> jax.Array:
    """Seed-averaged predicted times for a batch of scenarios.

    Args:
      bandwidth: ``[S, N]`` bytes/s — one row per scenario.
      rtt: scalar, ``[N]``, or ``[S, N]`` seconds.
      file_size: scalar or ``[S]`` bytes (per-scenario object sizes).
      grid: candidate (C, L) pairs; default = paper Table II sweep.
      throttle_t / throttle_bw: optional ``[S, N]`` Fig.-4-style throttle
        breakpoints (time, post-throttle rate).

    Returns:
      ``[S, G]`` float32 matrix of seed-averaged predicted transfer times —
      every (scenario, C, L, seed) cell simulated in one device call.
    """
    grid = list(grid or default_grid())
    bw = jnp.asarray(bandwidth, jnp.float32)
    if bw.ndim != 2:
        raise ValueError(f"bandwidth must be [S, N], got shape {bw.shape}")
    bw, rtt, throttle_t, throttle_bw = _prep(
        bw, rtt, throttle_t, throttle_bw)
    s = bw.shape[0]
    file_size = jnp.broadcast_to(
        jnp.asarray(file_size, jnp.float32), (s,))
    cfg = SimConfig(jitter=jitter)
    grid_c, grid_l, grid_min = _grid_arrays(grid)
    seeds = jnp.arange(max(n_seeds, 1))

    times_sgk = _fused_sweep_batch(
        bw, rtt, throttle_t, throttle_bw, file_size,
        grid_c, grid_l, grid_min, seeds, mode=mode, config=cfg,
    )
    return jnp.mean(times_sgk, axis=2)


def autotune_batch(
    bandwidth,
    rtt,
    file_size,
    grid: Sequence[tuple[int, int]] | None = None,
    throttle_t=None,
    throttle_bw=None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
) -> list[AutotuneResult]:
    """Per-scenario chunk-size selection over an ``[S, N]`` scenario batch.

    A thin argmin over :func:`sweep_scenarios` — the full (scenario, C, L,
    seed) lattice is simulated in one fused device call, then each
    scenario's minimizing (C, L) pair is reported as its own
    :class:`AutotuneResult` (same order as the bandwidth rows).
    """
    grid = list(grid or default_grid())
    times_sg = np.asarray(sweep_scenarios(
        bandwidth, rtt, file_size, grid=grid,
        throttle_t=throttle_t, throttle_bw=throttle_bw,
        jitter=jitter, n_seeds=n_seeds, mode=mode,
    ), np.float64)

    results = []
    for row in times_sg:
        best = int(np.argmin(row))
        c, l = grid[best]
        results.append(AutotuneResult(
            params=ChunkParams(initial_chunk=c, large_chunk=l, mode=mode),
            predicted_time=float(row[best]),
            grid=grid,
            predicted_times=[float(t) for t in row],
        ))
    return results
