"""Automatic chunk-size selection — the paper's §VIII-A future work.

The paper: *"Our future work will explore how to automatically choose these
chunk sizes based on network conditions and file sizes."*  This module does
that with the on-device simulator, and — because chunk geometry is a traced
:class:`~repro.core.jax_alloc.ChunkArrays` input, not a static jit argument
— the **entire** (C, L) × Monte-Carlo-seed sweep is one ``vmap(vmap(...))``
device call, regardless of grid size.  The batched API
(:func:`sweep_scenarios` / :func:`autotune_batch`) stacks a third ``vmap``
over an ``[S, N]`` bandwidth/RTT matrix so thousands of (scenario, C, L,
seed) cells evaluate in a single call.

The sweep runs on the **round-synchronous** core by default
(:func:`~repro.core.jax_sim.simulate_round_core` — O(#rounds) device steps
instead of O(#chunks); ≥5× steady-state on the Table II sweep at N=8) with
``engine="event"`` as the escape hatch back to exact event ordering, and
``engine="scan"`` for the fixed-trip-count variant whose lanes never
diverge under ``vmap``.  ``mode="static"`` always routes to the event core
(fixed chunks are not round-synchronous).

Beyond the grid: :func:`tune_chunk_params_grad` descends ``jax.grad`` of
the scan core's total time through a continuous (C, L) relaxation — the
grid sweep's argmin is only as fine as the grid, the gradient tuner is
not.

The framework's data plane calls this with live throughput estimates to
re-tune chunk sizes between transfers (e.g. between checkpoint-restore
waves — ``MDTPClient.retune``), amortizing one device call across
thousands of scenario sims.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import DEFAULT_MIN_CHUNK, MB, ChunkParams
from .jax_alloc import ChunkArrays
from .jax_sim import (
    _CORES as _ENGINE_CORES,
    SimConfig,
    _prep,
    _simulate,
    resolve_engine,
    simulate_scan_core,
)

__all__ = [
    "AutotuneResult",
    "GradTuneResult",
    "default_grid",
    "autotune_chunk_params",
    "autotune_batch",
    "sweep_scenarios",
    "contention_sweep",
    "swarm_sweep",
    "tune_chunk_params_grad",
]


@dataclass(frozen=True)
class AutotuneResult:
    params: ChunkParams
    predicted_time: float
    grid: list[tuple[int, int]]          # (C, L) pairs evaluated
    predicted_times: list[float]         # same order as grid

    def as_table(self) -> str:
        lines = ["C(MB),L(MB),predicted_s"]
        for (c, l), t in zip(self.grid, self.predicted_times):
            lines.append(f"{c / MB:g},{l / MB:g},{t:.2f}")
        return "\n".join(lines)


def default_grid() -> list[tuple[int, int]]:
    """Paper Table II's grid: C in {2,4,8,16} MB x L/C ratio in {1.25x ...}.

    Table II lists, per initial size C, large sizes {10C/8, 10C/4, 10C/2,
    10C}/... concretely L in {2.5C, 5C, 10C} plus the paper's chosen 10x
    pairing; we sweep L/C in {2.5, 5, 10, 20}.
    """
    grid = []
    for c_mb in (2, 4, 8, 16):
        for ratio in (2.5, 5.0, 10.0, 20.0):
            grid.append((c_mb * MB, int(c_mb * ratio) * MB))
    return grid


def _sweep_core(bw, rtt, throttle_t, throttle_bw, file_size,
                grid_c, grid_l, grid_min, seeds, *, mode, config,
                engine="round"):
    """``[G]`` grid × ``[K]`` seeds → ``[G, K]`` total times, one trace.

    Inner vmap over Monte-Carlo seeds, outer vmap over the stacked grid
    axis; every argument of the simulator core is traced, so this is a
    single jaxpr for any grid.  ``engine`` picks the loop structure
    (round-synchronous by default — same times, O(#rounds) steps).
    """
    core = _ENGINE_CORES[engine]

    def one(c, l, m, seed):
        return core(
            bw, rtt, throttle_t, throttle_bw, seed,
            ChunkArrays(c, l, m), file_size, mode=mode, config=config,
        ).total_time

    per_seed = jax.vmap(one, in_axes=(None, None, None, 0))
    return jax.vmap(per_seed, in_axes=(0, 0, 0, None))(
        grid_c, grid_l, grid_min, seeds)


def _sweep_core_batch(bw, rtt, throttle_t, throttle_bw, file_size,
                      grid_c, grid_l, grid_min, seeds, *, mode, config,
                      engine="round"):
    """Leading ``[S]`` scenario axis on bandwidth/rtt/throttle/file_size →
    ``[S, G, K]`` times; the third vmap stacked on the same core."""
    f = functools.partial(_sweep_core, mode=mode, config=config,
                          engine=engine)
    return jax.vmap(f, in_axes=(0, 0, 0, 0, 0, None, None, None, None))(
        bw, rtt, throttle_t, throttle_bw, file_size,
        grid_c, grid_l, grid_min, seeds)


#: One compile covers the whole (C, L) × seed sweep; tests assert the cache
#: holds a single entry after an arbitrary-size grid search.
_fused_sweep = jax.jit(
    _sweep_core, static_argnames=("mode", "config", "engine"))

#: Scenario-batched variant — still one compile for the whole lattice.
_fused_sweep_batch = jax.jit(
    _sweep_core_batch, static_argnames=("mode", "config", "engine"))


def _grid_arrays(grid) -> tuple[jax.Array, jax.Array, jax.Array]:
    grid_c = jnp.asarray([c for c, _ in grid], jnp.float32)
    grid_l = jnp.asarray([l for _, l in grid], jnp.float32)
    grid_min = jnp.full((len(grid),), DEFAULT_MIN_CHUNK, jnp.float32)
    return grid_c, grid_l, grid_min


def _sized_config(cfg: SimConfig, engine: str, grid, file_size) -> SimConfig:
    """For the scan engine, widen ``max_rounds`` to cover the sweep's
    worst case (smallest L, largest file) — every round moves at least
    ``L`` bytes, so ``ceil(max_file / min_L) + 2`` bounds the trip count.
    Injected faults forfeit whole chunks, so under a per-chunk failure
    probability ``p`` the expected useful fraction of rounds is ``1 - p``:
    the bound is inflated to ``need / (1 - p)`` plus slack (``p`` capped
    well below 1 — a tuner run at near-certain failure is degenerate and
    a finite bound keeps it from scanning forever).  The bound is static
    config, so this is a Python-level decision."""
    if engine != "scan":
        return cfg
    min_l = min(l for _, l in grid)
    need = int(np.ceil(float(np.max(file_size)) / float(min_l))) + 2
    p_fail = min(cfg.loss_rate + cfg.corruption_rate, 0.75)
    if p_fail > 0.0:
        need = int(np.ceil(need / (1.0 - p_fail))) + 8
    return cfg if cfg.max_rounds >= need else cfg._replace(max_rounds=need)


def autotune_chunk_params(
    bandwidth: Sequence[float],
    rtt,
    file_size: int,
    grid: Sequence[tuple[int, int]] | None = None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
    engine: str | None = None,
    pipeline_depth: int = 1,
    loss_rate: float = 0.0,
    corruption_rate: float = 0.0,
    hedge_quantile: float = 0.0,
    decode_bytes_per_s: float = 0.0,
) -> AutotuneResult:
    """Pick (C, L) minimizing simulated transfer time.

    The whole grid × seed sweep runs as ONE jit-compiled device call
    (chunk sizes are traced inputs riding a vmap axis) — no per-grid-point
    retrace, so wall time is dominated by the simulation itself rather
    than Python dispatch and compilation.

    Args:
      bandwidth: per-server bytes/s estimates (live throughput observations).
      rtt: scalar or per-server request RTT in seconds.
      file_size: bytes.
      grid: candidate (C, L) pairs; default = paper Table II sweep.
      jitter: lognormal sigma; with ``n_seeds > 1`` times are averaged over
        seeds (Monte-Carlo via the inner vmap axis).
      engine: simulator loop structure — default (``None``) resolves to
        the round-synchronous core (O(#rounds) device steps); pass
        ``"event"`` to fall back to exact per-event ordering or
        ``"scan"`` for the fixed-trip-count variant.
      pipeline_depth: the runtime's per-connection request pipeline depth
        (``SimConfig.pipeline_depth``) — without it the sweep over-pays
        for small chunks the pipelined data plane makes cheap and the
        adopted (C, L) diverges from what the wire actually does.
      loss_rate / corruption_rate: observed per-chunk fault probabilities
        (``SimConfig`` fault axes) — a faulted chunk burns its full
        duration and is re-fetched, which taxes large L harder (more
        bytes forfeited per fault), so a fleet reporting corrupt ranges
        tunes to different geometry than a clean one.  Stochastic: pair
        with ``n_seeds > 1`` so one unlucky draw doesn't pick the winner.
    """
    grid = list(grid or default_grid())
    engine = resolve_engine(engine, mode)
    bw, rtt, throttle_t, throttle_bw = _prep(
        bandwidth, rtt, None, None)
    cfg = _sized_config(
        SimConfig(jitter=jitter, pipeline_depth=pipeline_depth,
                  loss_rate=loss_rate, corruption_rate=corruption_rate,
                  hedge_quantile=hedge_quantile,
                  decode_bytes_per_s=decode_bytes_per_s),
        engine, grid, file_size)
    grid_c, grid_l, grid_min = _grid_arrays(grid)
    seeds = jnp.arange(max(n_seeds, 1))

    times_gk = _fused_sweep(
        bw, rtt, throttle_t, throttle_bw, jnp.float32(file_size),
        grid_c, grid_l, grid_min, seeds, mode=mode, config=cfg,
        engine=engine,
    )
    times = np.asarray(jnp.mean(times_gk, axis=1), np.float64)

    best = int(np.argmin(times))
    c, l = grid[best]
    return AutotuneResult(
        params=ChunkParams(initial_chunk=c, large_chunk=l, mode=mode),
        predicted_time=float(times[best]),
        grid=grid,
        predicted_times=[float(t) for t in times],
    )


def sweep_scenarios(
    bandwidth,
    rtt,
    file_size,
    grid: Sequence[tuple[int, int]] | None = None,
    throttle_t=None,
    throttle_bw=None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
    engine: str | None = None,
    pipeline_depth: int = 1,
    loss_rate: float = 0.0,
    corruption_rate: float = 0.0,
    hedge_quantile: float = 0.0,
    decode_bytes_per_s: float = 0.0,
) -> jax.Array:
    """Seed-averaged predicted times for a batch of scenarios.

    Args:
      bandwidth: ``[S, N]`` bytes/s — one row per scenario.
      rtt: scalar, ``[N]``, or ``[S, N]`` seconds.
      file_size: scalar or ``[S]`` bytes (per-scenario object sizes).
      grid: candidate (C, L) pairs; default = paper Table II sweep.
      throttle_t / throttle_bw: optional ``[S, N]`` Fig.-4-style throttle
        breakpoints (time, post-throttle rate).
      engine: loop structure; ``None`` → round core (``"scan"`` is worth
        considering here — under a batched while_loop every lane pays the
        slowest lane's trip count per step, which the fixed-bound scan
        avoids).

    Returns:
      ``[S, G]`` float32 matrix of seed-averaged predicted transfer times —
      every (scenario, C, L, seed) cell simulated in one device call.
    """
    grid = list(grid or default_grid())
    engine = resolve_engine(engine, mode)
    bw = jnp.asarray(bandwidth, jnp.float32)
    if bw.ndim != 2:
        raise ValueError(f"bandwidth must be [S, N], got shape {bw.shape}")
    bw, rtt, throttle_t, throttle_bw = _prep(
        bw, rtt, throttle_t, throttle_bw)
    s = bw.shape[0]
    file_size = jnp.broadcast_to(
        jnp.asarray(file_size, jnp.float32), (s,))
    cfg = _sized_config(
        SimConfig(jitter=jitter, pipeline_depth=pipeline_depth,
                  loss_rate=loss_rate, corruption_rate=corruption_rate,
                  hedge_quantile=hedge_quantile,
                  decode_bytes_per_s=decode_bytes_per_s),
        engine, grid, np.asarray(file_size))
    grid_c, grid_l, grid_min = _grid_arrays(grid)
    seeds = jnp.arange(max(n_seeds, 1))

    times_sgk = _fused_sweep_batch(
        bw, rtt, throttle_t, throttle_bw, file_size,
        grid_c, grid_l, grid_min, seeds, mode=mode, config=cfg,
        engine=engine,
    )
    return jnp.mean(times_sgk, axis=2)


def autotune_batch(
    bandwidth,
    rtt,
    file_size,
    grid: Sequence[tuple[int, int]] | None = None,
    throttle_t=None,
    throttle_bw=None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
    engine: str | None = None,
    pipeline_depth: int = 1,
    loss_rate: float = 0.0,
    corruption_rate: float = 0.0,
    hedge_quantile: float = 0.0,
    decode_bytes_per_s: float = 0.0,
) -> list[AutotuneResult]:
    """Per-scenario chunk-size selection over an ``[S, N]`` scenario batch.

    A thin argmin over :func:`sweep_scenarios` — the full (scenario, C, L,
    seed) lattice is simulated in one fused device call, then each
    scenario's minimizing (C, L) pair is reported as its own
    :class:`AutotuneResult` (same order as the bandwidth rows).
    """
    grid = list(grid or default_grid())
    times_sg = np.asarray(sweep_scenarios(
        bandwidth, rtt, file_size, grid=grid,
        throttle_t=throttle_t, throttle_bw=throttle_bw,
        jitter=jitter, n_seeds=n_seeds, mode=mode, engine=engine,
        pipeline_depth=pipeline_depth,
        loss_rate=loss_rate, corruption_rate=corruption_rate,
        hedge_quantile=hedge_quantile,
        decode_bytes_per_s=decode_bytes_per_s,
    ), np.float64)

    results = []
    for row in times_sg:
        best = int(np.argmin(row))
        c, l = grid[best]
        results.append(AutotuneResult(
            params=ChunkParams(initial_chunk=c, large_chunk=l, mode=mode),
            predicted_time=float(row[best]),
            grid=grid,
            predicted_times=[float(t) for t in row],
        ))
    return results


def contention_sweep(
    bandwidth: Sequence[float],
    rtt,
    file_size,
    max_transfers: int = 4,
    ks: Sequence[int] | None = None,
    grid: Sequence[tuple[int, int]] | None = None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
    engine: str | None = None,
    pipeline_depth: int = 1,
    loss_rate: float = 0.0,
    corruption_rate: float = 0.0,
    hedge_quantile: float = 0.0,
    decode_bytes_per_s: float = 0.0,
) -> dict[int, AutotuneResult]:
    """Per-contention-level chunk tuning: the (C, L) ladder a fleet
    scheduler adopts as concurrent transfers arrive and drain.

    Scenario ``k`` is the fleet under a fair ``k``-way split — every
    replica's bandwidth divided by ``k``, RTTs unchanged (latency is
    per-path, not per-share) — which is how the simulator mirrors K
    transfers contending for shared mirrors (TCP-fair uplink sharing).
    The whole (k, C, L, seed) lattice is ONE fused ``vmap(vmap(vmap))``
    device call via :func:`sweep_scenarios`; the result maps each active
    count to its tuned params (``repro.transfer.TransferManager`` keeps
    this as its ``contention_ladder`` and warm-starts arriving transfers
    from it).

    ``file_size`` may be a scalar (same remaining bytes at every level)
    or one entry per ``k``.
    """
    ks = list(ks if ks is not None else range(1, max_transfers + 1))
    if not ks or any(k < 1 for k in ks):
        raise ValueError(f"contention levels must be >= 1, got {ks}")
    grid = list(grid or default_grid())
    bw = np.asarray(bandwidth, np.float64)
    if bw.ndim != 1:
        raise ValueError(f"bandwidth must be [N], got shape {bw.shape}")
    mat = np.stack([bw / k for k in ks])
    results = autotune_batch(
        mat, rtt, file_size, grid=grid, jitter=jitter, n_seeds=n_seeds,
        mode=mode, engine=engine, pipeline_depth=pipeline_depth,
        loss_rate=loss_rate, corruption_rate=corruption_rate,
        hedge_quantile=hedge_quantile,
        decode_bytes_per_s=decode_bytes_per_s)
    return dict(zip(ks, results))


def swarm_sweep(
    file_size,
    origin_bw: float,
    peer_bw: float | None = None,
    ns: Sequence[int] = (2, 4, 8),
    onset: float = 1.0,
    rtt=0.03,
    grid: Sequence[tuple[int, int]] | None = None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
    engine: str | None = None,
    pipeline_depth: int = 1,
) -> dict[int, AutotuneResult]:
    """Per-swarm-size chunk tuning for peer-assisted broadcast.

    Scenario ``n`` is the fleet ONE of ``n`` restorers sees
    (:func:`repro.core.scenarios.swarm_fleet`): the origin at a fair
    ``1/n`` share of its fixed capacity plus ``n - 1`` peer mirrors that
    come online mid-transfer — an UP-step throttle breakpoint (the
    inverse of the Fig. 4 down-throttle) threaded through the same
    round/scan cores via the ``throttle_t``/``throttle_bw`` axes.  The
    result maps each swarm size to its tuned (C, L): the broadcast
    mirror of :func:`contention_sweep`'s ladder, consumed the same way
    (a restore fleet picks geometry for its swarm size instead of
    re-using the one-client-K-fast-mirrors defaults, which oversize
    chunks so badly the origin has served half the blob to everyone
    before any peer can come online).

    Unlike ``contention_sweep`` the scenario axis changes the server
    COUNT, so each swarm size runs as its own fused grid x seed device
    call instead of one batched lattice: vmap batching needs a fixed N,
    and padding with permanently-dark servers would stall the
    round-synchronous core's probe round for the pad's glacial chunk.
    """
    from .scenarios import swarm_axes, swarm_fleet

    ns = sorted(set(int(n) for n in ns))
    if not ns or ns[0] < 1:
        raise ValueError(f"swarm sizes must be >= 1, got {ns}")
    grid = list(grid or default_grid())
    results: dict[int, AutotuneResult] = {}
    for n in ns:
        servers = swarm_fleet(n, origin_bw=origin_bw, peer_bw=peer_bw,
                              onset=onset, rtt=rtt)
        bw0, tt, tb = swarm_axes(servers)
        results[n] = autotune_batch(
            np.asarray([bw0]), rtt, file_size,
            throttle_t=np.asarray([tt]), throttle_bw=np.asarray([tb]),
            grid=grid, jitter=jitter, n_seeds=n_seeds, mode=mode,
            engine=engine, pipeline_depth=pipeline_depth)[0]
    return results


# --------------------------------------------------------------------------
# Gradient-based continuous (C, L) tuning on the differentiable scan core
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class GradTuneResult:
    """Outcome of :func:`tune_chunk_params_grad`.

    ``final_grad`` is the (dT/dC, dT/dL) gradient at the adopted point —
    kept so callers (and the gradient-sanity test) can verify the scan
    core's differentiability contract: both entries finite, not both zero.
    """

    params: ChunkParams
    predicted_time: float
    loss_history: list[float]
    final_grad: tuple[float, float]

    @property
    def steps(self) -> int:
        return len(self.loss_history)


# -- shared z-space descent machinery (also used by repro.core.online) -----
#
# (C, L) are parameterized as ``floor + exp(z)``: C floored at ``min_chunk``
# and L at ``file_size / (max_rounds - 2)``, which keeps the static scan
# bound valid for every point the optimizer can visit.

def _l_floor_for(min_chunk: float, file_size: float, max_rounds: int,
                 p_fail: float = 0.0) -> float:
    """With faults on (``p_fail > 0``) the useful-round budget shrinks by
    the expected forfeit fraction, so the L floor rises to keep the static
    scan bound valid in expectation (fault-free callers are unchanged)."""
    rounds = max(max_rounds - 2, 1)
    if p_fail > 0.0:
        rounds = max(int(rounds * (1.0 - min(p_fail, 0.75))) - 2, 1)
    return max(float(min_chunk), float(file_size) / rounds)


def _z_init(init: tuple[float, float], min_chunk: float,
            l_floor: float) -> jax.Array:
    return jnp.asarray([
        np.log(max(init[0] - min_chunk, 1.0)),
        np.log(max(init[1] - l_floor, 1.0)),
    ], jnp.float32)


def _z_decode(z, min_chunk: float, l_floor: float):
    """Traced inverse of :func:`_z_init` — the point the loss evaluates."""
    return min_chunk + jnp.exp(z[0]), l_floor + jnp.exp(z[1])


def _adam_descend(vg, z: jax.Array, steps: int, lr: float, args=()):
    """Adam on ``vg(z, *args)`` with best-seen tracking.

    Returns ``(best_z, history)`` — ``best_z`` is the lowest-loss iterate
    (never worse than the init), ``history`` the loss per step.  Stops
    early on a non-finite loss or gradient (the bad step is recorded but
    never adopted).  Inline Adam — two scalars don't warrant an optimizer
    dependency.
    """
    m = jnp.zeros_like(z)
    v = jnp.zeros_like(z)
    b1, b2, adam_eps = 0.9, 0.999, 1e-8
    history: list[float] = []
    best_z, best_t = z, float("inf")
    for t in range(1, max(steps, 1) + 1):
        val, g = vg(z, *args)
        val = float(val)
        history.append(val)
        if not np.isfinite(val) or not np.all(np.isfinite(np.asarray(g))):
            break
        if val < best_t:
            best_t, best_z = val, z
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**t)
        vh = v / (1 - b2**t)
        z = z - lr * mh / (jnp.sqrt(vh) + adam_eps)
    return best_z, history


def _exact_time(params: ChunkParams, bw, rtt_a, throttle_t, throttle_bw,
                file_f, mode: str, pipeline_depth: int = 1,
                loss_rate: float = 0.0,
                corruption_rate: float = 0.0,
                hedge_quantile: float = 0.0,
                decode_bytes_per_s: float = 0.0) -> float:
    """Honest number for integer params: exact sizes, round core, no
    jitter — the metric both gradient tuners report and compare on (under
    faults, at the fixed seed 0 so init/final compare on the same draws).
    Routed through the cached jit dispatcher (an eager ``while_loop``
    costs seconds; online tuners call this every update)."""
    return float(_simulate(
        bw, rtt_a, throttle_t, throttle_bw, jnp.int32(0),
        ChunkArrays.from_params(params), file_f,
        mode=mode, config=SimConfig(pipeline_depth=pipeline_depth,
                                    loss_rate=loss_rate,
                                    corruption_rate=corruption_rate,
                                    hedge_quantile=hedge_quantile,
                                    decode_bytes_per_s=decode_bytes_per_s),
        engine="round",
    ).total_time)


def _finish_grad_tune(vg, vg_args, best_z, history,
                      init: tuple[float, float], min_chunk: int,
                      l_floor: float, mode: str,
                      bw, rtt_a, throttle_t, throttle_bw,
                      file_f, pipeline_depth: int = 1,
                      loss_rate: float = 0.0,
                      corruption_rate: float = 0.0,
                      hedge_quantile: float = 0.0,
                      decode_bytes_per_s: float = 0.0) -> GradTuneResult:
    """Round ``best_z`` to integer ``ChunkParams``, guarantee never-worse
    than ``init`` on the EXACT metric (rounding can cross a round-count
    jump), and report the (dT/dC, dT/dL) chain-rule gradient."""
    c_best = int(round(min_chunk + float(np.exp(best_z[0]))))
    l_best = int(round(l_floor + float(np.exp(best_z[1]))))
    params = ChunkParams(
        initial_chunk=max(c_best, min_chunk),
        large_chunk=max(l_best, min_chunk),
        min_chunk=min_chunk, mode=mode)
    t_final = _exact_time(params, bw, rtt_a, throttle_t, throttle_bw,
                          file_f, mode, pipeline_depth,
                          loss_rate, corruption_rate, hedge_quantile,
                          decode_bytes_per_s)
    init_params = ChunkParams(
        initial_chunk=max(int(round(init[0])), min_chunk),
        large_chunk=max(int(round(init[1])), min_chunk),
        min_chunk=min_chunk, mode=mode)
    t_init = _exact_time(init_params, bw, rtt_a, throttle_t, throttle_bw,
                         file_f, mode, pipeline_depth,
                         loss_rate, corruption_rate, hedge_quantile,
                         decode_bytes_per_s)
    if t_init < t_final:
        params, t_final = init_params, t_init
    # grad w.r.t. (C, L) via the chain rule through the softplus-free
    # floor+exp map: dT/dC = dT/dz0 / exp(z0) etc.
    _, g = vg(best_z, *vg_args)
    g = np.asarray(g, np.float64)
    final_grad = (g[0] / max(float(np.exp(best_z[0])), 1e-30),
                  g[1] / max(float(np.exp(best_z[1])), 1e-30))
    return GradTuneResult(
        params=params,
        predicted_time=t_final,
        loss_history=history,
        final_grad=(float(final_grad[0]), float(final_grad[1])),
    )


def tune_chunk_params_grad(
    bandwidth: Sequence[float],
    rtt,
    file_size: int,
    init: tuple[float, float] | None = None,
    steps: int = 60,
    lr: float = 0.05,
    mode: str = "proportional",
    min_chunk: int = DEFAULT_MIN_CHUNK,
    max_rounds: int = 1024,
    grid: Sequence[tuple[int, int]] | None = None,
    pipeline_depth: int = 1,
    loss_rate: float = 0.0,
    corruption_rate: float = 0.0,
    hedge_quantile: float = 0.0,
    decode_bytes_per_s: float = 0.0,
) -> GradTuneResult:
    """Continuous (C, L) refinement: ``jax.grad`` polish of the grid winner.

    Runs Adam on the **scan core** (the only reverse-differentiable engine
    — a data-dependent ``while_loop`` has no reverse rule) with the
    allocator's continuous relaxation (``SimConfig(exact_sizes=False)``),
    so total time is a.e. differentiable in the traced chunk geometry.

    Gradient semantics: transfer time is a sawtooth in (C, L) — smooth
    *within* a fixed round count, with downward jumps where the file packs
    into one fewer round.  The pathwise gradient sees only the
    within-basin slope (tail waste, probe cost), not the jumps (RTT
    amortization), so pure descent from an arbitrary point walks uphill on
    the macro trend.  The tuner therefore works as a **hybrid**: the fused
    grid sweep picks the basin (``init=None`` runs it implicitly — one
    device call), gradient descent refines inside and near it, and
    best-seen tracking guarantees the result is never worse than the
    init.  On the default scenario this polish beats the Table II grid's
    argmin by ~1% — exactly the resolution the grid cannot see.

    (C, L) are parameterized as ``floor + exp(z)``: C floored at
    ``min_chunk`` and L at ``file_size / (max_rounds - 2)``, which keeps
    the static scan bound valid for every point the optimizer can visit.
    One jit compile for the whole descent (z is traced); each step is one
    fixed-length scan forward + backward.

    Returns the best-seen point as integer ``ChunkParams`` plus the loss
    trajectory and the final (dT/dC, dT/dL).
    """
    bw, rtt_a, throttle_t, throttle_bw = _prep(bandwidth, rtt, None, None)
    file_f = jnp.float32(file_size)
    p_fail = loss_rate + corruption_rate
    if init is None:
        seed_res = autotune_chunk_params(
            bandwidth, rtt, int(file_size), grid=grid, mode=mode,
            pipeline_depth=pipeline_depth,
            loss_rate=loss_rate, corruption_rate=corruption_rate,
            hedge_quantile=hedge_quantile,
            decode_bytes_per_s=decode_bytes_per_s,
            n_seeds=4 if p_fail > 0.0 else 1)
        init = (float(seed_res.params.initial_chunk),
                float(seed_res.params.large_chunk))
    l_floor = _l_floor_for(min_chunk, file_size, max_rounds, p_fail)
    cfg = SimConfig(max_rounds=max_rounds, exact_sizes=False,
                    pipeline_depth=pipeline_depth,
                    loss_rate=loss_rate, corruption_rate=corruption_rate,
                    hedge_quantile=hedge_quantile,
                    decode_bytes_per_s=decode_bytes_per_s)

    def total_time(z, bw, rtt_a, throttle_t, throttle_bw):
        c, l = _z_decode(z, min_chunk, l_floor)
        chunk = ChunkArrays(c, l, jnp.float32(min_chunk))
        return simulate_scan_core(
            bw, rtt_a, throttle_t, throttle_bw, 0, chunk, file_f,
            mode=mode, config=cfg,
        ).total_time

    vg = jax.jit(jax.value_and_grad(total_time))
    vg_args = (bw, rtt_a, throttle_t, throttle_bw)
    z0 = _z_init(init, min_chunk, l_floor)
    best_z, history = _adam_descend(vg, z0, steps, lr, args=vg_args)
    return _finish_grad_tune(
        vg, vg_args, best_z, history, init, min_chunk, l_floor, mode,
        bw, rtt_a, throttle_t, throttle_bw, file_f, pipeline_depth,
        loss_rate, corruption_rate, hedge_quantile, decode_bytes_per_s)
