"""Automatic chunk-size selection — the paper's §VIII-A future work.

The paper: *"Our future work will explore how to automatically choose these
chunk sizes based on network conditions and file sizes."*  This module does
that with the on-device simulator: a (C, L) grid is evaluated for the
observed bandwidth/RTT vector by ``vmap``-ing ``jax_sim.simulate_transfer``
over the whole grid in one call, optionally Monte-Carlo-averaged over
jitter seeds, and the minimizing pair is returned.

The framework's data plane calls this with live throughput estimates to
re-tune chunk sizes between transfers (e.g. between checkpoint-restore
waves), amortizing one device call across thousands of scenario sims.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import MB, ChunkParams
from .jax_sim import SimConfig, simulate_transfer

__all__ = ["AutotuneResult", "default_grid", "autotune_chunk_params"]


@dataclass(frozen=True)
class AutotuneResult:
    params: ChunkParams
    predicted_time: float
    grid: list[tuple[int, int]]          # (C, L) pairs evaluated
    predicted_times: list[float]         # same order as grid

    def as_table(self) -> str:
        lines = ["C(MB),L(MB),predicted_s"]
        for (c, l), t in zip(self.grid, self.predicted_times):
            lines.append(f"{c / MB:g},{l / MB:g},{t:.2f}")
        return "\n".join(lines)


def default_grid() -> list[tuple[int, int]]:
    """Paper Table II's grid: C in {2,4,8,16} MB x L/C ratio in {1.25x ...}.

    Table II lists, per initial size C, large sizes {10C/8, 10C/4, 10C/2,
    10C}/... concretely L in {2.5C, 5C, 10C} plus the paper's chosen 10x
    pairing; we sweep L/C in {2.5, 5, 10, 20}.
    """
    grid = []
    for c_mb in (2, 4, 8, 16):
        for ratio in (2.5, 5.0, 10.0, 20.0):
            grid.append((c_mb * MB, int(c_mb * ratio) * MB))
    return grid


def autotune_chunk_params(
    bandwidth: Sequence[float],
    rtt,
    file_size: int,
    grid: Sequence[tuple[int, int]] | None = None,
    jitter: float = 0.0,
    n_seeds: int = 1,
    mode: str = "proportional",
) -> AutotuneResult:
    """Pick (C, L) minimizing simulated transfer time.

    Args:
      bandwidth: per-server bytes/s estimates (live throughput observations).
      rtt: scalar or per-server request RTT in seconds.
      file_size: bytes.
      grid: candidate (C, L) pairs; default = paper Table II sweep.
      jitter: lognormal sigma; with ``n_seeds > 1`` times are averaged over
        seeds (Monte-Carlo via an extra vmap axis).
    """
    grid = list(grid or default_grid())
    bw = jnp.asarray(bandwidth, jnp.float32)
    cfg = SimConfig(jitter=jitter)

    # The grid cannot be a vmap axis (ChunkParams is static), so evaluate
    # each (C, L) as its own jit call but vmap the Monte-Carlo seeds inside.
    times = []
    for c, l in grid:
        params = ChunkParams(initial_chunk=c, large_chunk=l, mode=mode)
        if n_seeds == 1:
            res = simulate_transfer(bw, rtt, file_size, params, config=cfg)
            times.append(float(res.total_time))
        else:
            def one(seed):
                return simulate_transfer(
                    bw, rtt, file_size, params, seed=seed, config=cfg
                ).total_time
            ts = jax.vmap(one)(jnp.arange(n_seeds))
            times.append(float(jnp.mean(ts)))

    best = int(np.argmin(times))
    c, l = grid[best]
    return AutotuneResult(
        params=ChunkParams(initial_chunk=c, large_chunk=l, mode=mode),
        predicted_time=times[best],
        grid=grid,
        predicted_times=times,
    )
