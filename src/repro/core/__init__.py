"""MDTP core: the paper's contribution.

* ``chunking`` — the adaptive bin-packing chunk allocator (§IV-B, Alg. 1).
* ``throughput`` — per-server throughput estimators.
* ``simulator`` — discrete-event multi-source transfer simulator.
* ``mdtp`` / ``static_chunking`` / ``aria2`` / ``bittorrent`` — policies.
* ``jax_alloc`` / ``jax_sim`` — vectorized JAX allocator + on-device
  simulators.  Chunk geometry, file size, and seed are traced inputs
  (``ChunkArrays``), so whole (C, L) × seed × scenario sweeps vmap
  through ONE compiled call.  Three loop engines: ``event`` (exact,
  O(#chunks) steps), ``round`` (round-synchronous, O(#rounds) vector
  steps) and ``scan`` (fixed trip count, reverse-differentiable).
* ``autotune`` — automatic chunk-size selection (paper §VIII-A): fused
  single-compile grid search (round engine by default) plus the batched
  ``autotune_batch`` / ``sweep_scenarios`` scenario-matrix API and the
  gradient polish ``tune_chunk_params_grad``.
* ``online`` — online (C, L) tuning from live fleet telemetry: the
  jitter-smoothed Monte-Carlo gradient tuner and the discounted-UCB
  bandit with drift detection, consumed by ``MDTPClient.fetch(tuner=...)``
  and the checkpoint-restore wave loop.
* ``scenarios`` — calibrated FABRIC-testbed stand-ins.
"""

from importlib import import_module

#: export name -> defining submodule (resolved on first attribute
#: access, PEP 562) — keeps ``repro.core.chunking``/``throughput``
#: importable by the sans-I/O scheduling layer without dragging JAX in.
_EXPORTS = {
    "ChunkParams": ".chunking", "default_chunk_params": ".chunking",
    "fast_server_mask": ".chunking", "geometric_mean": ".chunking",
    "next_chunk_size": ".chunking", "round_chunk_sizes": ".chunking",
    "Ewma": ".throughput", "LastSample": ".throughput",
    "ThroughputEstimator": ".throughput", "make_estimator": ".throughput",
    "ChunkRecord": ".simulator", "Policy": ".simulator",
    "Request": ".simulator", "ServerSpec": ".simulator",
    "SimResult": ".simulator", "TransferState": ".simulator",
    "Wait": ".simulator", "simulate": ".simulator",
    "MDTPPolicy": ".mdtp",
    "StaticChunkingPolicy": ".static_chunking",
    "default_static_chunk": ".static_chunking",
    "Aria2Policy": ".aria2",
    "BitTorrentPolicy": ".bittorrent",
    "ChunkArrays": ".jax_alloc", "round_allocate": ".jax_alloc",
    "AutotuneResult": ".autotune", "GradTuneResult": ".autotune",
    "autotune_batch": ".autotune", "autotune_chunk_params": ".autotune",
    "default_grid": ".autotune", "sweep_scenarios": ".autotune",
    "tune_chunk_params_grad": ".autotune",
    "BanditTuner": ".online", "GridTuner": ".online",
    "MCGradTuner": ".online", "Telemetry": ".online",
    "rtt_corrected_bandwidth": ".online",
    "tune_chunk_params_mcgrad": ".online",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    value = getattr(import_module(target, __name__), name)
    globals()[name] = value          # cache: __getattr__ runs once per name
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
