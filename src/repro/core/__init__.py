"""MDTP core: the paper's contribution.

* ``chunking`` — the adaptive bin-packing chunk allocator (§IV-B, Alg. 1).
* ``throughput`` — per-server throughput estimators.
* ``simulator`` — discrete-event multi-source transfer simulator.
* ``mdtp`` / ``static_chunking`` / ``aria2`` / ``bittorrent`` — policies.
* ``jax_alloc`` / ``jax_sim`` — vectorized JAX allocator + on-device
  simulators.  Chunk geometry, file size, and seed are traced inputs
  (``ChunkArrays``), so whole (C, L) × seed × scenario sweeps vmap
  through ONE compiled call.  Three loop engines: ``event`` (exact,
  O(#chunks) steps), ``round`` (round-synchronous, O(#rounds) vector
  steps) and ``scan`` (fixed trip count, reverse-differentiable).
* ``autotune`` — automatic chunk-size selection (paper §VIII-A): fused
  single-compile grid search (round engine by default) plus the batched
  ``autotune_batch`` / ``sweep_scenarios`` scenario-matrix API and the
  gradient polish ``tune_chunk_params_grad``.
* ``online`` — online (C, L) tuning from live fleet telemetry: the
  jitter-smoothed Monte-Carlo gradient tuner and the discounted-UCB
  bandit with drift detection, consumed by ``MDTPClient.fetch(tuner=...)``
  and the checkpoint-restore wave loop.
* ``scenarios`` — calibrated FABRIC-testbed stand-ins.
"""

from .chunking import (
    ChunkParams,
    default_chunk_params,
    fast_server_mask,
    geometric_mean,
    next_chunk_size,
    round_chunk_sizes,
)
from .throughput import Ewma, LastSample, ThroughputEstimator, make_estimator
from .simulator import (
    ChunkRecord,
    Policy,
    Request,
    ServerSpec,
    SimResult,
    TransferState,
    Wait,
    simulate,
)
from .mdtp import MDTPPolicy
from .static_chunking import StaticChunkingPolicy, default_static_chunk
from .aria2 import Aria2Policy
from .bittorrent import BitTorrentPolicy
from .jax_alloc import ChunkArrays, round_allocate
from .autotune import (
    AutotuneResult,
    GradTuneResult,
    autotune_batch,
    autotune_chunk_params,
    default_grid,
    sweep_scenarios,
    tune_chunk_params_grad,
)
from .online import (
    BanditTuner,
    GridTuner,
    MCGradTuner,
    Telemetry,
    rtt_corrected_bandwidth,
    tune_chunk_params_mcgrad,
)

__all__ = [
    "ChunkParams", "default_chunk_params", "fast_server_mask",
    "geometric_mean", "next_chunk_size", "round_chunk_sizes",
    "Ewma", "LastSample", "ThroughputEstimator", "make_estimator",
    "ChunkRecord", "Policy", "Request", "ServerSpec", "SimResult",
    "TransferState", "Wait", "simulate",
    "MDTPPolicy", "StaticChunkingPolicy", "default_static_chunk",
    "Aria2Policy", "BitTorrentPolicy",
    "ChunkArrays", "round_allocate",
    "AutotuneResult", "GradTuneResult", "autotune_chunk_params",
    "autotune_batch", "sweep_scenarios", "default_grid",
    "tune_chunk_params_grad",
    "BanditTuner", "GridTuner", "MCGradTuner", "Telemetry",
    "rtt_corrected_bandwidth", "tune_chunk_params_mcgrad",
]
