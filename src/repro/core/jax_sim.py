"""On-device (JAX) transfer simulator: event-driven and round-synchronous.

Two re-expressions of the discrete-event simulator for the MDTP and
static-chunking policies — one persistent connection per server, constant
per-server bandwidth with an optional single throttle breakpoint
(Fig. 4-style), optional per-chunk lognormal jitter, and optional
per-chunk fault injection (``SimConfig.loss_rate`` /
``corruption_rate``): a faulted chunk occupies its connection for the
full duration but delivers nothing, and its byte range is re-requested —
the on-device mirror of the real client's verify-and-re-pool path, so
re-fetch overhead is visible to the (C, L) autotuners.  Richer failure
shapes (mid-chunk cuts, server death, flapping) still need the Python
simulator's range-reclaim pool.

Engines
-------
``engine="event"`` (:func:`simulate_core`)
    The original ``lax.while_loop`` that retires ONE chunk per iteration
    (an ``argmin`` over servers, then scalar gather/scatter updates) —
    O(#chunks) tiny sequential device steps.  Exact event ordering; the
    reference for the other engines and the only one that is faithful for
    ``mode="static"`` (where fast servers take many more chunks per unit
    time than slow ones, i.e. rounds are NOT synchronous).

``engine="round"`` (:func:`simulate_round_core`)
    MDTP's allocator is *round-synchronous by construction* (§IV: chunks
    are sized so every server in a round finishes together), so each loop
    iteration can complete ALL in-flight chunks, observe all N
    throughputs, and allocate the next full round vectorized over servers
    (:func:`~repro.core.jax_alloc.round_allocate` — one cursor update per
    round).  Trip count drops from O(#chunks) to O(#rounds) ≈ #chunks/N
    and each step is wide vector ops with no per-event ``argmin``.  In
    ``proportional`` mode the allocation stream is essentially identical
    to the event core's (only ``th_max`` enters the size formula, and the
    fastest server's observation is visible to every later ask in both
    cores); completion times agree with the Python reference within the
    same 2% the event core achieves.  Default engine for the autotuner's
    fused sweep.

``engine="scan"`` (:func:`simulate_scan_core`)
    The same round step under a **fixed-round-bound masked ``lax.scan``**
    (``SimConfig.max_rounds`` steps, no-op once the transfer drains).
    Trades early exit for two properties a data-dependent ``while_loop``
    cannot offer: no lockstep divergence under ``vmap`` (every lane costs
    exactly ``max_rounds`` steps, so one slow scenario does not stall the
    whole batch), and reverse-mode differentiability end-to-end —
    ``jax.grad`` of total time w.r.t. the traced ``(C, L)`` geometry is
    well-defined, which is what the gradient-based tuner
    (``repro.core.autotune.tune_chunk_params_grad``) consumes.  Pair with
    ``SimConfig(exact_sizes=False)`` for useful gradients: the integer
    ``round()`` in the allocator has zero gradient a.e., so the continuous
    relaxation (< 1 byte error per request) is used while tuning.  A
    transfer that outruns ``max_rounds`` reports ``total_time = inf``
    (never a silently-truncated fast time).

Why this exists (hardware adaptation): the paper picks chunk sizes
empirically and leaves automatic selection to future work (§VIII-A).
Expressing the whole transfer as a pure JAX function makes the evaluation
loop *vmappable*: thousands of (bandwidth vector, C, L, seed) scenarios
simulate in one device call, which is what ``repro.core.autotune`` uses to
pick chunk sizes — a TPU-native replacement for the paper's manual grid.

Every quantity that varies across a sweep is a **traced input**: the
chunk geometry rides a :class:`~repro.core.jax_alloc.ChunkArrays` pytree,
the file size is a traced scalar, and the PRNG seed is a traced int.  Only
``mode`` (allocator branch structure), ``engine`` (loop structure) and
:class:`SimConfig` (loop bounds / jitter switch) are static — so an
arbitrary (C, L) × seed × scenario grid compiles exactly once.  Static
chunking is the same code path with ``C == L == chunk`` under
``mode="static"``, not a separate jaxpr.

Cross-checked against the Python simulator in tests (same scenario → same
completion time within float tolerance; round core within 2% on the
Fig. 2/3 scenario suite).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .jax_alloc import (
    ChunkArrays,
    ChunkParamsLike,
    as_chunk_arrays,
    chunk_sizes,
    round_allocate,
)

__all__ = [
    "SimConfig",
    "JaxSimResult",
    "simulate_core",
    "simulate_round_core",
    "simulate_scan_core",
    "resolve_engine",
    "simulate_transfer",
    "simulate_static",
]

_INF = jnp.float32(jnp.inf)


class SimConfig(NamedTuple):
    """Static simulation parameters (baked into the jaxpr)."""

    max_iters: int = 100_000
    jitter: float = 0.0  # lognormal sigma per chunk; 0 = deterministic
    #: lognormal sigma applied ONCE per simulation to every server's RTT
    #: (keyed on the traced seed, decorrelated from the per-chunk stream).
    #: Monte-Carlo averaging over seeds with ``rtt_jitter > 0`` randomizes
    #: where the (C, L) round-count jumps fall, which is what lets the
    #: MC-gradient tuner (``repro.core.online``) see RTT amortization as a
    #: smooth slope instead of a flat plateau between jumps.
    rtt_jitter: float = 0.0
    #: trip count of the ``engine="scan"`` core (static scan length).  A
    #: round moves at least ``large_chunk`` bytes, so ``max_rounds >=
    #: ceil(file_size / L) + 2`` always suffices; steps past completion
    #: are masked no-ops, and an undersized bound reports ``total_time =
    #: inf`` — size it for the smallest L in a sweep.
    max_rounds: int = 1024
    #: False = continuous allocator relaxation (skip ``jnp.round``) so the
    #: scan core is usefully differentiable in (C, L); < 1 byte/request off.
    exact_sizes: bool = True
    #: per-connection HTTP request pipeline depth of the modeled runtime
    #: (``MDTPClient.pipeline_depth``).  1 = serial request-response:
    #: every chunk pays a full request RTT.  With depth k > 1 the next
    #: request is issued while up to k-1 predecessors stream, so a warm
    #: server only idles for the RTT *not hidden* behind its in-flight
    #: bodies: per-chunk latency = max(0, rtt - (k-1) * body_time).  A
    #: server's FIRST chunk still pays the full RTT (empty pipe).  Static
    #: (baked into the jaxpr) like the rest of the config; the smooth
    #: max(0, ...) keeps the scan core differentiable.
    pipeline_depth: int = 1
    #: per-chunk probability the connection is cut / the body is lost
    #: mid-flight.  A lost chunk occupies its connection for the full
    #: modeled duration but credits no bytes and no throughput sample;
    #: its range re-enters the remaining budget and is re-requested.
    #: (The Python simulator models loss as a partial mid-chunk cut; here
    #: the whole chunk is forfeited — a conservative upper bound that
    #: keeps the cores branch-free.)  Fault draws consume PRNG splits
    #: ONLY when a rate is nonzero, so fault-free configs reproduce the
    #: exact seeded streams of earlier builds.
    loss_rate: float = 0.0
    #: per-chunk probability the delivered body fails integrity
    #: verification (CRC mismatch in the real client).  Identical dynamics
    #: to ``loss_rate`` on-device — full-duration waste, zero credit,
    #: re-fetch — kept as a separate axis so tuner calls mirror the
    #: client's telemetry split between resets and corrupt ranges.
    corruption_rate: float = 0.0
    #: endgame hedging quantile of the modeled client
    #: (``MDTPClient.hedge_quantile``).  In the round/scan engines, once
    #: the final round is in flight, a chunk whose duration exceeds this
    #: quantile of the round's durations completes no later than the
    #: first-finishing server could speculatively re-serve it (winner's
    #: RTT + body time) — the on-device mirror of the client's hedged
    #: endgame, so tuned (C, L) sees straggler tails the way the wire
    #: does.  0 disables hedging; the transform is a pure function of
    #: already-drawn durations (NO extra PRNG consumption), so
    #: hedge-free configs replay bit-identical event streams.
    hedge_quantile: float = 0.0
    #: client-side decode rate (DECODED bytes/s) for transfer-encoded
    #: bodies (the compressed-range path, ``repro.transfer.codec``): each
    #: chunk's duration gains ``size / decode_bytes_per_s`` of compute
    #: before the lane can issue its next request.  Tuners trade this
    #: against the wire bytes compression saves: callers model the ratio
    #: by scaling ``bandwidths`` (wire rate × ratio = effective decoded
    #: rate) and pay the inflate cost here.  0 (default) disables the
    #: term and reproduces earlier builds' jaxprs exactly — the gating
    #: is static, like every other field.
    decode_bytes_per_s: float = 0.0


class JaxSimResult(NamedTuple):
    #: seconds; +inf if the transfer did NOT complete within the engine's
    #: iteration bound (``max_iters``, or the scan engine's fixed
    #: ``max_rounds``) — a truncated simulation must not masquerade as a
    #: fast one.
    total_time: jax.Array        # scalar f32
    bytes_per_server: jax.Array  # [N] f32
    requests_per_server: jax.Array  # [N] i32
    iters: jax.Array             # scalar i32 (loop-iteration diagnostics)


class _State(NamedTuple):
    t_free: jax.Array        # [N] next time each server is free (inf = retired)
    th: jax.Array            # [N] observed throughput (0 = unprobed)
    cursor: jax.Array        # scalar, bytes assigned
    t_done: jax.Array        # scalar, latest completion seen
    pending: jax.Array       # [N] in-flight chunk size (0 = none)
    pending_dt: jax.Array    # [N] in-flight chunk duration
    pending_ok: jax.Array    # [N] bool, in-flight chunk will verify/arrive
    bytes_srv: jax.Array     # [N]
    reqs: jax.Array          # [N] i32
    it: jax.Array            # scalar i32
    key: jax.Array           # PRNG


def _chunk_duration(
    size: jax.Array, t0: jax.Array, rtt: jax.Array,
    bw0: jax.Array, throttle_t: jax.Array, bw1: jax.Array,
    depth: int = 1, warm: jax.Array | None = None,
    decode_bw: float = 0.0,
) -> jax.Array:
    """Time to fetch ``size`` bytes starting at ``t0`` on one server whose
    rate steps from ``bw0`` to ``bw1`` at ``throttle_t``.

    ``depth`` models the runtime's per-connection request pipelining (see
    ``SimConfig.pipeline_depth``): a ``warm`` server (one that has already
    served a request, so the pipe is primed) pays only the RTT residue
    not hidden behind its ``depth - 1`` in-flight bodies,
    ``max(0, rtt - (depth - 1) * body_time)``.  ``warm=None`` treats every
    chunk as warm; cold chunks and ``depth == 1`` pay the full RTT.
    Throttle-window arithmetic keeps the request-arrival convention
    ``t_start = t0 + rtt`` in all cases (the breakpoint is a property of
    the path, and keeping it fixed preserves the depth=1 jaxpr exactly).

    Elementwise, so it vectorizes over the ``[N]`` server axis of the
    round cores unchanged.  The untaken branch is re-clamped to a finite
    value ("double where") because ``throttle_t`` is ``inf`` for
    unthrottled servers: ``inf - inf`` NaNs in a discarded branch would
    otherwise poison reverse-mode gradients of the scan core.
    """
    t_start = t0 + rtt
    # bytes deliverable at the pre-throttle rate
    window = jnp.maximum(throttle_t - t_start, 0.0)
    first = bw0 * window
    pre_only = size <= first            # whole chunk fits before throttle
    window_safe = jnp.where(pre_only, 0.0, window)   # finite in both arms
    first_safe = bw0 * window_safe
    dur_pre = size / jnp.maximum(bw0, 1e-9)
    dur_post = window_safe + (size - first_safe) / jnp.maximum(bw1, 1e-9)
    dur = jnp.where(pre_only, dur_pre, dur_post)
    # throttle already in effect at t_start
    dur = jnp.where(t_start >= throttle_t, size / jnp.maximum(bw1, 1e-9), dur)
    if decode_bw > 0.0:
        # per-chunk compute term (``SimConfig.decode_bytes_per_s``): an
        # encoded body must inflate before the lane frees up, so decode
        # time occupies the lane like body time — and, below, hides RTT
        # behind the pipeline the same way.  Statically gated: the
        # decode-free jaxpr is unchanged.
        dur = dur + size / jnp.float32(decode_bw)
    if depth <= 1:
        return rtt + dur
    rtt_eff = jnp.maximum(rtt - (depth - 1) * dur, 0.0)
    if warm is not None:
        rtt_eff = jnp.where(warm, rtt_eff, rtt)
    return rtt_eff + dur


def _make_step(chunk: ChunkArrays, mode: str, cfg: SimConfig,
               file_size: jax.Array):
    """Build the while-loop body.  ``chunk`` / ``file_size`` are tracers
    (closed over — lax.while_loop hoists them as loop constants); ``mode``
    selects the allocator branch, ``mode="static"`` being the fixed-chunk
    baseline."""

    def body(args):
        state, bw0, throttle_t, bw1, rtt = args
        # Next event: the earliest-free active server.
        i = jnp.argmin(state.t_free)
        now = state.t_free[i]

        # 1) Complete its in-flight chunk (if any) and observe throughput.
        # A faulted chunk (lost / failed verification) consumed the full
        # duration but credits nothing: no bytes, no throughput sample,
        # no t_done — and its range rolls back into the remaining budget
        # so the allocator re-issues it, exactly like the real client's
        # verify-and-re-pool path.
        size_done = state.pending[i]
        has_pending = size_done > 0.0
        ok_i = jnp.logical_and(has_pending, state.pending_ok[i])
        bad_i = jnp.logical_and(has_pending,
                                jnp.logical_not(state.pending_ok[i]))
        th_obs = size_done / jnp.maximum(state.pending_dt[i], 1e-12)
        th = state.th.at[i].set(jnp.where(ok_i, th_obs, state.th[i]))
        bytes_srv = state.bytes_srv.at[i].add(jnp.where(ok_i, size_done, 0.0))
        t_done = jnp.where(ok_i, jnp.maximum(state.t_done, now), state.t_done)
        cursor0 = state.cursor - jnp.where(bad_i, size_done, 0.0)

        # 2) Ask the allocator for the next request.  float32 cursor
        # accumulation absorbs sub-eps residues at 64 GB scale, so anything
        # below ~2 ulp of the file size counts as done (planning tool — the
        # byte-exact path is the Python simulator / real client).
        remaining = jnp.maximum(file_size - cursor0, 0.0)
        eps = file_size * jnp.float32(3e-7) + jnp.float32(1.0)
        remaining = jnp.where(remaining <= eps, 0.0, remaining)
        size = chunk_sizes(th, remaining, chunk, mode=mode,
                           exact=cfg.exact_sizes)[i]
        active = size > 0.0

        key, sub = jax.random.split(state.key)
        scale = jnp.float32(1.0)
        if cfg.jitter > 0.0:
            scale = jnp.exp(
                jax.random.normal(sub) * cfg.jitter - 0.5 * cfg.jitter**2
            )
        dt = _chunk_duration(size, now, rtt[i], bw0[i] * scale, throttle_t[i],
                             bw1[i] * scale, depth=cfg.pipeline_depth,
                             warm=state.reqs[i] > 0,
                             decode_bw=cfg.decode_bytes_per_s)

        # Fault draw at issue time (the outcome is predetermined but only
        # observed at completion).  The extra split happens ONLY when a
        # fault rate is set, so fault-free seeds replay bit-identically.
        p_fail = cfg.loss_rate + cfg.corruption_rate
        ok_new = jnp.bool_(True)
        if p_fail > 0.0:
            key, fk = jax.random.split(key)
            ok_new = jax.random.uniform(fk) >= jnp.float32(p_fail)
        pending_ok = state.pending_ok.at[i].set(
            jnp.where(active, ok_new, True))

        t_free = state.t_free.at[i].set(jnp.where(active, now + dt, _INF))
        pending = state.pending.at[i].set(jnp.where(active, size, 0.0))
        pending_dt = state.pending_dt.at[i].set(jnp.where(active, dt, 0.0))
        cursor = cursor0 + jnp.where(active, size, 0.0)
        reqs = state.reqs.at[i].add(jnp.where(active, 1, 0))

        new_state = _State(
            t_free=t_free, th=th, cursor=cursor, t_done=t_done,
            pending=pending, pending_dt=pending_dt, pending_ok=pending_ok,
            bytes_srv=bytes_srv, reqs=reqs, it=state.it + 1, key=key,
        )
        return (new_state, bw0, throttle_t, bw1, rtt)

    def cond(args):
        state = args[0]
        return jnp.logical_and(
            jnp.any(jnp.isfinite(state.t_free)), state.it < cfg.max_iters
        )

    return cond, body


def _apply_rtt_jitter(rtt: jax.Array, seed, cfg: SimConfig) -> jax.Array:
    """Scale every server's RTT by a mean-1 lognormal factor, once per
    simulation.  Keyed on a ``fold_in`` of the traced seed so the draw is
    independent of the per-chunk bandwidth-jitter stream (which starts
    from ``PRNGKey(seed)`` and splits).  A pure element-wise transform of
    a traced input — vmappable and reverse-differentiable like the rest
    of the scan core."""
    if cfg.rtt_jitter <= 0.0:
        return rtt
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 0x7772)
    noise = jax.random.normal(key, rtt.shape)
    return rtt * jnp.exp(noise * cfg.rtt_jitter - 0.5 * cfg.rtt_jitter**2)


def _init_state(n: int, seed) -> _State:
    return _State(
        t_free=jnp.zeros((n,), jnp.float32),
        th=jnp.zeros((n,), jnp.float32),
        cursor=jnp.float32(0.0),
        t_done=jnp.float32(0.0),
        pending=jnp.zeros((n,), jnp.float32),
        pending_dt=jnp.zeros((n,), jnp.float32),
        pending_ok=jnp.ones((n,), jnp.bool_),
        bytes_srv=jnp.zeros((n,), jnp.float32),
        reqs=jnp.zeros((n,), jnp.int32),
        it=jnp.int32(0),
        key=jax.random.PRNGKey(seed),
    )


def _result(final: _State) -> JaxSimResult:
    """Common result build: a transfer is complete iff every connection
    retired (``t_free`` all +inf).  An exhausted iteration bound — event
    ``max_iters`` or the scan engine's fixed ``max_rounds`` — leaves live
    connections behind, and the truncated simulation reports ``inf``
    rather than masquerading as a fast transfer."""
    complete = jnp.logical_not(jnp.any(jnp.isfinite(final.t_free)))
    return JaxSimResult(
        total_time=jnp.where(complete, final.t_done, _INF),
        bytes_per_server=final.bytes_srv,
        requests_per_server=final.reqs,
        iters=final.it,
    )


def simulate_core(
    bandwidth: jax.Array,
    rtt: jax.Array,
    throttle_t: jax.Array,
    throttle_bw: jax.Array,
    seed: jax.Array,
    chunk: ChunkArrays,
    file_size: jax.Array,
    *,
    mode: str,
    config: SimConfig,
) -> JaxSimResult:
    """Pure traced core: one transfer, every sweepable quantity an array.

    All positional arguments are traced (``chunk`` is a pytree of scalars,
    ``file_size``/``seed`` scalars) so callers may ``vmap`` over any of
    them — the autotuner stacks a (C, L) grid, a seed axis, and a scenario
    axis on top of this single function and compiles once.
    """
    state = _init_state(bandwidth.shape[0], seed)
    file_size = jnp.asarray(file_size, jnp.float32)
    rtt = _apply_rtt_jitter(rtt.astype(jnp.float32), seed, config)
    cond, body = _make_step(chunk, mode, config, file_size)
    final, *_ = jax.lax.while_loop(
        cond, body,
        (state, bandwidth.astype(jnp.float32), throttle_t.astype(jnp.float32),
         throttle_bw.astype(jnp.float32), rtt),
    )
    return _result(final)


def _make_round_step(chunk: ChunkArrays, mode: str, cfg: SimConfig,
                     file_size: jax.Array):
    """Build the shared round-step used by BOTH round engines.

    One invocation = one MDTP round: complete every in-flight chunk,
    observe all N throughputs, and allocate the next full round in a
    single vectorized draw (``round_allocate`` — one cursor update).
    Rounds are synchronous in *sequence*, not forced to a global time
    barrier: each server starts its next chunk the instant its previous
    one finished (per-server clock ``t_free``), which is exactly the
    event core's schedule when chunk durations equalize within a round.

    Once the transfer drains the step is a no-op (all sizes 0, every
    ``t_free`` pinned at +inf), which is what lets the scan engine run a
    fixed trip count with masked tail steps.
    """

    def step(state: _State, bw0, throttle_t, bw1, rtt) -> _State:
        # 1) Complete ALL in-flight chunks; observe every server at once.
        # Faulted chunks (predrawn at issue) credit nothing — no bytes,
        # no throughput sample, no t_done — and roll their ranges back
        # into the remaining budget for re-allocation.
        has_pending = state.pending > 0.0
        ok_v = jnp.logical_and(has_pending, state.pending_ok)
        bad_v = jnp.logical_and(has_pending,
                                jnp.logical_not(state.pending_ok))
        th = jnp.where(
            ok_v,
            state.pending / jnp.maximum(state.pending_dt, 1e-12),
            state.th)
        bytes_srv = state.bytes_srv + jnp.where(ok_v, state.pending, 0.0)
        t_done = jnp.maximum(
            state.t_done,
            jnp.max(jnp.where(ok_v, state.t_free, -_INF)))
        cursor0 = state.cursor - jnp.sum(
            jnp.where(bad_v, state.pending, 0.0))

        # 2) One batched allocation for the whole round (same eps logic as
        # the event core: float32 cursor residue below ~2 ulp of the file
        # size counts as done).
        remaining = jnp.maximum(file_size - cursor0, 0.0)
        eps = file_size * jnp.float32(3e-7) + jnp.float32(1.0)
        remaining = jnp.where(remaining <= eps, 0.0, remaining)

        # Time-aware budget debit: in the event core a server only draws
        # from the cursor if bytes remain AT ITS ASK TIME.  Server j's
        # draws land at ``t_free[j] + k * dur_j``; the number before
        # server i's ask is ``ceil(lag_ij / dur_j)`` (index tie-break for
        # simultaneous asks).  For clock-aligned fleets this reduces to
        # the plain ask-order prefix (every lag is a fraction of a round
        # → count 1), but a straggler — e.g. a glacial replica still
        # finishing its probe while fast peers run whole extra rounds —
        # sees those interim chunks debited and is starved exactly as the
        # event core would starve it.  Durations come from the true rate
        # model (`_chunk_duration`), not the observed throughputs, so the
        # count is right during ramp-up too.  The earliest-asking server
        # has lag 0 everywhere and is never debited, so the cursor always
        # progresses and the loop terminates.  ``ceil`` only modulates a
        # count (zero cotangent), leaving scan-engine gradients intact.
        alive = jnp.isfinite(state.t_free)
        sizes_est = chunk_sizes(th, remaining, chunk, mode=mode,
                                exact=cfg.exact_sizes)
        tf_safe = jnp.where(alive, state.t_free, 0.0)
        dur_est = _chunk_duration(sizes_est, tf_safe, rtt, bw0, throttle_t,
                                  bw1, depth=cfg.pipeline_depth,
                                  warm=state.reqs > 0,
                                  decode_bw=cfg.decode_bytes_per_s)
        lag = jnp.maximum(tf_safe[:, None] - tf_safe[None, :], 0.0)
        idx = jnp.arange(lag.shape[0])
        tie = jnp.logical_and(tf_safe[:, None] == tf_safe[None, :],
                              idx[None, :] < idx[:, None])
        counts = jnp.ceil(lag / jnp.maximum(dur_est, 1e-9)[None, :])
        counts = counts + tie.astype(jnp.float32)
        granted, total = round_allocate(
            th, remaining, state.t_free, chunk, mode=mode,
            exact=cfg.exact_sizes, eligible=alive, draw_counts=counts)
        active = granted > 0.0

        # 3) All N durations in one vector op (no per-event argmin).
        # Retired servers' clocks are +inf — clamp them out of the
        # arithmetic so discarded-branch NaNs can't poison scan gradients.
        now = jnp.where(jnp.isfinite(state.t_free), state.t_free, 0.0)
        key, sub = jax.random.split(state.key)
        scale = jnp.float32(1.0)
        if cfg.jitter > 0.0:
            scale = jnp.exp(
                jax.random.normal(sub, now.shape) * cfg.jitter
                - 0.5 * cfg.jitter**2)
        dt = _chunk_duration(granted, now, rtt, bw0 * scale, throttle_t,
                             bw1 * scale, depth=cfg.pipeline_depth,
                             warm=state.reqs > 0,
                             decode_bw=cfg.decode_bytes_per_s)
        if cfg.hedge_quantile > 0.0:
            # Hedged endgame (the client's, see transfer.client): a range
            # on a server whose chunk duration exceeds the fleet's hedge
            # quantile is speculatively re-served once the transfer
            # reaches its endgame with the range still outstanding, and
            # the first completion wins.  Modeled as a completion-time
            # cap: the straggler's chunk finishes no later than (a) the
            # rest of the fleet drains the remaining budget — the moment
            # the endgame frees a fast mirror — plus (b) the winner's
            # RTT + body time at its then-current rate.  In the final
            # round the drain term is zero and this is exactly "first
            # idle server re-serves it"; mid-transfer it prices the
            # many rounds the fleet still owes, so only chunks that
            # genuinely outlive the transfer (a grayed mirror's
            # transition chunk) are trimmed.  Bytes stay credited to the
            # owner — wire-level win/waste accounting lives on
            # TransferReport.  Pure transform of already-drawn
            # durations: NO PRNG is consumed and the gating is static,
            # so hedge-free configs replay bit-identical streams.
            t_fin = now + dt
            w = jnp.argmin(jnp.where(active, t_fin, _INF))
            t_best = jnp.min(jnp.where(active, t_fin, _INF))
            q = jnp.nanquantile(jnp.where(active, dt, jnp.nan),
                                jnp.float32(cfg.hedge_quantile))
            eff_bw = jnp.where(t_best >= throttle_t, bw1, bw0) * scale
            fleet_bw = jnp.sum(jnp.where(active, eff_bw, 0.0))
            others_bw = fleet_bw - eff_bw
            remaining_after = jnp.maximum(remaining - total, 0.0)
            t_drain = jnp.where(
                others_bw > 0.0,
                t_best + remaining_after / jnp.maximum(others_bw, 1e-9),
                _INF)
            hedge_fin = (t_drain + rtt[w]
                         + granted / jnp.maximum(eff_bw[w], 1e-9))
            if cfg.decode_bytes_per_s > 0.0:
                # the winner's re-serve pays the decode term too
                hedge_fin = hedge_fin + granted / jnp.float32(
                    cfg.decode_bytes_per_s)
            straggler = jnp.logical_and(active, dt > q)
            straggler = jnp.logical_and(
                straggler, jnp.arange(dt.shape[0]) != w)
            dt = jnp.where(straggler,
                           jnp.minimum(dt, jnp.maximum(hedge_fin - now,
                                                       1e-9)),
                           dt)
        t_free = jnp.where(active, now + dt, _INF)

        # Fault draws for the whole round at once; extra split only when a
        # rate is set so fault-free seeds replay bit-identically.
        p_fail = cfg.loss_rate + cfg.corruption_rate
        ok_new = jnp.ones(now.shape, jnp.bool_)
        if p_fail > 0.0:
            key, fk = jax.random.split(key)
            ok_new = jax.random.uniform(fk, now.shape) >= jnp.float32(p_fail)

        stepped = jnp.logical_or(jnp.any(has_pending), jnp.any(active))
        return _State(
            t_free=t_free,
            th=th,
            cursor=cursor0 + total,
            t_done=t_done,
            pending=jnp.where(active, granted, 0.0),
            pending_dt=jnp.where(active, dt, 0.0),
            pending_ok=jnp.where(active, ok_new, True),
            bytes_srv=bytes_srv,
            reqs=state.reqs + active.astype(jnp.int32),
            it=state.it + stepped.astype(jnp.int32),
            key=key,
        )

    return step


def simulate_round_core(
    bandwidth: jax.Array,
    rtt: jax.Array,
    throttle_t: jax.Array,
    throttle_bw: jax.Array,
    seed: jax.Array,
    chunk: ChunkArrays,
    file_size: jax.Array,
    *,
    mode: str,
    config: SimConfig,
) -> JaxSimResult:
    """Round-synchronous ``while_loop`` core: O(#rounds) trip count with
    early exit.  Same signature and traced-input contract as
    :func:`simulate_core`; ``iters`` counts rounds, not events."""
    state = _init_state(bandwidth.shape[0], seed)
    file_size = jnp.asarray(file_size, jnp.float32)
    rtt = _apply_rtt_jitter(rtt.astype(jnp.float32), seed, config)
    step = _make_round_step(chunk, mode, config, file_size)

    def body(args):
        st, bw0, tt, tb, rt = args
        return (step(st, bw0, tt, tb, rt), bw0, tt, tb, rt)

    def cond(args):
        st = args[0]
        return jnp.logical_and(
            jnp.any(jnp.isfinite(st.t_free)), st.it < config.max_iters)

    final, *_ = jax.lax.while_loop(
        cond, body,
        (state, bandwidth.astype(jnp.float32), throttle_t.astype(jnp.float32),
         throttle_bw.astype(jnp.float32), rtt),
    )
    return _result(final)


def simulate_scan_core(
    bandwidth: jax.Array,
    rtt: jax.Array,
    throttle_t: jax.Array,
    throttle_bw: jax.Array,
    seed: jax.Array,
    chunk: ChunkArrays,
    file_size: jax.Array,
    *,
    mode: str,
    config: SimConfig,
) -> JaxSimResult:
    """Fixed-round-bound masked ``lax.scan`` core.

    Exactly ``config.max_rounds`` steps regardless of data — steps after
    the transfer drains are no-ops — so vmapped lanes never diverge in
    lockstep cost, and the whole simulation is reverse-differentiable:
    ``jax.grad`` of ``total_time`` w.r.t. the traced ``chunk`` / scenario
    inputs is well-defined (pair with ``SimConfig(exact_sizes=False)`` so
    the allocator's integer rounding doesn't zero the (C, L) gradient).
    ``config.max_rounds`` must cover ``ceil(file_size / large_chunk) + 2``;
    a bound the transfer outruns yields ``total_time = inf``.
    """
    state = _init_state(bandwidth.shape[0], seed)
    file_size = jnp.asarray(file_size, jnp.float32)
    step = _make_round_step(chunk, mode, config, file_size)
    bw0 = bandwidth.astype(jnp.float32)
    tt = throttle_t.astype(jnp.float32)
    tb = throttle_bw.astype(jnp.float32)
    rt = _apply_rtt_jitter(rtt.astype(jnp.float32), seed, config)

    def scan_body(st, _):
        return step(st, bw0, tt, tb, rt), None

    final, _ = jax.lax.scan(scan_body, state, None, length=config.max_rounds)
    return _result(final)


#: Modes whose rounds complete in lockstep by construction (§IV: chunk
#: sizes equalize durations), i.e. where the round engines are faithful.
_ROUND_SYNC_MODES = ("proportional", "fast_get_large")

_CORES = {
    "event": simulate_core,
    "round": simulate_round_core,
    "scan": simulate_scan_core,
}


def resolve_engine(engine: str | None, mode: str) -> str:
    """Map ``engine=None``/``"auto"`` to the faithful default for ``mode``.

    ``"round"`` for the round-synchronous allocator modes; ``"event"`` for
    ``mode="static"``, where fixed chunk sizes make fast servers take many
    more chunks per unit time than slow ones (rounds never synchronize, so
    a one-chunk-per-server-per-round core would mis-share the file).
    """
    if engine in (None, "auto"):
        return "round" if mode in _ROUND_SYNC_MODES else "event"
    if engine not in _CORES:
        raise ValueError(
            f"unknown engine: {engine!r} (expected event|round|scan)")
    return engine


def _dispatch_core(bandwidth, rtt, throttle_t, throttle_bw, seed, chunk,
                   file_size, *, mode, config, engine):
    return _CORES[engine](
        bandwidth, rtt, throttle_t, throttle_bw, seed, chunk, file_size,
        mode=mode, config=config)


_simulate = jax.jit(
    _dispatch_core, static_argnames=("mode", "config", "engine"))


def _prep(bandwidth, rtt, throttle_t, throttle_bw):
    """Normalize scenario inputs: broadcast rtt/throttle args to the
    bandwidth shape — ``[N]`` single-scenario or ``[S, N]`` batched."""
    bandwidth = jnp.asarray(bandwidth, jnp.float32)
    shape = bandwidth.shape
    rtt = jnp.broadcast_to(jnp.asarray(rtt, jnp.float32), shape)
    if throttle_t is None:
        throttle_t = jnp.full(shape, jnp.inf, jnp.float32)
    else:
        throttle_t = jnp.broadcast_to(
            jnp.asarray(throttle_t, jnp.float32), shape)
    if throttle_bw is None:
        throttle_bw = bandwidth
    else:
        throttle_bw = jnp.broadcast_to(
            jnp.asarray(throttle_bw, jnp.float32), shape)
    return bandwidth, rtt, throttle_t, throttle_bw


def simulate_transfer(
    bandwidth,
    rtt,
    file_size: float,
    params: ChunkParamsLike,
    throttle_t=None,
    throttle_bw=None,
    seed: int = 0,
    config: SimConfig = SimConfig(),
    mode: str | None = None,
    engine: str | None = "event",
) -> JaxSimResult:
    """MDTP transfer on-device.  All array args are per-server ``[N]``.

    ``params`` may be a static ``ChunkParams`` or a traced ``ChunkArrays``
    / ``(C, L, min)`` triple; either way the chunk geometry enters the
    compiled function as data, so calls differing only in chunk sizes,
    file size, or seed share one executable.

    ``engine`` selects the loop structure (see the module docstring):
    ``"event"`` (default — exact event ordering, O(#chunks) steps),
    ``"round"`` (O(#rounds) vectorized steps, the autotuner's default),
    ``"scan"`` (fixed ``config.max_rounds`` trip count, differentiable),
    or ``None``/``"auto"`` (``"round"`` unless ``mode="static"``).
    """
    chunk, mode = as_chunk_arrays(params, mode)
    engine = resolve_engine(engine, mode)
    bandwidth, rtt, throttle_t, throttle_bw = _prep(
        bandwidth, rtt, throttle_t, throttle_bw)
    return _simulate(
        bandwidth, rtt, throttle_t, throttle_bw, seed, chunk,
        jnp.float32(file_size), mode=mode, config=config, engine=engine,
    )


def simulate_static(
    bandwidth,
    rtt,
    file_size: float,
    chunk_size: float,
    throttle_t=None,
    throttle_bw=None,
    seed: int = 0,
    config: SimConfig = SimConfig(),
) -> JaxSimResult:
    """Static-chunking transfer on-device (Rodriguez baseline).

    Same code path as :func:`simulate_transfer` with ``C == L == chunk``
    under ``mode="static"`` — not a separately compiled jaxpr.  Always the
    event engine: fixed chunks are NOT round-synchronous (a 5× faster
    server takes 5× the chunks per unit time).
    """
    c = jnp.float32(chunk_size)
    return simulate_transfer(
        bandwidth, rtt, file_size, ChunkArrays(c, c, c),
        throttle_t=throttle_t, throttle_bw=throttle_bw,
        seed=seed, config=config, mode="static", engine="event",
    )
