"""On-device (JAX) event-driven transfer simulator.

A ``lax.while_loop`` re-expression of the discrete-event simulator for the
MDTP and static-chunking policies: one persistent connection per server,
constant per-server bandwidth with an optional single throttle breakpoint
(Fig. 4-style), optional per-chunk lognormal jitter.  No failure modeling —
that path needs the Python simulator's range-reclaim pool.

Why this exists (hardware adaptation): the paper picks chunk sizes
empirically and leaves automatic selection to future work (§VIII-A).
Expressing the whole transfer as a pure JAX function makes the evaluation
loop *vmappable*: thousands of (bandwidth vector, C, L, seed) scenarios
simulate in one device call, which is what ``repro.core.autotune`` uses to
pick chunk sizes — a TPU-native replacement for the paper's manual grid.

Every quantity that varies across a sweep is a **traced input**: the
chunk geometry rides a :class:`~repro.core.jax_alloc.ChunkArrays` pytree,
the file size is a traced scalar, and the PRNG seed is a traced int.  Only
``mode`` (allocator branch structure) and :class:`SimConfig` (loop bounds /
jitter switch) are static — so an arbitrary (C, L) × seed × scenario grid
compiles exactly once.  Static chunking is the same code path with
``C == L == chunk`` under ``mode="static"``, not a separate jaxpr.

Cross-checked against the Python simulator in tests (same scenario → same
completion time within float tolerance).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .jax_alloc import ChunkArrays, ChunkParamsLike, as_chunk_arrays, chunk_sizes

__all__ = [
    "SimConfig",
    "JaxSimResult",
    "simulate_core",
    "simulate_transfer",
    "simulate_static",
]

_INF = jnp.float32(jnp.inf)


class SimConfig(NamedTuple):
    """Static simulation parameters (baked into the jaxpr)."""

    max_iters: int = 100_000
    jitter: float = 0.0  # lognormal sigma per chunk; 0 = deterministic


class JaxSimResult(NamedTuple):
    total_time: jax.Array        # scalar f32, seconds
    bytes_per_server: jax.Array  # [N] f32
    requests_per_server: jax.Array  # [N] i32
    iters: jax.Array             # scalar i32 (loop-iteration diagnostics)


class _State(NamedTuple):
    t_free: jax.Array        # [N] next time each server is free (inf = retired)
    th: jax.Array            # [N] observed throughput (0 = unprobed)
    cursor: jax.Array        # scalar, bytes assigned
    t_done: jax.Array        # scalar, latest completion seen
    pending: jax.Array       # [N] in-flight chunk size (0 = none)
    pending_dt: jax.Array    # [N] in-flight chunk duration
    bytes_srv: jax.Array     # [N]
    reqs: jax.Array          # [N] i32
    it: jax.Array            # scalar i32
    key: jax.Array           # PRNG


def _chunk_duration(
    size: jax.Array, t0: jax.Array, rtt: jax.Array,
    bw0: jax.Array, throttle_t: jax.Array, bw1: jax.Array,
) -> jax.Array:
    """Time to fetch ``size`` bytes starting at ``t0`` on one server whose
    rate steps from ``bw0`` to ``bw1`` at ``throttle_t``."""
    t_start = t0 + rtt
    # bytes deliverable at the pre-throttle rate
    window = jnp.maximum(throttle_t - t_start, 0.0)
    first = bw0 * window
    dur_pre = size / bw0
    dur_post = window + (size - first) / jnp.maximum(bw1, 1e-9)
    dur = jnp.where(size <= first, dur_pre, dur_post)
    # throttle already in effect at t_start
    dur = jnp.where(t_start >= throttle_t, size / jnp.maximum(bw1, 1e-9), dur)
    return rtt + dur


def _make_step(chunk: ChunkArrays, mode: str, cfg: SimConfig,
               file_size: jax.Array):
    """Build the while-loop body.  ``chunk`` / ``file_size`` are tracers
    (closed over — lax.while_loop hoists them as loop constants); ``mode``
    selects the allocator branch, ``mode="static"`` being the fixed-chunk
    baseline."""

    def body(args):
        state, bw0, throttle_t, bw1, rtt = args
        # Next event: the earliest-free active server.
        i = jnp.argmin(state.t_free)
        now = state.t_free[i]

        # 1) Complete its in-flight chunk (if any) and observe throughput.
        size_done = state.pending[i]
        has_pending = size_done > 0.0
        th_obs = size_done / jnp.maximum(state.pending_dt[i], 1e-12)
        th = state.th.at[i].set(jnp.where(has_pending, th_obs, state.th[i]))
        bytes_srv = state.bytes_srv.at[i].add(jnp.where(has_pending, size_done, 0.0))
        t_done = jnp.where(has_pending, jnp.maximum(state.t_done, now), state.t_done)

        # 2) Ask the allocator for the next request.  float32 cursor
        # accumulation absorbs sub-eps residues at 64 GB scale, so anything
        # below ~2 ulp of the file size counts as done (planning tool — the
        # byte-exact path is the Python simulator / real client).
        remaining = jnp.maximum(file_size - state.cursor, 0.0)
        eps = file_size * jnp.float32(3e-7) + jnp.float32(1.0)
        remaining = jnp.where(remaining <= eps, 0.0, remaining)
        size = chunk_sizes(th, remaining, chunk, mode=mode)[i]
        active = size > 0.0

        key, sub = jax.random.split(state.key)
        scale = jnp.float32(1.0)
        if cfg.jitter > 0.0:
            scale = jnp.exp(
                jax.random.normal(sub) * cfg.jitter - 0.5 * cfg.jitter**2
            )
        dt = _chunk_duration(size, now, rtt[i], bw0[i] * scale, throttle_t[i],
                             bw1[i] * scale)

        t_free = state.t_free.at[i].set(jnp.where(active, now + dt, _INF))
        pending = state.pending.at[i].set(jnp.where(active, size, 0.0))
        pending_dt = state.pending_dt.at[i].set(jnp.where(active, dt, 0.0))
        cursor = state.cursor + jnp.where(active, size, 0.0)
        reqs = state.reqs.at[i].add(jnp.where(active, 1, 0))

        new_state = _State(
            t_free=t_free, th=th, cursor=cursor, t_done=t_done,
            pending=pending, pending_dt=pending_dt, bytes_srv=bytes_srv,
            reqs=reqs, it=state.it + 1, key=key,
        )
        return (new_state, bw0, throttle_t, bw1, rtt)

    def cond(args):
        state = args[0]
        return jnp.logical_and(
            jnp.any(jnp.isfinite(state.t_free)), state.it < cfg.max_iters
        )

    return cond, body


def simulate_core(
    bandwidth: jax.Array,
    rtt: jax.Array,
    throttle_t: jax.Array,
    throttle_bw: jax.Array,
    seed: jax.Array,
    chunk: ChunkArrays,
    file_size: jax.Array,
    *,
    mode: str,
    config: SimConfig,
) -> JaxSimResult:
    """Pure traced core: one transfer, every sweepable quantity an array.

    All positional arguments are traced (``chunk`` is a pytree of scalars,
    ``file_size``/``seed`` scalars) so callers may ``vmap`` over any of
    them — the autotuner stacks a (C, L) grid, a seed axis, and a scenario
    axis on top of this single function and compiles once.
    """
    n = bandwidth.shape[0]
    state = _State(
        t_free=jnp.zeros((n,), jnp.float32),
        th=jnp.zeros((n,), jnp.float32),
        cursor=jnp.float32(0.0),
        t_done=jnp.float32(0.0),
        pending=jnp.zeros((n,), jnp.float32),
        pending_dt=jnp.zeros((n,), jnp.float32),
        bytes_srv=jnp.zeros((n,), jnp.float32),
        reqs=jnp.zeros((n,), jnp.int32),
        it=jnp.int32(0),
        key=jax.random.PRNGKey(seed),
    )
    file_size = jnp.asarray(file_size, jnp.float32)
    cond, body = _make_step(chunk, mode, config, file_size)
    final, *_ = jax.lax.while_loop(
        cond, body,
        (state, bandwidth.astype(jnp.float32), throttle_t.astype(jnp.float32),
         throttle_bw.astype(jnp.float32), rtt.astype(jnp.float32)),
    )
    return JaxSimResult(
        total_time=final.t_done,
        bytes_per_server=final.bytes_srv,
        requests_per_server=final.reqs,
        iters=final.it,
    )


_simulate = jax.jit(simulate_core, static_argnames=("mode", "config"))


def _prep(bandwidth, rtt, throttle_t, throttle_bw):
    """Normalize scenario inputs: broadcast rtt/throttle args to the
    bandwidth shape — ``[N]`` single-scenario or ``[S, N]`` batched."""
    bandwidth = jnp.asarray(bandwidth, jnp.float32)
    shape = bandwidth.shape
    rtt = jnp.broadcast_to(jnp.asarray(rtt, jnp.float32), shape)
    if throttle_t is None:
        throttle_t = jnp.full(shape, jnp.inf, jnp.float32)
    else:
        throttle_t = jnp.broadcast_to(
            jnp.asarray(throttle_t, jnp.float32), shape)
    if throttle_bw is None:
        throttle_bw = bandwidth
    else:
        throttle_bw = jnp.broadcast_to(
            jnp.asarray(throttle_bw, jnp.float32), shape)
    return bandwidth, rtt, throttle_t, throttle_bw


def simulate_transfer(
    bandwidth,
    rtt,
    file_size: float,
    params: ChunkParamsLike,
    throttle_t=None,
    throttle_bw=None,
    seed: int = 0,
    config: SimConfig = SimConfig(),
    mode: str | None = None,
) -> JaxSimResult:
    """MDTP transfer on-device.  All array args are per-server ``[N]``.

    ``params`` may be a static ``ChunkParams`` or a traced ``ChunkArrays``
    / ``(C, L, min)`` triple; either way the chunk geometry enters the
    compiled function as data, so calls differing only in chunk sizes,
    file size, or seed share one executable.
    """
    chunk, mode = as_chunk_arrays(params, mode)
    bandwidth, rtt, throttle_t, throttle_bw = _prep(
        bandwidth, rtt, throttle_t, throttle_bw)
    return _simulate(
        bandwidth, rtt, throttle_t, throttle_bw, seed, chunk,
        jnp.float32(file_size), mode=mode, config=config,
    )


def simulate_static(
    bandwidth,
    rtt,
    file_size: float,
    chunk_size: float,
    throttle_t=None,
    throttle_bw=None,
    seed: int = 0,
    config: SimConfig = SimConfig(),
) -> JaxSimResult:
    """Static-chunking transfer on-device (Rodriguez baseline).

    Same code path as :func:`simulate_transfer` with ``C == L == chunk``
    under ``mode="static"`` — not a separately compiled jaxpr.
    """
    c = jnp.float32(chunk_size)
    return simulate_transfer(
        bandwidth, rtt, file_size, ChunkArrays(c, c, c),
        throttle_t=throttle_t, throttle_bw=throttle_bw,
        seed=seed, config=config, mode="static",
    )
