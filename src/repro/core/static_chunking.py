"""Static (uniform) chunking baseline — Rodriguez & Biersack [13].

Identical plumbing to MDTP (one persistent connection per server, global
byte cursor, work-conserving: a free server immediately grabs the next
chunk), but every request is the same fixed size.  This is the paper's
"Static Chunking" comparison implementation (§V): *"It shares the core
features and operational details of MDTP, with the primary difference being
its chunk-sizing strategy."*  Like the paper's version (and unlike the
original Rodriguez scheme) it does **not** re-request in-flight chunks at
the endgame — each byte is requested once.
"""

from __future__ import annotations

from typing import Optional

from .simulator import Action, Policy, Request, TransferState

__all__ = ["StaticChunkingPolicy", "default_static_chunk"]

MB = 1024 * 1024


def default_static_chunk(file_size: int) -> int:
    """The paper tuned static chunk sizes per file (§VI-A); these match the
    MDTP large-chunk regime which was competitive in their sweep."""
    return 40 * MB if file_size <= 8 * 1024 * MB else 160 * MB


class StaticChunkingPolicy(Policy):
    name = "static"

    def __init__(self, chunk_size: Optional[int] = None):
        self._chunk_arg = chunk_size

    def reset(self, n_servers: int, file_size: int) -> None:
        self.chunk = self._chunk_arg or default_static_chunk(file_size)
        self._dead = [False] * n_servers

    def next_action(self, state: TransferState, conn: int, now: float) -> Action:
        if self._dead[conn]:
            return None
        remaining = state.unassigned_bytes()
        if remaining <= 0:
            return None
        return Request(conn, min(self.chunk, remaining))

    def on_complete(
        self, state: TransferState, conn: int, server: int,
        nbytes: int, elapsed: float, now: float, truncated: bool = False,
    ) -> None:
        if truncated or nbytes == 0:
            self._dead[server] = True
