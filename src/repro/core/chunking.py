"""MDTP adaptive chunk-size allocation (paper §IV-B, Algorithm 1).

This module is the single source of truth for the bin-packing allocation
rule.  It is shared by:

* the discrete-event simulator (``repro.core.simulator`` + policy classes),
* the real asyncio transfer runtime (``repro.transfer.client``),
* the vectorized JAX implementation (``repro.core.jax_alloc``) which is
  cross-checked against this one in tests.

The rule, faithful to the paper
-------------------------------
Each server is a *bin*.  The bin threshold (shared deadline) is the fastest
server's download time for the "large" chunk::

    T = L / th_max

and server *i*'s next chunk is sized to fill its bin exactly by that
deadline::

    C_i = round(T * th_i)

The paper's prose (§IV-B) sizes *every* server proportionally, with the
fastest server requesting exactly ``L``.  Algorithm 1's pseudocode instead
gives every "fast" server (throughput >= geometric mean) the large chunk
``L``.  Both semantics are implemented; ``mode="proportional"`` (prose,
consistent with Fig. 5c's equal per-replica request counts) is the default
and ``mode="fast_get_large"`` matches the pseudocode.

The geometric mean is kept as the paper's fast/slow classifier.  Note that
``max(th) >= GM`` always holds, so in ``proportional`` mode the GM filter
cannot change the chosen deadline; it only matters in ``fast_get_large``
mode.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Sequence

__all__ = [
    "DEFAULT_MIN_CHUNK",
    "ChunkParams",
    "default_chunk_params",
    "geometric_mean",
    "fast_server_mask",
    "next_chunk_size",
    "round_chunk_sizes",
]

MB = 1024 * 1024

#: Paper Table II (bold entries): (initial C, large L) per file-size regime.
_SMALL_FILE_LIMIT = 8 * 1024 * MB  # <= 8 GB
_SMALL_PARAMS = (4 * MB, 40 * MB)
_LARGE_PARAMS = (16 * MB, 160 * MB)

#: floor for adaptive sizes, shared by ChunkParams and the autotuner's
#: sweep geometry so the scored and adopted min_chunk cannot diverge.
DEFAULT_MIN_CHUNK = 64 * 1024


@dataclass(frozen=True)
class ChunkParams:
    """Static parameters of the MDTP allocator.

    Attributes:
      initial_chunk: size ``C`` of the uniform probing chunk every server
        downloads first (Algorithm 1 line 1).
      large_chunk: size ``L`` requested by the fastest server each round
        (Algorithm 1 line 2).
      min_chunk: floor for adaptive sizes so a glacial server still makes
        progress and ``round()`` can never emit a zero-byte request.
      mode: ``"proportional"`` (paper prose, default),
        ``"fast_get_large"`` (paper pseudocode), or ``"static"`` (every
        probed server gets exactly ``large_chunk`` — the fixed-chunk
        baseline, used to fold static chunking into the adaptive code
        path via ``C == L == chunk``).
    """

    initial_chunk: int = _SMALL_PARAMS[0]
    large_chunk: int = _SMALL_PARAMS[1]
    min_chunk: int = DEFAULT_MIN_CHUNK
    mode: str = "proportional"

    def __post_init__(self) -> None:
        if self.initial_chunk <= 0 or self.large_chunk <= 0:
            raise ValueError("chunk sizes must be positive")
        if self.min_chunk <= 0:
            raise ValueError("min_chunk must be positive")
        if self.mode not in ("proportional", "fast_get_large", "static"):
            raise ValueError(f"unknown mode: {self.mode!r}")

    def with_mode(self, mode: str) -> "ChunkParams":
        return replace(self, mode=mode)

    def as_triple(self) -> tuple[int, int, int]:
        """The ``(C, L, min_chunk)`` geometry, mode stripped — the data
        half of the allocator, as consumed by the traced JAX path."""
        return (self.initial_chunk, self.large_chunk, self.min_chunk)


def default_chunk_params(file_size: int, mode: str = "proportional") -> ChunkParams:
    """Paper Table II defaults: 4/40 MB up to 8 GB, 16/160 MB above."""
    c, l = _SMALL_PARAMS if file_size <= _SMALL_FILE_LIMIT else _LARGE_PARAMS
    return ChunkParams(initial_chunk=c, large_chunk=l, mode=mode)


def geometric_mean(throughputs: Sequence[float]) -> float:
    """Geometric mean over *positive* observations (paper's classifier).

    Servers with no observation yet (``<= 0``) are excluded; an empty set
    yields ``0.0`` so every server classifies as "fast" until probed.
    """
    logs = [math.log(t) for t in throughputs if t > 0.0]
    if not logs:
        return 0.0
    return math.exp(math.fsum(logs) / len(logs))


def fast_server_mask(throughputs: Sequence[float]) -> list[bool]:
    """Paper: a server is *fast* iff its throughput >= the geometric mean.

    A whisker of relative tolerance absorbs exp(log(x)) round-trip error so
    the maximum-throughput server always classifies fast (GM <= max holds
    mathematically but not always bit-wise).
    """
    gm = geometric_mean(throughputs) * (1.0 - 1e-12)
    return [t >= gm and t > 0.0 for t in throughputs]


def next_chunk_size(
    server: int,
    throughputs: Sequence[float],
    params: ChunkParams,
    remaining: int,
) -> int:
    """Size of the next byte-range request for ``server``.

    Implements the per-iteration body of Algorithm 1 (lines 11-31) for one
    server, given the latest throughput estimates of *all* servers.

    Args:
      server: index of the server that just became free.
      throughputs: latest estimate per server; ``<= 0`` means "not yet
        observed" (that server is still on its initial probing chunk).
      params: allocator constants.
      remaining: unassigned bytes left in the file (global cursor pool).

    Returns:
      Request size in bytes, clamped to ``remaining`` (0 when done).
    """
    if remaining <= 0:
        return 0
    th_i = throughputs[server]
    if th_i <= 0.0:
        # Not yet probed: uniform initial chunk (Algorithm 1 lines 5-10).
        return min(params.initial_chunk, remaining)

    if params.mode == "static":
        # Fixed-chunk baseline: throughput is ignored, every request is L.
        return min(max(params.large_chunk, params.min_chunk), remaining)
    th_max = max(t for t in throughputs if t > 0.0)
    if params.mode == "fast_get_large":
        gm = geometric_mean(throughputs)
        if th_i >= gm:
            return min(params.large_chunk, remaining)
        size = int(round(params.large_chunk * th_i / th_max))
    else:  # proportional (prose semantics)
        if th_i >= th_max:
            size = params.large_chunk
        else:
            # C_i = T_fastest * th_i, T_fastest = L / th_max.
            size = int(round(params.large_chunk * th_i / th_max))
    size = max(size, params.min_chunk)
    return min(size, remaining)


def round_chunk_sizes(
    throughputs: Sequence[float],
    params: ChunkParams,
    remaining: int,
) -> list[int]:
    """Vector form: the chunk each server would get if it asked right now.

    Used by the planners (checkpoint restore splits a whole object across
    replicas in one shot) and mirrored exactly by ``jax_alloc.chunk_sizes``.
    """
    return [
        next_chunk_size(i, throughputs, params, remaining)
        for i in range(len(throughputs))
    ]
