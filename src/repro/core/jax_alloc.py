"""Vectorized JAX implementation of the MDTP bin-packing allocator.

Mirrors ``repro.core.chunking`` exactly (cross-checked in tests) but is
jit/vmap-friendly: a single fused computation over the throughput vector,
usable inside ``lax.while_loop`` (the on-device transfer simulator) and
``vmap`` (Monte-Carlo sweeps / the chunk-size autotuner).

All sizes are float32 bytes here; the integer clamping semantics of the
Python allocator are reproduced with ``jnp.round``.  float32 is exact to
~16 bytes at the 160 MB chunk scale, far below the allocator's 64 KiB
``min_chunk`` — the equivalence test asserts this bound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .chunking import ChunkParams

__all__ = ["chunk_sizes", "geometric_mean"]


def geometric_mean(throughputs: jax.Array) -> jax.Array:
    """GM over positive entries; 0.0 if none (matches chunking.py)."""
    mask = throughputs > 0.0
    n = jnp.sum(mask)
    logs = jnp.where(mask, jnp.log(jnp.where(mask, throughputs, 1.0)), 0.0)
    gm = jnp.exp(jnp.sum(logs) / jnp.maximum(n, 1))
    return jnp.where(n > 0, gm, 0.0)


def chunk_sizes(
    throughputs: jax.Array,
    remaining: jax.Array,
    params: ChunkParams,
) -> jax.Array:
    """Vector of next-request sizes, one per server.

    Equivalent to ``chunking.round_chunk_sizes`` evaluated for every server
    against the same ``remaining`` (i.e. "what would each server get if it
    asked right now").

    Args:
      throughputs: ``[N]`` float32, bytes/s; ``<= 0`` = not yet probed.
      remaining: scalar, unassigned bytes.
      params: allocator constants (static — baked into the jaxpr).

    Returns:
      ``[N]`` float32 sizes, clamped to ``remaining``; 0 when done.
    """
    th = throughputs.astype(jnp.float32)
    remaining = jnp.asarray(remaining, jnp.float32)
    probed = th > 0.0
    any_probed = jnp.any(probed)
    th_max = jnp.max(jnp.where(probed, th, -jnp.inf))
    th_max = jnp.where(any_probed, th_max, 1.0)  # avoid -inf division

    C = jnp.float32(params.initial_chunk)
    L = jnp.float32(params.large_chunk)

    proportional = jnp.round(L * th / th_max)
    if params.mode == "fast_get_large":
        gm = geometric_mean(th)
        adaptive = jnp.where(th >= gm, L, proportional)
    else:
        adaptive = jnp.where(th >= th_max, L, proportional)

    size = jnp.where(probed, adaptive, C)
    size = jnp.maximum(size, jnp.float32(params.min_chunk))
    size = jnp.minimum(size, remaining)
    return jnp.where(remaining > 0.0, size, 0.0)
