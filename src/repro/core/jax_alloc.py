"""Vectorized JAX implementation of the MDTP bin-packing allocator.

Mirrors ``repro.core.chunking`` exactly (cross-checked in tests) but is
jit/vmap-friendly: a single fused computation over the throughput vector,
usable inside ``lax.while_loop`` (the on-device transfer simulator) and
``vmap`` (Monte-Carlo sweeps / the chunk-size autotuner).

Chunk geometry is **data, not code**: the ``(C, L, min_chunk)`` triple is
carried as a :class:`ChunkArrays` pytree of traced scalars, so a whole
(C, L) grid can ride a ``vmap`` axis through one compiled simulator —
the autotuner evaluates its entire sweep in a single device call instead
of re-tracing per grid point.  Only ``mode`` (a branch structure) stays
static.

All sizes are float32 bytes here; the integer clamping semantics of the
Python allocator are reproduced with ``jnp.round``.  float32 is exact to
~16 bytes at the 160 MB chunk scale, far below the allocator's 64 KiB
``min_chunk`` — the equivalence test asserts this bound.
"""

from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp

from .chunking import ChunkParams

__all__ = [
    "ChunkArrays",
    "as_chunk_arrays",
    "chunk_sizes",
    "round_allocate",
    "geometric_mean",
]


class ChunkArrays(NamedTuple):
    """Traced ``(C, L, min_chunk)`` triple of the MDTP allocator.

    A pytree of float32 scalars (or batched arrays under ``vmap``), so the
    chunk geometry flows through ``jax.jit`` as a runtime input — sweeping
    a grid of candidate sizes costs one compile, not one per point.
    """

    initial_chunk: jax.Array
    large_chunk: jax.Array
    min_chunk: jax.Array

    @classmethod
    def from_params(cls, params: ChunkParams) -> "ChunkArrays":
        return cls(
            initial_chunk=jnp.float32(params.initial_chunk),
            large_chunk=jnp.float32(params.large_chunk),
            min_chunk=jnp.float32(params.min_chunk),
        )


ChunkParamsLike = Union[ChunkParams, ChunkArrays, tuple]


def as_chunk_arrays(
    params: ChunkParamsLike, mode: str | None = None
) -> tuple[ChunkArrays, str]:
    """Normalize any chunk-parameter form to ``(ChunkArrays, mode)``.

    Accepts a :class:`~repro.core.chunking.ChunkParams` (mode read from it
    unless overridden), a :class:`ChunkArrays`, or a bare ``(C, L, min)``
    triple of scalars/arrays.
    """
    if isinstance(params, ChunkParams):
        return ChunkArrays.from_params(params), (mode or params.mode)
    if isinstance(params, ChunkArrays):
        arrays = params
    else:
        c, l, m = params
        arrays = ChunkArrays(
            jnp.asarray(c, jnp.float32),
            jnp.asarray(l, jnp.float32),
            jnp.asarray(m, jnp.float32),
        )
    return arrays, (mode or "proportional")


def geometric_mean(throughputs: jax.Array) -> jax.Array:
    """GM over positive entries; 0.0 if none (matches chunking.py)."""
    mask = throughputs > 0.0
    n = jnp.sum(mask)
    logs = jnp.where(mask, jnp.log(jnp.where(mask, throughputs, 1.0)), 0.0)
    gm = jnp.exp(jnp.sum(logs) / jnp.maximum(n, 1))
    return jnp.where(n > 0, gm, 0.0)


def chunk_sizes(
    throughputs: jax.Array,
    remaining: jax.Array,
    params: ChunkParamsLike,
    mode: str | None = None,
    exact: bool = True,
) -> jax.Array:
    """Vector of next-request sizes, one per server.

    Equivalent to ``chunking.round_chunk_sizes`` evaluated for every server
    against the same ``remaining`` (i.e. "what would each server get if it
    asked right now").

    Args:
      throughputs: ``[N]`` float32, bytes/s; ``<= 0`` = not yet probed.
      remaining: scalar, unassigned bytes.
      params: allocator constants — a static ``ChunkParams`` or a traced
        ``ChunkArrays`` / ``(C, L, min)`` triple (vmappable).
      mode: static branch selector; defaults to ``params.mode`` for
        ``ChunkParams`` and ``"proportional"`` otherwise.  ``"static"``
        gives every probed server exactly ``L`` (fixed-chunk baseline).
      exact: when False, skip the integer ``jnp.round`` on proportional
        sizes — a continuous relaxation whose output is differentiable in
        ``(C, L)`` (``round`` has zero gradient a.e.), used by the
        gradient-based tuner.  The relaxation error is < 1 byte per
        request.

    Returns:
      ``[N]`` float32 sizes, clamped to ``remaining``; 0 when done.
    """
    arrays, mode = as_chunk_arrays(params, mode)
    th = throughputs.astype(jnp.float32)
    remaining = jnp.asarray(remaining, jnp.float32)
    probed = th > 0.0
    any_probed = jnp.any(probed)
    th_max = jnp.max(jnp.where(probed, th, -jnp.inf))
    th_max = jnp.where(any_probed, th_max, 1.0)  # avoid -inf division

    C = arrays.initial_chunk
    L = arrays.large_chunk

    proportional = L * th / th_max
    if exact:
        proportional = jnp.round(proportional)
    if mode == "fast_get_large":
        gm = geometric_mean(th)
        adaptive = jnp.where(th >= gm, L, proportional)
    elif mode == "static":
        adaptive = jnp.broadcast_to(L, th.shape)
    else:
        adaptive = jnp.where(th >= th_max, L, proportional)

    size = jnp.where(probed, adaptive, C)
    size = jnp.maximum(size, arrays.min_chunk)
    size = jnp.minimum(size, remaining)
    return jnp.where(remaining > 0.0, size, 0.0)


def round_allocate(
    throughputs: jax.Array,
    remaining: jax.Array,
    order_key: jax.Array,
    params: ChunkParamsLike,
    mode: str | None = None,
    exact: bool = True,
    eligible: jax.Array | None = None,
    draw_counts: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Allocate one full round for all N servers in a single vector op.

    The event-driven core draws one request per loop iteration, updating
    the shared cursor between draws.  A round-synchronous round makes the
    same N draws, so they can be fused: compute every server's candidate
    size against the round-start ``remaining`` (:func:`chunk_sizes`), then
    replay the sequential budget clamp as an exclusive prefix sum in *ask
    order* (``order_key`` ascending, stable ties by index — the order the
    event core would have served the requests).  Because the adaptive size
    formula depends on ``remaining`` only through the final clamp,
    ``min(size_i, remaining - sum(earlier grants))`` is byte-identical to
    the event core's per-draw recomputation.

    Args:
      throughputs: ``[N]`` observed bytes/s (``<= 0`` = unprobed).
      remaining: scalar unassigned bytes at round start.
      order_key: ``[N]`` ask-time proxy (per-server clock); servers are
        served in ascending order, so the endgame's last bytes go to the
        earliest-asking server exactly as in the event core.
      params / mode / exact: forwarded to :func:`chunk_sizes`.
      eligible: optional ``[N]`` bool mask; ineligible servers draw
        nothing this round (retired connections).
      draw_counts: optional ``[N, N]`` float matrix — ``counts[i, j]`` =
        how many draws of server j's current size land before server i's
        ask.  Defaults to the 0/1 ask-order precedence above; the round
        simulator passes a time-aware count (a lagging server sees every
        chunk its peers complete during its lag debited from the budget,
        which is how the event core starves stragglers).

    Returns:
      ``(granted, total)`` — ``[N]`` per-server grants and their scalar
      sum (the round's single cursor update).

    The budget debit is an ``[N, N]`` masked sum rather than sort →
    cumsum → scatter: at simulator N (4–16 servers) the N² form is a
    handful of fused vector ops, while XLA sort/gather/scatter in the hot
    loop body cost ~2–3× the whole step.
    """
    sizes = chunk_sizes(throughputs, remaining, params, mode=mode, exact=exact)
    if eligible is not None:
        sizes = jnp.where(eligible, sizes, 0.0)
    if draw_counts is None:
        key = jnp.asarray(order_key)
        idx = jnp.arange(sizes.shape[0])
        # j is served before i iff it asks earlier (stable ties by index)
        draw_counts = ((key[None, :] < key[:, None]) | (
            (key[None, :] == key[:, None]) & (idx[None, :] < idx[:, None]))
        ).astype(jnp.float32)
    before = jnp.sum(draw_counts * sizes[None, :], axis=1)
    avail = jnp.maximum(jnp.asarray(remaining, jnp.float32) - before, 0.0)
    granted = jnp.minimum(sizes, avail)
    # a server whose budget was fully consumed by peer draws during its
    # lag can never draw again (remaining only shrinks): its grant is 0
    # and the simulator retires it.
    return granted, jnp.sum(granted)
