"""Discrete-event network simulator for multi-source transfers.

The paper evaluates MDTP on the FABRIC testbed (6 replicas, 1 client).  This
container has no WAN, so protocol experiments run on this simulator instead:
servers are modeled with per-request latency, piecewise-constant bandwidth
profiles (for the Fig. 4 throttling experiment), lognormal per-chunk jitter,
permanent failures, and on/off availability (for BitTorrent seeder flapping,
Fig. 2c).  The event loop is policy-agnostic: MDTP, static chunking, the
Aria2 model and the BitTorrent model all plug in through the same
``Policy`` interface, so comparisons are apples-to-apples.

Design notes
------------
* A *connection* is the schedulable agent (MDTP/static: one per server;
  Aria2: ``max_connections`` roaming connections; BitTorrent: one per
  seeder).  When a connection becomes free the policy is asked for its next
  action: request a byte range from some server, sleep, or finish.
* Byte ranges are handed out by ``TransferState`` from a global cursor plus
  a reclaim pool.  If a server dies or flaps mid-chunk, the undelivered tail
  of its range goes back to the pool and is re-issued later — each byte is
  *delivered* exactly once, and (for MDTP/static) *requested* exactly once
  unless a failure forces a re-issue.  This is the fault-tolerance behavior
  the framework's checkpoint-restore path relies on.
* Time is float seconds.  Determinism: all randomness flows from one
  ``numpy.random.Generator`` seeded by the caller.
* This simulator is the byte-exact REFERENCE the on-device JAX engines
  are cross-checked against (``repro.core.jax_sim``: event core to float
  tolerance, round-synchronous core within 2% on the Fig. 2/3 suite).
"""

from __future__ import annotations

import bisect
import heapq
import math
from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

__all__ = [
    "ServerSpec",
    "Request",
    "Wait",
    "Policy",
    "ChunkRecord",
    "TransferState",
    "SimResult",
    "simulate",
]

_INF = float("inf")
#: MTU-sized payload used to convert bytes to a packet count (Fig. 5b).
_PACKET_PAYLOAD = 1448


@dataclass(frozen=True)
class ServerSpec:
    """Static description of one replica server.

    Attributes:
      name: label used in results.
      bandwidth: steady-state bytes/second at t=0.
      rtt: request round-trip overhead in seconds (one idle RTT between a
        request being issued on a persistent session and first byte).
      connect_latency: one-time session-establishment latency.
      profile: piecewise bandwidth changes, ``((t, new_bw), ...)`` sorted by
        time — models the Fig. 4 throttling experiment.
      jitter: sigma of a mean-1 lognormal factor applied per chunk.
      fail_at: server dies permanently at this time (fault-tolerance tests).
      avail_up / avail_down: mean up/down durations of an on/off Markov
        availability process (BitTorrent seeders, Fig. 2c).  ``avail_up <=
        0`` means always up.
      loss_rate: per-chunk probability the connection is cut mid-body: a
        uniform fraction of the chunk arrives (taking the time those bytes
        take), the tail is reclaimed and re-issued.  Models flaky paths /
        resets without taking the whole server down.
      corruption_rate: per-chunk probability the body arrives complete but
        fails integrity verification — full transfer time is paid, zero
        bytes are credited, and the whole range is re-issued.  Mirrors the
        real client's CRC verify-and-re-pool path.  Both fault draws
        consume RNG only when their rate is nonzero, so fault-free
        scenarios replay the exact seeded event streams of earlier builds.
      degrade_at / degrade_factor: gray failure — at ``degrade_at`` the
        server silently degrades to ``degrade_factor`` of its (possibly
        profiled) bandwidth and stays there.  Unlike ``fail_at`` the
        connection never breaks: the client sees a healthy but slow
        mirror, the case hedged endgame + probation exist for (the
        paper's "bandwidth decrease to the fastest server" experiment).
    """

    name: str
    bandwidth: float
    rtt: float = 0.03
    connect_latency: float = 0.0
    profile: tuple[tuple[float, float], ...] = ()
    jitter: float = 0.0
    fail_at: float = _INF
    avail_up: float = 0.0
    avail_down: float = 0.0
    loss_rate: float = 0.0
    corruption_rate: float = 0.0
    degrade_at: float = _INF
    degrade_factor: float = 1.0

    def bandwidth_at(self, t: float) -> float:
        bw = self.bandwidth
        for start, new_bw in self.profile:
            if t >= start:
                bw = new_bw
            else:
                break
        if t >= self.degrade_at:
            bw *= self.degrade_factor
        return bw

    def rate_boundaries(self) -> list[float]:
        bounds = [start for start, _ in self.profile]
        if self.degrade_at < _INF and self.degrade_factor != 1.0:
            bisect.insort(bounds, self.degrade_at)
        return bounds


@dataclass(frozen=True)
class Request:
    """Policy action: fetch ``size`` bytes from ``server``."""

    server: int
    size: int


@dataclass(frozen=True)
class Wait:
    """Policy action: go idle and ask again at time ``until``."""

    until: float


Action = Union[Request, Wait, None]


class Policy:
    """Scheduling policy driving one multi-source transfer."""

    #: human-readable protocol name for results tables.
    name: str = "policy"

    def n_connections(self, n_servers: int) -> int:
        return n_servers

    def reset(self, n_servers: int, file_size: int) -> None:
        raise NotImplementedError

    def next_action(self, state: "TransferState", conn: int, now: float) -> Action:
        """Called when connection ``conn`` is free.  Must not allocate ranges
        itself — return a ``Request`` and the event loop allocates."""
        raise NotImplementedError

    def on_complete(
        self, state: "TransferState", conn: int, server: int,
        nbytes: int, elapsed: float, now: float, truncated: bool = False,
    ) -> None:
        """Observation hook after a chunk finishes.

        ``truncated=True`` (or ``nbytes == 0``) signals the server went down
        mid-chunk — the client sees a broken connection.  The undelivered
        tail has already been reclaimed into the range pool.
        """


@dataclass
class ChunkRecord:
    conn: int
    server: int
    start: int
    length: int          # bytes actually delivered
    requested: int       # bytes requested (== length unless truncated)
    t_request: float
    t_complete: float
    truncated: bool = False

    @property
    def elapsed(self) -> float:
        return self.t_complete - self.t_request


class TransferState:
    """Client-side byte-range bookkeeping shared with the policies."""

    def __init__(self, file_size: int, n_servers: int):
        self.file_size = int(file_size)
        self.n_servers = n_servers
        self._cursor = 0
        self._pool: list[tuple[int, int]] = []  # reclaimed (start, length)
        self.bytes_per_server = [0] * n_servers
        self.requests_per_server = [0] * n_servers
        self.chunks: list[ChunkRecord] = []

    # -- range allocation ---------------------------------------------------
    def unassigned_bytes(self) -> int:
        return (self.file_size - self._cursor) + sum(l for _, l in self._pool)

    def delivered_bytes(self) -> int:
        return sum(self.bytes_per_server)

    def allocate(self, nbytes: int) -> tuple[int, int]:
        """Hand out one contiguous range of at most ``nbytes``.

        Reclaimed ranges are drained before fresh cursor bytes so failed
        chunks are retried promptly.  Returns ``(start, length)``;
        ``length == 0`` when nothing is left.

        The pool is a min-heap keyed on range start (ranges never overlap),
        so drain/return are O(log P) instead of the O(P log P) of a sorted
        list rebuilt on every reclaim.
        """
        if nbytes <= 0:
            return (self._cursor, 0)
        if self._pool:
            start, length = self._pool[0]
            take = min(length, nbytes)
            if take == length:
                heapq.heappop(self._pool)
            else:
                # shrunk head keeps its heap position (start only grows)
                heapq.heapreplace(self._pool, (start + take, length - take))
            return (start, take)
        take = min(nbytes, self.file_size - self._cursor)
        start = self._cursor
        self._cursor += take
        return (start, take)

    def reclaim(self, start: int, length: int) -> None:
        """Return an undelivered sub-range to the pool (failure path)."""
        if length > 0:
            heapq.heappush(self._pool, (start, length))

    # -- results ------------------------------------------------------------
    def record(self, rec: ChunkRecord) -> None:
        self.chunks.append(rec)
        if rec.length > 0:
            self.bytes_per_server[rec.server] += rec.length
        self.requests_per_server[rec.server] += 1


@dataclass
class SimResult:
    policy: str
    total_time: float
    file_size: int
    chunks: list[ChunkRecord]
    bytes_per_server: list[int]
    requests_per_server: list[int]
    server_names: list[str]

    @property
    def n_servers(self) -> int:
        return len(self.bytes_per_server)

    @property
    def throughput(self) -> float:
        return self.file_size / self.total_time if self.total_time > 0 else 0.0

    def utilization(self, min_frac: float = 0.0) -> float:
        """Fraction of replicas that delivered data (paper Fig. 5a).

        ``min_frac`` is a de-minimis cut: a replica counts as *used* only if
        it delivered more than ``min_frac * file_size``.  The paper's Aria2
        measurement (83%: 5 of 6) reflects steady-state participation; our
        Aria2 model probes every mirror once before parking the slowest, so
        benchmarks apply ``min_frac=0.01`` and report it.
        """
        cut = min_frac * self.file_size
        used = sum(1 for b in self.bytes_per_server if b > cut)
        return used / self.n_servers

    @property
    def packets_per_server(self) -> list[int]:
        """MTU-payload packet counts per replica (paper Fig. 5b proxy)."""
        return [int(math.ceil(b / _PACKET_PAYLOAD)) for b in self.bytes_per_server]

    def request_sizes(self, server: int) -> list[int]:
        return [c.requested for c in self.chunks if c.server == server and c.length > 0]

    def completion_spread(self) -> float:
        """Gap between the first and last server to finish its final chunk.

        The paper's bin-packing goal is that every round (and in particular
        the last one) completes "around the same time" — this is the
        straggler metric for that claim.
        """
        last = {}
        for c in self.chunks:
            if c.length > 0:
                last[c.server] = max(last.get(c.server, 0.0), c.t_complete)
        if not last:
            return 0.0
        return max(last.values()) - min(last.values())

    def check_integrity(self) -> None:
        """Every byte delivered exactly once, covering [0, file_size)."""
        ivals = sorted(
            (c.start, c.start + c.length) for c in self.chunks if c.length > 0
        )
        pos = 0
        for s, e in ivals:
            if s != pos:
                raise AssertionError(f"gap/overlap at byte {pos}: next range starts {s}")
            pos = e
        if pos != self.file_size:
            raise AssertionError(f"covered {pos} of {self.file_size} bytes")


class _ServerRuntime:
    """Per-server dynamic state: availability intervals and failure.

    Downtime intervals are merged into a disjoint sorted list and the
    bandwidth profile flattened into parallel arrays at construction, so
    the per-segment lookups inside ``transfer`` are ``bisect`` O(log K)
    instead of linear scans — these run once per rate/availability segment
    of every chunk, the hottest loop of the Python simulator.
    """

    def __init__(self, spec: ServerSpec, rng: np.random.Generator, horizon: float):
        self.spec = spec
        down: list[tuple[float, float]] = []
        if spec.fail_at < _INF:
            down.append((spec.fail_at, _INF))
        if spec.avail_up > 0.0 and spec.avail_down > 0.0:
            t = float(rng.exponential(spec.avail_up))
            while t < horizon:
                d = float(rng.exponential(spec.avail_down))
                down.append((t, t + d))
                t += d + float(rng.exponential(spec.avail_up))
        down.sort()
        # Merge overlaps (fail_at can overlap a flap) — disjoint intervals
        # make the bisect lookups exact.
        merged: list[tuple[float, float]] = []
        for s, e in down:
            if merged and s <= merged[-1][1]:
                prev_s, prev_e = merged[-1]
                merged[-1] = (prev_s, max(prev_e, e))
            else:
                merged.append((s, e))
        self.down = merged
        self._down_starts = [s for s, _ in merged]
        self._down_ends = [e for _, e in merged]
        #: rate at t = _rates[bisect_right(_rate_times, t)]
        times = [start for start, _ in spec.profile]
        rates = [spec.bandwidth] + [bw for _, bw in spec.profile]
        if spec.degrade_at < _INF and spec.degrade_factor != 1.0:
            # fold gray degradation into the flattened rate function:
            # every segment at or after degrade_at is scaled down
            i = bisect.bisect_right(times, spec.degrade_at)
            times = times[:i] + [spec.degrade_at] + times[i:]
            rates = (rates[:i + 1]
                     + [r * spec.degrade_factor for r in rates[i:]])
        self._rate_times = times
        self._rates = rates

    def is_up(self, t: float) -> bool:
        return self.next_downtime_covering(t) is None

    def next_downtime_covering(self, t: float) -> Optional[tuple[float, float]]:
        i = bisect.bisect_right(self._down_starts, t) - 1
        if i >= 0 and self._down_ends[i] > t:
            return self.down[i]
        return None

    def next_down_after(self, t: float) -> float:
        i = bisect.bisect_right(self._down_ends, t)
        if i < len(self.down):
            return max(self._down_starts[i], t)
        return _INF

    def next_up_time(self, t: float) -> float:
        cov = self.next_downtime_covering(t)
        return cov[1] if cov else t

    def bandwidth_at(self, t: float) -> float:
        return self._rates[bisect.bisect_right(self._rate_times, t)]

    def next_rate_boundary(self, t: float) -> float:
        j = bisect.bisect_right(self._rate_times, t)
        return self._rate_times[j] if j < len(self._rate_times) else _INF

    def transfer(
        self, t0: float, nbytes: int, rng: np.random.Generator, first_use: bool
    ) -> tuple[float, int]:
        """Simulate fetching ``nbytes`` starting with a request at ``t0``.

        Returns ``(t_finish, delivered)``.  ``delivered < nbytes`` iff the
        server went down mid-transfer, the connection was cut by an
        injected loss, or the body failed verification (``delivered == 0``
        with full time paid); the caller reclaims the undelivered tail.
        """
        spec = self.spec
        # Fault predraws — each guarded by its own rate so fault-free
        # specs consume no extra RNG and replay historical streams.
        lost_after = None
        if spec.loss_rate > 0.0 and rng.random() < spec.loss_rate:
            lost_after = int(rng.random() * nbytes)  # bytes that make it
        corrupt = False
        if spec.corruption_rate > 0.0:
            corrupt = lost_after is None and rng.random() < spec.corruption_rate
        scale = 1.0
        if spec.jitter > 0.0:
            # mean-1 lognormal so calibration is unbiased.
            scale = float(
                rng.lognormal(mean=-0.5 * spec.jitter**2, sigma=spec.jitter)
            )
        t = t0 + spec.rtt + (spec.connect_latency if first_use else 0.0)
        if lost_after is not None:
            # Walk the rate/availability segments only up to the cut point:
            # the client sees a clean partial body then a dead socket.
            t_cut, got = self._walk(t, lost_after, scale)
            return (t_cut, got)
        t_fin, delivered = self._walk(t, nbytes, scale)
        if corrupt and delivered == nbytes:
            # Full time burned, nothing trustworthy landed: the client's
            # checksum rejects the body and re-pools the whole range.
            return (t_fin, 0)
        return (t_fin, delivered)

    def _walk(
        self, t: float, nbytes: int, scale: float
    ) -> tuple[float, int]:
        """Advance through rate/availability segments delivering up to
        ``nbytes`` from time ``t`` (first-byte time, post-RTT)."""
        remaining = float(nbytes)
        while remaining > 0.0:
            down = self.next_downtime_covering(t)
            if down is not None:
                return (t, nbytes - int(round(remaining)))
            rate = self.bandwidth_at(t) * scale
            if rate <= 0.0:
                return (t, nbytes - int(round(remaining)))
            # Next moment the rate function or availability changes.
            horizon = min(self.next_rate_boundary(t), self.next_down_after(t))
            dt_need = remaining / rate
            if t + dt_need <= horizon:
                return (t + dt_need, nbytes)
            delivered_now = rate * (horizon - t)
            remaining -= delivered_now
            t = horizon
        return (t, nbytes)


def simulate(
    policy: Policy,
    servers: Sequence[ServerSpec],
    file_size: int,
    seed: int = 0,
    horizon: float = 36_000.0,
) -> SimResult:
    """Run one transfer to completion under ``policy``.

    Raises ``RuntimeError`` if the transfer cannot complete (e.g. every
    server permanently failed with bytes still owed).
    """
    rng = np.random.default_rng(seed)
    n = len(servers)
    runtimes = [_ServerRuntime(s, rng, horizon) for s in servers]
    state = TransferState(file_size, n)
    policy.reset(n, file_size)
    n_conns = policy.n_connections(n)

    # Event heap: (time, tiebreak, kind, conn, payload)
    events: list[tuple] = []
    seq = 0
    first_use = [True] * n
    outstanding = 0
    idle_conns: set[int] = set()

    def dispatch(conn: int, now: float) -> None:
        nonlocal seq, outstanding
        action = policy.next_action(state, conn, now)
        if action is None:
            idle_conns.add(conn)
            return
        if isinstance(action, Wait):
            until = max(action.until, now + 1e-9)
            heapq.heappush(events, (until, seq, "wake", conn, None))
            seq += 1
            outstanding += 1
            return
        assert isinstance(action, Request)
        start, length = state.allocate(action.size)
        if length == 0:
            idle_conns.add(conn)
            return
        srv = runtimes[action.server]
        fin, delivered = srv.transfer(now, length, rng, first_use[action.server])
        first_use[action.server] = False
        heapq.heappush(
            events,
            (fin, seq, "complete", conn,
             (action.server, start, length, delivered, now)),
        )
        seq += 1
        outstanding += 1

    t_now = 0.0
    for conn in range(n_conns):
        dispatch(conn, 0.0)

    t_last_byte = 0.0
    while events:
        t_now, _, kind, conn, payload = heapq.heappop(events)
        outstanding -= 1
        if t_now > horizon:
            raise RuntimeError(
                f"{policy.name}: exceeded horizon {horizon}s "
                f"({state.delivered_bytes()}/{file_size} bytes)"
            )
        if kind == "wake":
            dispatch(conn, t_now)
            continue
        server, start, length, delivered, t_req = payload
        truncated = delivered < length
        if truncated:
            state.reclaim(start + delivered, length - delivered)
        rec = ChunkRecord(
            conn=conn, server=server, start=start, length=delivered,
            requested=length, t_request=t_req, t_complete=t_now,
            truncated=truncated,
        )
        state.record(rec)
        if delivered > 0:
            t_last_byte = max(t_last_byte, t_now)
        policy.on_complete(
            state, conn, server, delivered, t_now - t_req, t_now,
            truncated=truncated,
        )
        # A completion may unblock idle connections (e.g. a reclaimed range
        # appeared, or endgame work-stealing) — re-poll them.
        woken = list(idle_conns)
        idle_conns.clear()
        dispatch(conn, t_now)
        for c in woken:
            if c != conn:
                dispatch(c, t_now)

    if state.delivered_bytes() != file_size:
        raise RuntimeError(
            f"{policy.name}: transfer stalled at "
            f"{state.delivered_bytes()}/{file_size} bytes (all connections idle)"
        )

    return SimResult(
        policy=policy.name,
        total_time=t_last_byte,
        file_size=file_size,
        chunks=state.chunks,
        bytes_per_server=state.bytes_per_server,
        requests_per_server=state.requests_per_server,
        server_names=[s.name for s in servers],
    )
