"""Calibrated simulation scenarios for reproducing the paper's experiments.

The paper's FABRIC testbed: one client, six same-spec geographically
distributed servers behind 10 Gbps NICs, Apache over HTTP.  Measured
end-to-end application throughput was far below NIC line rate (Python
client; WAN paths): MDTP moved 64 GB in ~446 s => ~145 MB/s aggregate.

Two presets capture the paper's (mutually tension-y) observations:

* ``paper_baseline`` — one distinctly fast path plus five slower ones,
  aggregate ~145 MB/s.  Reproduces Fig. 2 absolute times, the Fig. 4
  throttling deltas (throttling the fastest to 500 Mbps = 62.5 MB/s must
  actually bite, so the fastest exceeds that), the Fig. 5a/5b utilization
  and packet-skew behavior of Aria2.
* ``paper_balanced`` — six near-equal servers (same aggregate).  Reproduces
  Fig. 5c: with near-homogeneous capacity MDTP issues an *equal number* of
  requests per replica (the paper measured exactly 37 for a 32 GB file),
  because every round completes in lockstep.

Calibration notes live in EXPERIMENTS.md §Reproduction.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from .simulator import ServerSpec

__all__ = [
    "MBPS",
    "GB",
    "paper_baseline",
    "paper_balanced",
    "bittorrent_seeders",
    "with_added_latency",
    "with_throttled_fastest",
    "PAPER_FILE_SIZES",
    "shared_bottleneck",
    "with_fair_share",
    "contention_matrix",
    "ContentionTrace",
    "contention_traces",
    "with_faults",
    "FaultTrace",
    "fault_traces",
    "with_gray_degradation",
    "FlashCrowdTrace",
    "flash_crowd_traces",
    "SwarmTrace",
    "swarm_fleet",
    "swarm_axes",
    "swarm_traces",
    "ShardTrace",
    "shard_fleet",
    "shard_traces",
]

MBPS = 1024 * 1024  # we quote server rates in MiB/s
GB = 1024**3

#: File sizes evaluated in the paper (§VI-A).
PAPER_FILE_SIZES = tuple(s * GB for s in (1, 2, 4, 8, 16, 32, 64))

_DEFAULT_RTT = 0.03  # ~WAN RTT between FABRIC sites


def paper_baseline(rtt: float = _DEFAULT_RTT, jitter: float = 0.02) -> list[ServerSpec]:
    """Six replicas, one fast path: aggregate ~145 MiB/s."""
    rates = [12, 14, 15, 16, 18, 70]
    return [
        ServerSpec(name=f"replica{i + 1}", bandwidth=r * MBPS, rtt=rtt, jitter=jitter)
        for i, r in enumerate(rates)
    ]


def paper_balanced(rtt: float = _DEFAULT_RTT, jitter: float = 0.02) -> list[ServerSpec]:
    """Six near-equal replicas: aggregate ~145.5 MiB/s (Fig. 5c regime)."""
    rates = [23.0, 23.5, 24.0, 24.5, 25.0, 25.5]
    return [
        ServerSpec(name=f"replica{i + 1}", bandwidth=r * MBPS, rtt=rtt, jitter=jitter)
        for i, r in enumerate(rates)
    ]


def bittorrent_seeders(
    rtt: float = _DEFAULT_RTT,
    mean_up: float = 60.0,
    mean_down: float = 45.0,
) -> list[ServerSpec]:
    """The same six replicas as seeders with on/off availability flapping.

    Calibrated so the expected number of simultaneously active seeders sits
    in the paper's observed 2-5 band (Fig. 2c): availability = up/(up+down)
    = 0.57 => E[active] ~= 3.4 of 6.
    """
    return [
        ServerSpec(
            name=s.name, bandwidth=s.bandwidth, rtt=rtt, jitter=s.jitter,
            avail_up=mean_up, avail_down=mean_down,
        )
        for s in paper_baseline(rtt=rtt)
    ]


def with_added_latency(
    servers: list[ServerSpec], extra_rtt: float = 0.5
) -> list[ServerSpec]:
    """Paper §VII-C: +0.5 s latency on the *fastest* server's requests."""
    fastest = max(range(len(servers)), key=lambda i: servers[i].bandwidth)
    return [
        replace(s, rtt=s.rtt + extra_rtt) if i == fastest else s
        for i, s in enumerate(servers)
    ]


# --------------------------------------------------------------------------
# Multi-transfer contention (fleet-shared scheduling, TransferManager)
# --------------------------------------------------------------------------
#
# MDTP's bin-packing frames each server as a capacity bin for ONE transfer
# (§IV).  A managed fleet packs K concurrent transfers into the same bins;
# the simulator-side mirror models contention as a fair k-way bandwidth
# split per replica (TCP-fair sharing of each mirror's uplink), which is
# what ``repro.core.autotune.contention_sweep`` vmaps over and what
# ``benchmarks/contention_bench.py`` replays phase by phase.


def shared_bottleneck(rtt: float = _DEFAULT_RTT,
                      jitter: float = 0.0) -> list[ServerSpec]:
    """Six replicas where ONE fast path carries most of the fleet:
    aggregate ~140 MiB/s, 120 of it behind a single mirror.  Concurrent
    transfers all lean on the same bottleneck — the worst case for
    independent greedy clients that each plan as if they owned it."""
    rates = [4, 4, 4, 4, 4, 120]
    return [
        ServerSpec(name=f"replica{i + 1}", bandwidth=r * MBPS, rtt=rtt,
                   jitter=jitter)
        for i, r in enumerate(rates)
    ]


def with_fair_share(servers: list[ServerSpec], k: int) -> list[ServerSpec]:
    """The fleet as ONE of ``k`` concurrent transfers sees it: every
    mirror's bandwidth (and throttle-profile rates) split ``k`` ways.
    ``k = 1`` returns the servers unchanged."""
    if k < 1:
        raise ValueError("k must be >= 1")
    if k == 1:
        return list(servers)
    return [
        replace(s, bandwidth=s.bandwidth / k,
                profile=tuple((t, bw / k) for t, bw in s.profile))
        for s in servers
    ]


def contention_matrix(servers: list[ServerSpec],
                      ks: list[int]) -> list[list[float]]:
    """``[len(ks), N]`` per-transfer bandwidth rows (row i = fair share
    under ``ks[i]`` concurrent transfers) — the scenario-batch input for
    ``sweep_scenarios`` / ``contention_sweep``."""
    return [[s.bandwidth / k for s in servers] for k in ks]


@dataclass(frozen=True)
class ContentionTrace:
    """K transfers contending for one fleet.

    ``sizes[j]`` bytes for transfer j, arriving ``arrivals[j]`` seconds
    after trace start.  Replayed phase-by-phase (a phase = a constant
    active set, each active transfer at fair share) by the contention
    benchmark and the manager tests.
    """

    name: str
    servers: tuple[ServerSpec, ...]
    sizes: tuple[int, ...]
    arrivals: tuple[float, ...]

    def __post_init__(self):
        if len(self.sizes) != len(self.arrivals):
            raise ValueError("one arrival per transfer required")


def contention_traces() -> list[ContentionTrace]:
    """The three fleet-contention regimes the manager must win:

    * ``simultaneous`` — three unequal transfers arrive together on the
      calibrated baseline fleet (pure k-way split; k drops 3 → 2 → 1 as
      the shorter transfers drain, re-expanding everyone's share);
    * ``staggered`` — transfers land 5 s apart, flipping the fleet
      through the k = 1/2/3 regimes in both directions;
    * ``bottleneck`` — K=3 transfers leaning on one dominant path, where
      greedy per-transfer planning oversizes the shared bin the most.

    WAN-grade RTTs (the FABRIC inter-site regime, amplified) make chunk
    geometry matter: at a fair k-way share the RTT-amortization optimum
    shifts, which is exactly the signal ``contention_sweep`` captures.
    Deterministic (``jitter=0``) so benchmark comparisons are exact.
    """
    base = tuple(paper_baseline(rtt=0.20, jitter=0.0))
    bottleneck = tuple(shared_bottleneck(rtt=0.30))
    return [
        ContentionTrace(
            "simultaneous", base,
            sizes=(GB, 3 * GB // 4, GB // 2),
            arrivals=(0.0, 0.0, 0.0)),
        ContentionTrace(
            "staggered", base,
            sizes=(GB, GB, GB),
            arrivals=(0.0, 5.0, 10.0)),
        ContentionTrace(
            "bottleneck", bottleneck,
            sizes=(GB, GB, GB),
            arrivals=(0.0, 0.0, 0.0)),
    ]


def with_gray_degradation(
    servers: list[ServerSpec],
    degrade_at: float,
    degrade_factor: float = 0.1,
    only: int | None = None,
) -> list[ServerSpec]:
    """Inject silent mid-transfer degradation (``ServerSpec.degrade_at``/
    ``degrade_factor``) — the paper's "bandwidth decrease to the fastest
    server" case.  ``only=None`` grays the whole fleet; ``only=i`` grays
    just replica ``i`` (one slow mirror, the hedging/probation regime)."""
    return [
        replace(s, degrade_at=degrade_at, degrade_factor=degrade_factor)
        if only is None or i == only else s
        for i, s in enumerate(servers)
    ]


@dataclass(frozen=True)
class FlashCrowdTrace:
    """One named overload regime: a fleet plus an arrival process.

    ``sizes[j]`` bytes arrive at ``arrivals[j]`` seconds — the workload
    the manager's admission gate, SRPT queue, and shed mode absorb.
    Deterministic arrival times (no RNG) so benchmark replays and the
    simulator agree on the exact storm shape.
    """

    name: str
    servers: tuple[ServerSpec, ...]
    sizes: tuple[int, ...]
    arrivals: tuple[float, ...]


def flash_crowd_traces(rtt: float = _DEFAULT_RTT) -> list[FlashCrowdTrace]:
    """The three overload regimes of the ROADMAP's flash-crowd item:

    * ``burst`` — a flash crowd: 12 same-sized transfers land within
      ~0.6 s of each other on the calibrated baseline fleet.  Without
      admission control everyone splits every mirror 12 ways and every
      transfer finishes late together; with SRPT + a max-active gate the
      short head of the queue drains fast.
    * ``diurnal`` — two arrival waves (morning/evening) of 6 transfers
      each with mixed sizes; exercises queue drain + re-expansion.
    * ``gray-burst`` — the ``burst`` storm while the FASTEST mirror
      silently degrades to 10% of its bandwidth mid-storm
      (``ServerSpec.degrade_at``): the compound case hedged endgame +
      probation + admission are jointly built for.

    Deterministic fleets (``jitter=0``) and arrival grids, so real-socket
    replays (``benchmarks/flashcrowd_bench.py``) and simulator runs see
    the identical storm.
    """
    base = tuple(paper_baseline(rtt=rtt, jitter=0.0))
    fastest = max(range(len(base)), key=lambda i: base[i].bandwidth)
    burst_arrivals = tuple(0.05 * j for j in range(12))
    wave = tuple(0.2 * j for j in range(6))
    diurnal_arrivals = wave + tuple(30.0 + t for t in wave)
    return [
        FlashCrowdTrace(
            "burst", base,
            sizes=(GB // 4,) * 12,
            arrivals=burst_arrivals),
        FlashCrowdTrace(
            "diurnal", base,
            sizes=(GB // 4, GB // 2, GB // 8, GB // 4, GB // 2, GB // 8) * 2,
            arrivals=diurnal_arrivals),
        FlashCrowdTrace(
            "gray-burst",
            tuple(with_gray_degradation(
                list(base), degrade_at=2.0, degrade_factor=0.1,
                only=fastest)),
            sizes=(GB // 4,) * 12,
            arrivals=burst_arrivals),
    ]


# --------------------------------------------------------------------------
# Fault injection (integrity + loss — the chaos-harness mirror)
# --------------------------------------------------------------------------
#
# The real stack injects faults at the HTTP server (``transfer.server
# .FaultPolicy``) and recovers in the client (CRC verify, banned re-pool,
# resume journal).  These traces are the simulator-side mirror: the same
# per-chunk loss/corruption probabilities on ``ServerSpec``, with matching
# ``SimConfig.loss_rate``/``corruption_rate`` for the on-device tuner
# cores, so (C, L) tuning can price in re-fetch overhead.


def with_faults(
    servers: list[ServerSpec],
    loss_rate: float = 0.0,
    corruption_rate: float = 0.0,
    only: int | None = None,
) -> list[ServerSpec]:
    """Inject per-chunk fault probabilities into a fleet.

    ``only=None`` applies the rates to every replica (a lossy client-side
    path); ``only=i`` taints just replica ``i`` (one bad mirror — the
    regime where re-fetch-from-alternate wins big).
    """
    return [
        replace(s, loss_rate=loss_rate, corruption_rate=corruption_rate)
        if only is None or i == only else s
        for i, s in enumerate(servers)
    ]


@dataclass(frozen=True)
class FaultTrace:
    """One named fault regime, with the fleet-wide effective rates the
    on-device tuner cores should mirror (``SimConfig.loss_rate`` /
    ``corruption_rate`` are scalar, so per-replica taints are averaged
    into an effective fleet rate weighted by nothing fancier than 1/N —
    the tuner only needs the right order of magnitude of re-fetch tax)."""

    name: str
    servers: tuple[ServerSpec, ...]
    loss_rate: float
    corruption_rate: float


def fault_traces(rtt: float = _DEFAULT_RTT) -> list[FaultTrace]:
    """The three fault regimes the robustness suite exercises:

    * ``lossy-path`` — every replica drops 5% of chunks mid-body (WAN
      resets); tests reclaim + backoff overhead.
    * ``corrupt-mirror`` — ONE replica (the fastest, worst case) corrupts
      20% of its bodies; tests CRC verify + banned re-pool + the fleet
      health deprioritization.
    * ``flaky-fleet`` — 2% loss and 2% corruption everywhere; the
      background-noise regime (C, L) tuning should price in.

    Deterministic base fleets (``jitter=0``) so fault overhead is the
    only stochastic term.
    """
    base = paper_baseline(rtt=rtt, jitter=0.0)
    fastest = max(range(len(base)), key=lambda i: base[i].bandwidth)
    n = len(base)
    return [
        FaultTrace(
            "lossy-path",
            tuple(with_faults(base, loss_rate=0.05)),
            loss_rate=0.05, corruption_rate=0.0),
        FaultTrace(
            "corrupt-mirror",
            tuple(with_faults(base, corruption_rate=0.20, only=fastest)),
            loss_rate=0.0, corruption_rate=0.20 / n),
        FaultTrace(
            "flaky-fleet",
            tuple(with_faults(base, loss_rate=0.02, corruption_rate=0.02)),
            loss_rate=0.02, corruption_rate=0.02),
    ]


def with_throttled_fastest(
    servers: list[ServerSpec],
    limit_bytes_per_s: float = 62.5 * 1000 * 1000,  # 500 Mbps
    at_time: float = 0.0,
) -> list[ServerSpec]:
    """Paper §VII-D: cap the fastest server's bandwidth at 500 Mbps."""
    fastest = max(range(len(servers)), key=lambda i: servers[i].bandwidth)
    out = []
    for i, s in enumerate(servers):
        if i == fastest:
            capped = min(s.bandwidth, limit_bytes_per_s)
            out.append(replace(s, profile=s.profile + ((at_time, capped),)))
        else:
            out.append(s)
    return out


# --------------------------------------------------------------------------
# Peer-assisted broadcast (checkpoint-restore swarms)
# --------------------------------------------------------------------------
#
# The real stack: N restoring nodes arrive together, each mounting its
# filling buffer on a ``repro.transfer.PeerMirror`` and fetching from the
# origin plus every other restorer's mirror (coverage-gated packing).
# The simulator mirror below is the capacity view ONE such restorer sees:
# the origin at a fair 1/n share of its fixed uplink, and each peer as a
# mirror that starts DARK (a restoring node has nothing to serve yet) and
# steps UP to a fair share of its uplink at a staggered onset — the
# inverse of the Fig. 4 down-throttle, riding the same single-breakpoint
# (bw0, throttle_t, bw1) axes of the jax round/scan cores.

#: effectively-offline rate for a peer that hasn't come online yet: low
#: enough to contribute nothing, high enough that its probe chunk's
#: pre-onset crawl doesn't dominate a round (the onset step completes it).
_DARK_BW = 1.0


def swarm_fleet(n: int, origin_bw: float = 96 * MBPS,
                peer_bw: float | None = None, onset: float = 1.0,
                rtt: float = _DEFAULT_RTT) -> list[ServerSpec]:
    """The fleet ONE of ``n`` broadcast restorers sees.

    ``origin_bw`` is the origin's FIXED aggregate capacity — n restorers
    arriving together split it n ways (TCP-fair), so the per-client
    origin share shrinks as the swarm grows; that scarcity is exactly
    what peer serving relieves.  Each of the other ``n - 1`` restorers
    appears as a peer mirror: dark until ``onset`` scaled by a per-peer
    stagger (ranges complete one restorer at a time, so peers come
    online spread over [onset, 2*onset)), then serving a fair
    ``1/(n - 1)`` share of its own uplink (``peer_bw``, default =
    ``origin_bw``).  ``n = 1`` is the no-swarm baseline: the origin
    alone at full rate.
    """
    if n < 1:
        raise ValueError(f"swarm size must be >= 1, got {n}")
    peer_bw = origin_bw if peer_bw is None else peer_bw
    servers = [ServerSpec(name="origin", bandwidth=origin_bw / n, rtt=rtt,
                          jitter=0.0)]
    for k in range(n - 1):
        stagger = onset * (1.0 + k / max(n - 1, 1))
        servers.append(ServerSpec(
            name=f"peer{k + 1}", bandwidth=_DARK_BW, rtt=rtt, jitter=0.0,
            profile=((stagger, peer_bw / (n - 1)),)))
    return servers


def swarm_axes(servers: list[ServerSpec]) -> tuple[list, list, list]:
    """``(bw0, throttle_t, throttle_bw)`` per-server axes for the jax
    round/scan cores (their single-breakpoint throttle form).  Servers
    without a profile keep their rate on both sides of an infinite
    breakpoint; profiled servers contribute their first step — which for
    a swarm peer is the UP-step onset."""
    bw0, tt, tb = [], [], []
    for s in servers:
        bw0.append(float(s.bandwidth))
        if s.profile:
            t, b = s.profile[0]
            tt.append(float(t))
            tb.append(float(b))
        else:
            tt.append(float("inf"))
            tb.append(float(s.bandwidth))
    return bw0, tt, tb


@dataclass(frozen=True)
class SwarmTrace:
    """One named broadcast regime: ``n`` restorers of a ``size``-byte
    checkpoint on one fixed-capacity origin, as the per-client fleet
    view of :func:`swarm_fleet`.  Deterministic (``jitter=0``) so the
    event core and the round/scan cores (via :func:`swarm_axes`) replay
    the identical capacity schedule."""

    name: str
    n: int
    servers: tuple[ServerSpec, ...]
    size: int


def swarm_traces(rtt: float = _DEFAULT_RTT) -> list[SwarmTrace]:
    """The three broadcast regimes the swarm suite exercises:

    * ``pair`` — 2 restorers: the minimal swarm (one peer each); mostly
      a sanity anchor, peer capacity equals origin capacity.
    * ``quad`` — 4 restorers arriving together, early peer onset: the
      real-socket benchmark's shape (``benchmarks/broadcast_bench.py``
      runs this with actual ``PeerMirror`` fleets).
    * ``cold-start`` — 8 restorers behind a LATE onset: the origin-bound
      opening phase dominates, the regime where striped first-fetches
      (de-correlating what each node asks the origin for) matter most.
    """
    return [
        SwarmTrace("pair", 2,
                   tuple(swarm_fleet(2, onset=0.5, rtt=rtt)), GB),
        SwarmTrace("quad", 4,
                   tuple(swarm_fleet(4, onset=0.5, rtt=rtt)), GB),
        SwarmTrace("cold-start", 8,
                   tuple(swarm_fleet(8, onset=4.0, rtt=rtt)), GB),
    ]


# --------------------------------------------------------------------------
# Sharded, work-stealing restore (K-host meshes)
# --------------------------------------------------------------------------
#
# The real stack (``repro.transfer.shard``): a K-host mesh splits the
# blob into contiguous per-host spans; each host fetches its span from
# its own origin and serves landed bytes to peers, and hosts that finish
# early *steal* uncovered tails of a straggling host's span — fetching
# them through their own fast origin so the victim can drain the stolen
# range from a fast peer mirror instead of its slow origin.  The
# simulator mirror below is the capacity view the STRAGGLER sees for its
# own span: its slow origin, plus each would-be thief as a peer mirror
# that comes online once the thief has finished its own span and landed
# stolen bytes worth advertising.


def shard_fleet(k: int, origin_bw: float = 96 * MBPS,
                straggler_frac: float = 0.125, steal_onset: float = 1.0,
                rtt: float = _DEFAULT_RTT) -> list[ServerSpec]:
    """The fleet the straggler of a ``k``-host sharded restore sees.

    Its own origin runs at ``origin_bw * straggler_frac`` (the gray
    mirror that motivates stealing); each of the other ``k - 1`` hosts
    appears as a peer that is dark until ``steal_onset`` scaled by a
    per-thief stagger (a thief first finishes its OWN span, then lands
    stolen bytes), then serves a fair ``1/(k - 1)`` share of a full
    ``origin_bw`` uplink.  ``straggler_frac = 1`` is the balanced
    no-straggler baseline.
    """
    if k < 1:
        raise ValueError(f"shard count must be >= 1, got {k}")
    servers = [ServerSpec(name="origin", bandwidth=origin_bw * straggler_frac,
                          rtt=rtt, jitter=0.0)]
    for t in range(k - 1):
        stagger = steal_onset * (1.0 + t / max(k - 1, 1))
        servers.append(ServerSpec(
            name=f"thief{t + 1}", bandwidth=_DARK_BW, rtt=rtt, jitter=0.0,
            profile=((stagger, origin_bw / max(k - 1, 1)),)))
    return servers


@dataclass(frozen=True)
class ShardTrace:
    """One named sharded-restore regime: the straggler's-eye view of a
    ``k``-host mesh restoring a blob whose per-host span is ``size``
    bytes.  Deterministic (``jitter=0``); ``swarm_axes`` converts the
    servers to the jax round/scan throttle form unchanged (peer onsets
    are single up-steps, exactly like swarm peers)."""

    name: str
    k: int
    servers: tuple[ServerSpec, ...]
    size: int


def shard_traces(rtt: float = _DEFAULT_RTT) -> list[ShardTrace]:
    """The two regimes ``benchmarks/shard_bench.py`` mirrors with real
    sockets:

    * ``balanced`` — 4 hosts, no straggler: stealing should find nothing
      to do and cost nothing (the win-guard's "do no harm" side).
    * ``straggler`` — 4 hosts, one origin at 1/8 rate: the regime where
      work stealing converts the victim's makespan from span/slow-rate
      toward span/(slow + thieves' fair shares).
    """
    span = GB // 4
    return [
        ShardTrace("balanced", 4,
                   tuple(shard_fleet(4, straggler_frac=1.0, rtt=rtt)), span),
        ShardTrace("straggler", 4,
                   tuple(shard_fleet(4, straggler_frac=0.125,
                                     steal_onset=0.5, rtt=rtt)), span),
    ]
