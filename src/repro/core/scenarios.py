"""Calibrated simulation scenarios for reproducing the paper's experiments.

The paper's FABRIC testbed: one client, six same-spec geographically
distributed servers behind 10 Gbps NICs, Apache over HTTP.  Measured
end-to-end application throughput was far below NIC line rate (Python
client; WAN paths): MDTP moved 64 GB in ~446 s => ~145 MB/s aggregate.

Two presets capture the paper's (mutually tension-y) observations:

* ``paper_baseline`` — one distinctly fast path plus five slower ones,
  aggregate ~145 MB/s.  Reproduces Fig. 2 absolute times, the Fig. 4
  throttling deltas (throttling the fastest to 500 Mbps = 62.5 MB/s must
  actually bite, so the fastest exceeds that), the Fig. 5a/5b utilization
  and packet-skew behavior of Aria2.
* ``paper_balanced`` — six near-equal servers (same aggregate).  Reproduces
  Fig. 5c: with near-homogeneous capacity MDTP issues an *equal number* of
  requests per replica (the paper measured exactly 37 for a 32 GB file),
  because every round completes in lockstep.

Calibration notes live in EXPERIMENTS.md §Reproduction.
"""

from __future__ import annotations

from .simulator import ServerSpec

__all__ = [
    "MBPS",
    "GB",
    "paper_baseline",
    "paper_balanced",
    "bittorrent_seeders",
    "with_added_latency",
    "with_throttled_fastest",
    "PAPER_FILE_SIZES",
]

MBPS = 1024 * 1024  # we quote server rates in MiB/s
GB = 1024**3

#: File sizes evaluated in the paper (§VI-A).
PAPER_FILE_SIZES = tuple(s * GB for s in (1, 2, 4, 8, 16, 32, 64))

_DEFAULT_RTT = 0.03  # ~WAN RTT between FABRIC sites


def paper_baseline(rtt: float = _DEFAULT_RTT, jitter: float = 0.02) -> list[ServerSpec]:
    """Six replicas, one fast path: aggregate ~145 MiB/s."""
    rates = [12, 14, 15, 16, 18, 70]
    return [
        ServerSpec(name=f"replica{i + 1}", bandwidth=r * MBPS, rtt=rtt, jitter=jitter)
        for i, r in enumerate(rates)
    ]


def paper_balanced(rtt: float = _DEFAULT_RTT, jitter: float = 0.02) -> list[ServerSpec]:
    """Six near-equal replicas: aggregate ~145.5 MiB/s (Fig. 5c regime)."""
    rates = [23.0, 23.5, 24.0, 24.5, 25.0, 25.5]
    return [
        ServerSpec(name=f"replica{i + 1}", bandwidth=r * MBPS, rtt=rtt, jitter=jitter)
        for i, r in enumerate(rates)
    ]


def bittorrent_seeders(
    rtt: float = _DEFAULT_RTT,
    mean_up: float = 60.0,
    mean_down: float = 45.0,
) -> list[ServerSpec]:
    """The same six replicas as seeders with on/off availability flapping.

    Calibrated so the expected number of simultaneously active seeders sits
    in the paper's observed 2-5 band (Fig. 2c): availability = up/(up+down)
    = 0.57 => E[active] ~= 3.4 of 6.
    """
    return [
        ServerSpec(
            name=s.name, bandwidth=s.bandwidth, rtt=rtt, jitter=s.jitter,
            avail_up=mean_up, avail_down=mean_down,
        )
        for s in paper_baseline(rtt=rtt)
    ]


def with_added_latency(
    servers: list[ServerSpec], extra_rtt: float = 0.5
) -> list[ServerSpec]:
    """Paper §VII-C: +0.5 s latency on the *fastest* server's requests."""
    fastest = max(range(len(servers)), key=lambda i: servers[i].bandwidth)
    out = []
    for i, s in enumerate(servers):
        if i == fastest:
            out.append(ServerSpec(
                name=s.name, bandwidth=s.bandwidth, rtt=s.rtt + extra_rtt,
                connect_latency=s.connect_latency, profile=s.profile,
                jitter=s.jitter,
            ))
        else:
            out.append(s)
    return out


def with_throttled_fastest(
    servers: list[ServerSpec],
    limit_bytes_per_s: float = 62.5 * 1000 * 1000,  # 500 Mbps
    at_time: float = 0.0,
) -> list[ServerSpec]:
    """Paper §VII-D: cap the fastest server's bandwidth at 500 Mbps."""
    fastest = max(range(len(servers)), key=lambda i: servers[i].bandwidth)
    out = []
    for i, s in enumerate(servers):
        if i == fastest:
            capped = min(s.bandwidth, limit_bytes_per_s)
            out.append(ServerSpec(
                name=s.name, bandwidth=s.bandwidth, rtt=s.rtt,
                connect_latency=s.connect_latency,
                profile=s.profile + ((at_time, capped),),
                jitter=s.jitter,
            ))
        else:
            out.append(s)
    return out
