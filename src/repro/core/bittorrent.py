"""BitTorrent behavioral model for the simulator.

The paper ran six always-on seeders with choking disabled and still observed
erratic participation: 2-5 of 6 seeders active at any time (Fig. 2c), ~2x
MDTP's transfer time, and 30x worse run-to-run variance.  We model the
client side as: equal pieces (BitTorrent piece sizes are static per
torrent), one request pipeline per seeder, and seeder availability as an
on/off Markov process (``ServerSpec.avail_up/avail_down``) calibrated to the
2-5 active-seeder band.  A piece interrupted by a seeder flap is resumed
from the byte it stopped at (slightly *favoring* BT versus real piece-hash
semantics, which would discard the partial piece — noted in EXPERIMENTS.md).

Rarest-first and tit-for-tat do not matter in the paper's setting (all
seeders hold the full file; choking was disabled), so they are not modeled.
"""

from __future__ import annotations

from .simulator import Action, Policy, Request, TransferState, Wait

__all__ = ["BitTorrentPolicy"]

MB = 1024 * 1024


class BitTorrentPolicy(Policy):
    name = "bittorrent"

    def __init__(self, piece_size: int = 4 * MB, retry_interval: float = 5.0):
        self.piece_size = piece_size
        self.retry_interval = retry_interval

    def reset(self, n_servers: int, file_size: int) -> None:
        self._backoff_until = [0.0] * n_servers

    def next_action(self, state: TransferState, conn: int, now: float) -> Action:
        seeder = conn  # one pipeline per seeder
        if state.unassigned_bytes() <= 0:
            return None
        if now < self._backoff_until[seeder]:
            return Wait(self._backoff_until[seeder])
        return Request(seeder, min(self.piece_size, state.unassigned_bytes()))

    def on_complete(
        self, state: TransferState, conn: int, server: int,
        nbytes: int, elapsed: float, now: float, truncated: bool = False,
    ) -> None:
        if truncated or nbytes == 0:
            # seeder flapped; poll it again after a tracker-ish delay
            self._backoff_until[server] = now + self.retry_interval
