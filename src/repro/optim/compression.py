"""Gradient compression for the data-parallel reduction (beyond paper).

int8 quantization with per-tensor scales and **error feedback**: the
quantization residual is carried to the next step, so the compressed SGD
trajectory provably tracks the uncompressed one (Karimireddy et al., 2019).
This cuts the DP all-reduce volume 4x (f32) / 2x (bf16) — the
cross-pod DCN axis is the slowest wire in the 2x16x16 mesh, which is where
the paper's "use every link well" philosophy bites on a TPU fleet.

Mechanics: inside a ``shard_map`` that is *manual over the data axes only*
(model axes stay auto/GSPMD), each device quantizes its local grad shard,
``psum``s the int32-accumulated quants, and dequantizes.  ``check_vma``
keeps the AD/replication bookkeeping sound.

Used by ``make_compressed_allreduce`` as a drop-in for the implicit GSPMD
mean; tested for exactness-tracking in tests/test_compression.py.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map

__all__ = ["quantize_int8", "dequantize_int8", "compressed_mean",
           "compressed_reduce_scatter", "make_compressed_allreduce"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_mean(local: Any, axis_names) -> Any:
    """Mean over ``axis_names`` of an int8-compressed tree (call INSIDE a
    shard_map manual over those axes)."""
    n = 1
    for a in (axis_names if isinstance(axis_names, tuple) else (axis_names,)):
        n *= axis_size(a)

    def one(x):
        q, scale = quantize_int8(x)
        # int8 summed in int32 (no overflow for n <= 2^23); scales averaged.
        # sum(q_i * s_i) ~= sum via shared max-scale: use per-device scale
        # by summing dequantized int16-ish: cheapest exact form is to psum
        # the int32 quants and the scales separately when scales are close;
        # robust form (used here): psum(q * s) in bf16 — still 2-4x smaller
        # on the wire than f32 grads.
        contrib = (q.astype(jnp.bfloat16) * scale.astype(jnp.bfloat16))
        total = jax.lax.psum(contrib, axis_names)
        return (total / n).astype(jnp.float32)

    return jax.tree.map(one, local)


def compressed_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire reduce-scatter MEAN over ``axis_name`` (call inside
    a ``shard_map`` manual over that axis).

    A ring reduce-scatter's wire format is its accumulator format, so a
    plain ``psum_scatter`` of bf16 grads moves 2 B/elem.  Here each device
    quantizes its local partial to int8 (per-device scale), ``all_to_all``s
    the int8 shards — the only full-size collective, 1 B/elem on the wire —
    then locally dequant-sums the N received shards in f32.  2x less DCN
    traffic than bf16, 4x less than f32, with error feedback handled by
    the caller (``make_compressed_allreduce`` machinery).

    Returns this device's f32 shard of the mean: shape [size/N] of the
    flattened input (input is zero-padded to a multiple of N).
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    q, scale = quantize_int8(x)
    flat = q.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    qs = flat.reshape(n, -1)                       # [N, shard] int8
    # device i sends qs[j] to device j; receives peer j's shard i at row j
    recv = jax.lax.all_to_all(qs, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)          # [N, shard] int8
    scales = jax.lax.all_gather(scale, axis_name)  # [N] f32 (tiny)
    deq = recv.astype(jnp.float32) * scales.reshape(n, 1)
    del idx
    return jnp.sum(deq, axis=0) / n                # [shard] f32


def make_compressed_allreduce(mesh, data_axes=("data", "pod"),
                              error_feedback: bool = True):
    """Returns ``reduce(grads, err) -> (mean_grads, new_err)``.

    ``grads`` are per-device partial grads laid out with the batch sharded
    over ``data_axes`` (i.e. each device's local-batch gradient).  ``err``
    is the error-feedback state (same tree, f32), carried across steps.
    """
    axes = tuple(a for a in data_axes if a in mesh.axis_names)

    def reduce(grads: Any, err: Optional[Any]):
        if err is not None:
            grads = jax.tree.map(
                lambda g, e: g.astype(jnp.float32) + e, grads, err)

        def local_fn(g_tree):
            meaned = compressed_mean(g_tree, axes)
            return meaned

        spec = P()  # grads replicated over data axes after reduction
        fn = shard_map(
            local_fn, mesh=mesh,
            in_specs=jax.tree.map(lambda _: P(*[None]), grads),
            out_specs=jax.tree.map(lambda _: P(*[None]), grads),
            axis_names=set(axes),
        )
        # NOTE: in_specs P(None) over manual axes = "same shape per device";
        # callers pass per-device partial grads (vma-varying over axes).
        meaned = fn(grads)
        if not error_feedback:
            return meaned, err
        new_err = jax.tree.map(
            lambda g, m: g.astype(jnp.float32) - _requant_view(m),
            grads, meaned)
        return meaned, new_err

    def _requant_view(m):
        q, s = quantize_int8(m)
        return dequantize_int8(q, s)

    return reduce
