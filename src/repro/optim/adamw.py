"""AdamW with dtype-configurable moments and ZeRO-1-style state sharding.

No optax in this environment — this is the framework's own optimizer.

Sharding: optimizer moments mirror the parameter logical axes but are
resolved with an extra override (``embed -> ("data", "pod")``), which
shards the dominant dimension of nearly every tensor across the data axes.
XLA then emits the reduce-scatter (grads -> sharded update) and all-gather
(updated params -> compute sharding) pairs of a classic ZeRO-1 — we only
declare storage shardings and let SPMD place the collectives.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec

__all__ = ["AdamWConfig", "adamw_init", "adamw_apply", "opt_state_specs",
           "lr_at_step", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"      # bf16 halves optimizer HBM (kimi)
    zero1: bool = True                 # shard moments over data axes


def lr_at_step(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def opt_state_specs(param_specs: Any, cfg: AdamWConfig) -> Any:
    """ParamSpec tree for (m, v): same shapes/logical axes as params.

    The ZeRO-1 data-axis sharding is applied at resolve time by the launch
    code (rules override), not here — specs stay logical.
    """
    mk = lambda s: ParamSpec(s.shape, s.logical, "zeros")
    m = jax.tree.map(mk, param_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    v = jax.tree.map(mk, param_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return {"m": m, "v": v, "step": ParamSpec((), (), "zeros")}


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.float32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def adamw_apply(grads: Any, state: dict, params: Any, cfg: AdamWConfig,
                decay_mask: Optional[Any] = None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1.0
    lr = lr_at_step(cfg, step)

    with jax.named_scope("f32c"):
        gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)

    bc1 = 1.0 - cfg.b1 ** step
    bc2 = 1.0 - cfg.b2 ** step
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v, wd):
        # f32c: the optimizer update is genuinely f32 (master math)
        with jax.named_scope("f32c"):
            return _upd_f32(p, g, m, v, wd)

    def _upd_f32(p, g, m, v, wd):
        gf = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + wd * pf)
        return pf.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    # weight decay skips 1-D params (norm scales, biases) by default
    if decay_mask is None:
        decay_mask = jax.tree.map(
            lambda p: cfg.weight_decay if p.ndim >= 2 else 0.0, params)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_w = jax.tree.leaves(decay_mask)
    new = [upd(p, g, m, v, w)
           for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = jax.tree.unflatten(treedef, [t[0] for t in new])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in new])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
