"""repro.optim"""
