"""Sharding context: logical-axis rules resolved against an active mesh.

Models are written against *logical* axis names ("batch", "heads", "mlp",
"expert", ...).  The launcher activates a ``ShardingCtx`` binding those
names to physical mesh axes; ``constrain`` then emits
``with_sharding_constraint`` hints and ``axis_size``/``has_axis`` let
blocks (MoE all-to-all) discover the topology.  With no active context
(unit tests, single-CPU smoke runs) everything degrades to a no-op, so the
same model code runs anywhere.

Hillclimbing edits the *rules*, never the models.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "ShardingCtx",
    "activate",
    "active_ctx",
    "constrain",
    "logical_to_spec",
    "named_sharding",
]

#: Baseline logical->mesh rules (megatron-style TP over "model", DP over
#: "pod"+"data").  Values are a mesh axis name, a tuple of axis names, or
#: None (replicated).  Per-arch overrides live in the arch config.
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "attn_in": None,        # attention-weight d dims (FSDP lever)
    "attn_out_d": None,
    "qheads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "moe_seq": "model",     # seq resharding at the MoE a2a boundary
    "layers": None,
    "state": None,          # SSM state dim
    "conv": None,
    "cache_seq": None,      # KV-cache sequence dim (seq-sharded for 500k)
    "frames": None,         # audio/vision source positions
    "fsdp": None,           # extra storage-only shard dim; "data" = FSDP
}


@dataclass(frozen=True)
class ShardingRules:
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    def override(self, **kw) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(d)

    def resolve_entries(self, logical: Sequence[Optional[str]],
                        axes_present: frozenset) -> list:
        """Raw per-dim entries (mesh axis name / tuple / None), dropping
        mesh axes the active mesh does not have (no "pod" on one pod)."""
        out = []
        for name in logical:
            if name is None:
                out.append(None)
                continue
            target = self.rules.get(name)
            if target is None:
                out.append(None)
            elif isinstance(target, tuple):
                present = tuple(a for a in target if a in axes_present)
                out.append(present if present else None)
            else:
                out.append(target if target in axes_present else None)
        return out

    def resolve(self, logical: Sequence[Optional[str]],
                axes_present: frozenset) -> P:
        out = _dedupe(self.resolve_entries(logical, axes_present))
        while out and out[-1] is None:
            out.pop()
        return P(*out)


@dataclass
class ShardingCtx:
    mesh: Mesh
    rules: ShardingRules

    @property
    def axes(self) -> frozenset:
        return frozenset(self.mesh.axis_names)

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        entries = self.rules.resolve_entries(logical, self.axes)
        if shape is not None:
            # Divisibility masking for INPUT/storage shardings: jit argument
            # shardings must tile evenly (GSPMD only pads intermediates), so
            # an axis that doesn't divide the dim drops to replicated.  E.g.
            # GQA kv=8 heads cannot shard over model=16 -> wk/wv replicate
            # and the decode cache seq-shards instead (dryrun.py rules).
            entries = entries + [None] * (len(shape) - len(entries))
            masked = []
            for dim, entry in zip(shape, entries):
                if entry is None:
                    masked.append(None)
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                factor = 1
                for a in axes:
                    factor *= self.mesh.shape[a]
                masked.append(entry if dim % factor == 0 else None)
            entries = masked
        out = _dedupe(entries)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def axis_size(self, name: str) -> int:
        if name not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[name]

    def batch_axes(self) -> tuple[str, ...]:
        """Physical axes the batch is sharded over (for psum in loss)."""
        target = self.rules.rules.get("batch")
        if target is None:
            return ()
        if isinstance(target, str):
            target = (target,)
        return tuple(a for a in target if a in self.mesh.axis_names)


def _dedupe(entries: list) -> list:
    """Drop mesh axes already claimed by an earlier dim (masking can free an
    axis — e.g. batch=1 decode frees 'data' for the cache_seq dim)."""
    seen: set = set()
    out = []
    for entry in entries:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = tuple(a for a in axes if a not in seen)
        seen.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    return out


_tls = threading.local()


def active_ctx() -> Optional[ShardingCtx]:
    return getattr(_tls, "ctx", None)


@contextmanager
def activate(mesh: Mesh, rules: Optional[ShardingRules] = None):
    """Bind a mesh + rules for the duration of a trace/lower call."""
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = ShardingCtx(mesh=mesh, rules=rules or ShardingRules())
    try:
        yield _tls.ctx
    finally:
        _tls.ctx = prev


def logical_to_spec(logical: Sequence[Optional[str]]) -> P:
    ctx = active_ctx()
    if ctx is None:
        return P()
    return ctx.spec(logical)


def named_sharding(logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    ctx = active_ctx()
    if ctx is None:
        return None
    return ctx.sharding(logical)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with the sharding its logical dims resolve to.

    No-op without an active context so model code is mesh-agnostic, and
    no-op inside a ``shard_map`` manual region (vma-varying values cannot
    take auto-axis constraints; the surrounding shard_map specs govern).
    """
    ctx = active_ctx()
    if ctx is None:
        return x
    try:
        if getattr(jax.typeof(x), "vma", None):
            return x
    except Exception:
        pass
    from repro.compat import any_axis_bound
    if any_axis_bound(ctx.mesh.axis_names):
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(logical))
