"""GPipe-style pipeline parallelism over a mesh axis (default: "pod").

At two-pod scale the ``pod`` axis crosses DCN, where all-reducing every
gradient (outer data parallelism) costs a full model round-trip per step.
Pipelining over pods changes the cross-pod wire cost to ONE activation
hand-off per microbatch per boundary — for kimi-k2-class models that is
~2000x fewer DCN bytes than gradient mirroring (activations [mb,S,D]
vs 1T gradients), the textbook reason trillion-parameter fleets pipeline
across their slowest interconnect.

Mechanics (``jax.shard_map`` manual over the stage axis, auto over
data/model — GSPMD keeps doing TP/FSDP *inside* each stage):

* the stacked layer-group params ``blocks`` [G, ...] are sharded over the
  stage axis (G/S groups per stage) — that IS the pipeline placement;
* the batch is split into M microbatches; a ``lax.scan`` runs
  T = M + S - 1 ticks; each tick applies this stage's layer groups to its
  current activation and ``ppermute``s the result to the next stage;
* stage 0 injects microbatch t on tick t (t < M); the last stage's
  outputs for ticks >= S-1 are the pipeline's outputs, gathered with a
  one-hot mask + psum over the stage axis (bubble fraction
  (S-1)/(M+S-1), the GPipe schedule);
* ``jax.grad`` differentiates straight through: the AD transpose of
  ``ppermute`` is the reverse permute, so the backward pipeline runs
  automatically in the opposite direction.

Embedding / final-norm / unembed run replicated across stages outside the
shard_map (negligible compute; GSPMD dedups).  Scope: the decoder-only
("dense"/"moe"-family) stack with TP/ZeRO-1 storage — heterogeneous
stacks (zamba2's shared block, whisper's encoder) and FSDP-stored archs
keep the pod axis as data parallelism (GSPMD's partial-manual mode
re-replicates FSDP-sharded operand dims entering the shard_map, which
defeats FSDP; a known sharp edge of mixing manual stage placement with
auto parameter sharding).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.common import ModelConfig
from repro.models.layers import apply_norm
from repro.models.transformer import _apply_block, _positions_embed, program_for

__all__ = ["make_pp_forward", "pp_lm_loss"]


def _split_microbatches(x, n):
    B = x.shape[0]
    assert B % n == 0, (B, n)
    return x.reshape(n, B // n, *x.shape[1:])


def make_pp_forward(cfg: ModelConfig, mesh, n_microbatches: int,
                    stage_axis: str = "pod"):
    """Returns forward(params, tokens) -> final hidden states [B, S, D],
    pipelined over ``stage_axis``.  Requires a homogeneous decoder stack
    (program remainder empty) whose group count divides the stage count.
    """
    grp, n_groups, rem = program_for(cfg)
    assert not rem, "PP needs a homogeneous stack (no remainder groups)"
    S = mesh.shape[stage_axis]
    assert n_groups % S == 0, (n_groups, S)
    M = n_microbatches

    def stage_body(blocks_local, x_mb):
        """Run this stage's layer groups on one microbatch activation."""
        def group_body(carry, gp):
            x, aux = carry
            for i, kind in enumerate(grp):
                p = gp[f"b{i}_{kind}"]
                x, aux = _apply_block(cfg, kind, p, x, None, aux, None)
            return (x, aux), None
        if cfg.remat != "none":
            group_body = jax.checkpoint(group_body)
        (x, aux), _ = jax.lax.scan(group_body, (x_mb, jnp.float32(0.0)),
                                   blocks_local)
        return x, aux

    def pipelined(blocks_local, xs_mb):
        """shard_map body: manual over stage_axis.

        blocks_local: this stage's [G/S, ...] params.
        xs_mb: [M, mb, S, D] embedded microbatches (same on every stage).
        Returns (y [T-S+1, mb, S, D] last-stage outputs, aux [1]).

        NOTE the feed enters every stage replicated: shard_map realizes
        the unvarying->varying conversion as a psum_invariant (an
        all-inputs-identical exchange).  Kept in f32 because XLA-CPU's
        bf16 AllReducePromotion pass crashes cloning copy-reducers; the
        roofline charges it as real traffic (conservative - on TPU it is
        a no-op copy).  Feeding s32 tokens and embedding inside stage 0
        would shrink it D-fold but trips an SPMD partition-grouping CHECK
        in this XLA version - revisit on a newer toolchain.
        """
        sid = jax.lax.axis_index(stage_axis)
        T = M + S - 1
        mb_shape = xs_mb.shape[1:]

        def tick(carry, t):
            inp, aux_acc = carry
            # stage 0 ingests microbatch t (zeros once the feed drains)
            feed = jnp.where(t < M, xs_mb[jnp.minimum(t, M - 1)],
                             jnp.zeros(mb_shape, xs_mb.dtype))
            x = jnp.where(sid == 0, feed.astype(jnp.float32),
                          inp.astype(jnp.float32)).astype(xs_mb.dtype)
            y, aux = stage_body(blocks_local, x)
            # hand to the next stage (last stage's send is dropped by
            # the ring edge going back to 0, which stage 0 ignores)
            nxt = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % S) for i in range(S)])
            # last stage emits microbatch t-(S-1) on tick t (y*0 keeps the
            # masked branch varying - no bf16 psum_invariant)
            emit = jnp.where(sid == S - 1, y, y * 0)
            return (nxt, aux_acc + aux), emit

        # the carry becomes stage-varying after one tick; start it varying
        # via sid arithmetic (jax.lax.pcast would also work, but its
        # copy-reducer all-reduce trips XLA-CPU's AllReducePromotion pass
        # at 512 devices)
        zero_var = (sid * 0).astype(xs_mb.dtype)
        init = (jnp.zeros(mb_shape, xs_mb.dtype) + zero_var,
                jnp.float32(0.0) + zero_var.astype(jnp.float32))
        (_, aux_total), emits = jax.lax.scan(tick, init, jnp.arange(T))
        # emits [T, mb, S, D]: valid rows are ticks S-1..T-1 on the LAST
        # stage (zeros elsewhere).  Returned stage-stacked via out_specs
        # (the caller slices the last stage's block) — an explicit psum
        # here trips XLA-CPU's AllReducePromotion pass on this shape.
        y = emits[S - 1:]
        return y, (aux_total / (M * n_groups))[None]

    def forward(params, tokens):
        x = _positions_embed(cfg, params, tokens)
        xs = _split_microbatches(x, M)
        y, aux = shard_map(
            pipelined, mesh=mesh,
            in_specs=(P(stage_axis), P()),
            out_specs=(P(stage_axis), P(stage_axis)),
            axis_names={stage_axis},
        )(params["blocks"], xs)
        y = y[-M:]                                       # last stage's block
        aux = jnp.sum(aux)                               # sum over stages
        y = y.reshape(-1, *y.shape[2:])                  # [B, S, D]
        y = apply_norm(params["final_norm"], y, cfg.norm_eps, cfg.norm,
                       cfg.norm_mult_dtype == "float32",
                       custom_bwd=bool(cfg.norm_custom_bwd))
        return y, aux

    return forward


def pp_lm_loss(params: dict, cfg: ModelConfig, batch: dict, forward) -> jax.Array:
    """Next-token loss on the pipelined forward (mirrors lm_loss)."""
    y, aux = forward(params, batch["tokens"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", y, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", y, params["unembed"])
    with jax.named_scope("f32c"):
        logits = logits.astype(jnp.float32)[:, :-1]
        targets = batch["tokens"][:, 1:]
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(targets, cfg.vocab_size, dtype=jnp.float32)
        nll = jnp.mean(lse - jnp.sum(logits * onehot, axis=-1))
    return nll + 0.01 * aux
