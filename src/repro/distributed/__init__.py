"""repro.distributed"""
