"""Pallas TPU chunked SSD (Mamba2) forward scan.

One kernel instance owns a block of SSD heads for one batch element and
walks the sequence chunk by chunk (grid k-axis sequential on TPU), so the
recurrent state h [Hb, P, N] lives in f32 VMEM scratch for the whole
sequence — the HBM I/O is bf16 x/B/C in, bf16 y out, exactly the dtype
contract the roofline walker assumes for the SSD math (DESIGN.md §6).

  x tile    [Q, Hb, P]   VMEM (bf16 in, f32 compute)
  dt tile   [Q, Hb]      VMEM f32
  B,C tile  [Q, N]       VMEM
  h state   [Hb, P, N]   VMEM scratch, f32, persists across chunks
  L matrix  [Q, Q] per head block — registers/VMEM temporaries

Within a chunk the standard SSD decomposition:
  y = (C·Bᵀ ∘ L) · (dt·x)  +  (C · h_in) ∘ exp(cum)        (intra + inter)
  h_out = h_in * exp(total) + Bᵀ · (dt·x ∘ exp(total-cum))
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["ssm_scan_bh"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
            chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, Hb, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q, Hb]
    A = a_ref[0].astype(jnp.float32)          # [Hb]  (negative)
    Bm = b_ref[0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)         # [Q, N]

    dA = dt * A[None, :]                      # [Q, Hb]
    cum = jnp.cumsum(dA, axis=0)              # [Q, Hb]
    total = cum[-1:, :]                       # [1, Hb]

    # decay matrix L per head: L[q, k, h] = exp(cum_q - cum_k) for k <= q
    li = cum[:, None, :] - cum[None, :, :]    # [Q, Q, Hb]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (kj <= qi)[:, :, None]
    L = jnp.where(mask, jnp.exp(li), 0.0)     # [Q, Q, Hb]

    scores = jax.lax.dot_general(              # [Q, Q] = C · Bᵀ
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    xdt = x * dt[:, :, None]                  # [Q, Hb, P]

    w = scores[:, :, None] * L                # [Q, Q, Hb]
    y_intra = jnp.einsum("qkh,khp->qhp", w, xdt)

    h = h_ref[...]                            # [Hb, P, N]
    y_inter = jnp.einsum("qn,hpn->qhp", Cm, h) * jnp.exp(cum)[:, :, None]

    decay_in = jnp.exp(total - cum)           # [Q, Hb]
    upd = jnp.einsum("kn,khp->hpn", Bm, xdt * decay_in[:, :, None])
    h_ref[...] = h * jnp.exp(total)[0, :, None, None] + upd

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_bh(
    x: jax.Array,            # [BH_blocks? -> B, S, Hb, P] flattened below
    dt: jax.Array,           # [B, S, Hb] f32
    A: jax.Array,            # [B, Hb] f32 (negative; per-block slice)
    Bm: jax.Array,           # [B, S, N]
    Cm: jax.Array,           # [B, S, N]
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, Hb, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, Hb, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, Hb), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Hb), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, Hb, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, Hb, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((Hb, P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
