"""Pure-jnp oracle for the SSD scan kernel: the sequential recurrence
   h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t ⊗ x_t ;   y_t = C_t · h_t
(the chunked form in ``repro.models.ssm._ssd_chunked`` is itself
validated against this same recurrence in tests/test_models_smoke.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssm_scan_ref"]


def ssm_scan_ref(x, dt, A, Bm, Cm):
    """x [B,S,H,P]; dt [B,S,H] f32; A [B,H]; Bm/Cm [B,S,N] -> [B,S,H,P]."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # [B,H,P], [B,H], [B,N], [B,N]
        dec = jnp.exp(dtt * Af)                   # [B,H]
        upd = jnp.einsum("bn,bh,bhp->bhpn", bt, dtt, xt)
        h = h * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
