"""jit'd public wrapper for the SSD scan kernel.

Splits the SSD heads into VMEM-sized blocks (state [Hb, P, N] f32 must
fit scratch alongside the [Q, Q, Hb] decay tensor), pads the sequence to
the chunk size (zero dt ⇒ identity state update, zero C ⇒ zero output:
padding is exact), and runs one pallas_call per head block.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .kernel import ssm_scan_bh

__all__ = ["ssm_scan"]


def ssm_scan(
    x: jax.Array,            # [B, S, H, P]
    dt: jax.Array,           # [B, S, H] (f32 or bf16)
    A: jax.Array,            # [H] f32 (negative)
    Bm: jax.Array,           # [B, S, N]
    Cm: jax.Array,           # [B, S, N]
    *,
    chunk: int = 128,
    head_block: int = 8,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, P = x.shape
    pad_s = (-S) % chunk
    if pad_s:
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_s), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_s), (0, 0)))
    Sp = S + pad_s

    hb = min(head_block, H)
    assert H % hb == 0, (H, hb)
    outs = []
    Ab = jnp.broadcast_to(A[None, :], (B, H)).astype(jnp.float32)
    for h0 in range(0, H, hb):
        sl = slice(h0, h0 + hb)
        outs.append(ssm_scan_bh(
            x[:, :, sl, :], dt[:, :, sl].astype(jnp.float32),
            Ab[:, sl], Bm, Cm, chunk=chunk, interpret=interpret))
    y = jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]
    return y[:, :S]
