"""Pallas TPU kernels for the framework's compute hot spots.

MDTP itself has no kernel-level contribution (it is a data-plane protocol,
DESIGN.md §2); these kernels serve the assigned architectures' hot paths:

* ``flash_attention`` — online-softmax attention (causal/window/GQA); the
  fix for the XLA-materialized-scores HBM traffic the roofline flags as the
  dominant memory term on attention archs.
* ``decode_attention`` — one-token GQA attention against a long KV cache
  (scalar-prefetched position, block skipping for sliding windows); the
  decode_32k / long_500k serving hot loop.
* ``ssm_scan`` — chunked SSD (Mamba2) forward: bf16 HBM I/O with the f32
  reference math kept in VMEM (the dtype contract DESIGN.md §6 assumes).
* ``rmsnorm`` — fused residual+norm (memory-bound glue layer).

Validated in interpret mode against the pure-jnp oracles (ref.py) across
shape/dtype sweeps; selected on real TPUs via ``attn_impl="pallas"``.
"""

from .decode_attention import decode_attention, decode_attention_ref
from .flash_attention import attention_ref, flash_attention
from .rmsnorm import rmsnorm, rmsnorm_ref
from .ssm_scan import ssm_scan, ssm_scan_ref

__all__ = ["flash_attention", "attention_ref", "rmsnorm", "rmsnorm_ref",
           "decode_attention", "decode_attention_ref",
           "ssm_scan", "ssm_scan_ref"]
