"""Pure-jnp oracle for the flash-attention kernel.

Plain materialized-softmax attention with GQA grouping, causal and
sliding-window masks — the correctness reference every kernel variant is
allclose-checked against (tests/test_kernels.py sweeps shapes/dtypes).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,            # [B, Sq, H, hd]
    k: jax.Array,            # [B, Sk, KV, hd]
    v: jax.Array,            # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    valid = jnp.ones((Sq, Sk), bool)
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > (q_pos - window)
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (can happen with tiny windows): define as zeros
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
