"""jit'd public wrapper around the flash-attention Pallas kernel.

Handles layout ([B,S,H,hd] model convention -> [B*H,S,hd] kernel
convention), MXU lane padding of head_dim (zero columns are exact for
q/k/v), and sequence padding to the block size (masked through ``kv_len``).

``interpret=True`` executes the kernel body in Python on CPU — the
correctness path in this container; on a real TPU the same call compiles
to a Mosaic kernel.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd

__all__ = ["flash_attention"]

_LANES = 128


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(
    q: jax.Array,            # [B, Sq, H, hd]
    k: jax.Array,            # [B, Sk, KV, hd]
    v: jax.Array,            # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    blk_q = min(blk_q, max(8, Sq))
    blk_k = min(blk_k, max(8, Sk))

    # layout: [B,S,N,hd] -> [B*N, S, hd]
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)

    # MXU lane padding for head_dim (kimi hd=112 -> 128)
    qt = _pad_to(qt, 2, _LANES)
    kt = _pad_to(kt, 2, _LANES)
    vt = _pad_to(vt, 2, _LANES)

    # sequence padding to block multiples; padded keys masked via kv_len
    qt = _pad_to(qt, 1, blk_q)
    kt = _pad_to(kt, 1, blk_k)
    vt = _pad_to(vt, 1, blk_k)

    out = flash_attention_bhsd(
        qt, kt, vt, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, kv_len=Sk, interpret=interpret)

    out = out[:, :Sq, :hd].reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return out
