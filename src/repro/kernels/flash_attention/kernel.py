"""Pallas TPU flash attention: online-softmax, causal + sliding window + GQA.

Tiling: grid = (B*H, Sq/blk_q, Sk/blk_k), innermost (k) axis sequential on
TPU so the online-softmax state lives in VMEM scratch across k-steps:

  q tile   [blk_q, hd]        VMEM (revisited for every k step)
  k,v tile [blk_k, hd]        VMEM
  acc      [blk_q, hd]  f32   VMEM scratch
  m, l     [blk_q, 128] f32   VMEM scratch (row stats, lane-replicated)

Causal/window masking is done with block-index arithmetic; fully-masked
k-blocks skip their matmuls via ``pl.when`` (on real TPUs this saves the
MXU issue; the VMEM streaming of the skipped tile is hidden by the grid
pipeline).  hd is padded to the 128-lane MXU width by ``ops.py`` when
needed (e.g. kimi's hd=112) — zero columns are exact for q/k/v.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_bhsd"]

_NEG_INF = -1e30
_LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, blk_q, blk_k, n_k, kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * blk_q
    k_start = ki * blk_k

    # block-level reachability: skip blocks fully above the causal diagonal
    # or fully left of the window
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + blk_q - 1)
    if window is not None:
        # newest k needed for the oldest q in this tile
        run = jnp.logical_and(run, k_start + blk_k > q_start - (window - 1))

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [blk_q, blk_k]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        valid = k_pos < kv_len
        if causal:
            valid = jnp.logical_and(valid, k_pos <= q_pos)
        if window is not None:
            valid = jnp.logical_and(valid, k_pos > q_pos - window)
        s = jnp.where(valid, s, _NEG_INF)

        m_prev = m_ref[...]                                # [blk_q, 128]
        row_max = jnp.max(s, axis=1, keepdims=True)        # [blk_q, 1]
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(row_max, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])      # [blk_q, 1]
        p = jnp.exp(s - m_new[:, :1])                      # [blk_q, blk_k]
        p = jnp.where(valid, p, 0.0)

        l_new = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), l_ref.shape)
        v_blk = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _emit():
        l = l_ref[:, :1]
        o_ref[0] = jnp.where(
            l > 0.0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "blk_q", "blk_k",
                     "kv_len", "interpret"),
)
def flash_attention_bhsd(
    q: jax.Array,            # [BH, Sq, hd]
    k: jax.Array,            # [BKV, Sk, hd]
    v: jax.Array,            # [BKV, Sk, hd]
    *,
    scale: float,
    causal: bool = True,
    window=None,
    blk_q: int = 128,
    blk_k: int = 128,
    kv_len=None,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, Sk, blk_q, blk_k)
    n_q, n_k = Sq // blk_q, Sk // blk_k
    kv_len = Sk if kv_len is None else kv_len

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_k=n_k, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, _LANES), jnp.float32),   # m
            pltpu.VMEM((blk_q, _LANES), jnp.float32),   # l
            pltpu.VMEM((blk_q, hd), jnp.float32),       # acc
        ],
        interpret=interpret,
    )(q, k, v)
