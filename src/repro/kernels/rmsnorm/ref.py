"""Pure-jnp oracle for the fused residual-add + RMSNorm kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_ref"]


def rmsnorm_ref(
    x: jax.Array,                       # [rows, d]
    scale: jax.Array,                   # [d]
    residual: Optional[jax.Array] = None,
    eps: float = 1e-6,
) -> jax.Array:
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)
