"""jit'd wrapper: accepts [..., d] activations, flattens rows, pads rows to
the block multiple, dispatches to the Pallas kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import rmsnorm_rows

__all__ = ["rmsnorm"]


def rmsnorm(x: jax.Array, scale: jax.Array,
            residual: Optional[jax.Array] = None, *, eps: float = 1e-6,
            blk_rows: int = 256, interpret: bool = False) -> jax.Array:
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    r2 = residual.reshape(rows, d) if residual is not None else None
    blk = min(blk_rows, rows)
    pad = (-rows) % blk
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        if r2 is not None:
            r2 = jnp.pad(r2, ((0, pad), (0, 0)))
    y = rmsnorm_rows(x2, scale, r2, eps=eps, blk_rows=blk,
                     interpret=interpret)
    return y[:rows].reshape(shape)
