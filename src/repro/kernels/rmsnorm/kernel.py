"""Pallas TPU fused residual-add + RMSNorm.

The memory-bound layer between every pair of matmuls: fusing the residual
add with normalization halves its HBM traffic (read x + res, write y once,
instead of an intermediate round-trip).  Row-blocked: each grid step
normalizes ``blk_rows`` full rows held in VMEM; f32 statistics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_rows"]


def _kernel(x_ref, s_ref, o_ref, *, eps, has_res, r_ref=None):
    xf = x_ref[...].astype(jnp.float32)
    if has_res:
        xf = xf + r_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * s_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)


def _kernel_res(x_ref, r_ref, s_ref, o_ref, *, eps):
    _kernel(x_ref, s_ref, o_ref, eps=eps, has_res=True, r_ref=r_ref)


def _kernel_nores(x_ref, s_ref, o_ref, *, eps):
    _kernel(x_ref, s_ref, o_ref, eps=eps, has_res=False)


@functools.partial(jax.jit,
                   static_argnames=("eps", "blk_rows", "interpret"))
def rmsnorm_rows(x, scale, residual=None, *, eps: float = 1e-6,
                 blk_rows: int = 256, interpret: bool = False):
    rows, d = x.shape
    blk_rows = min(blk_rows, rows)
    assert rows % blk_rows == 0, (rows, blk_rows)
    grid = (rows // blk_rows,)
    row_spec = pl.BlockSpec((blk_rows, d), lambda i: (i, 0))
    scale_spec = pl.BlockSpec((d,), lambda i: (0,))
    if residual is not None:
        return pl.pallas_call(
            functools.partial(_kernel_res, eps=eps),
            grid=grid,
            in_specs=[row_spec, row_spec, scale_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
            interpret=interpret,
        )(x, residual, scale)
    return pl.pallas_call(
        functools.partial(_kernel_nores, eps=eps),
        grid=grid,
        in_specs=[row_spec, scale_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
