"""Pure-jnp oracle for the decode-attention kernel (mirrors the math in
``repro.models.layers.attention_from_cache``)."""

from __future__ import annotations

import jax.numpy as jnp
import jax

__all__ = ["decode_attention_ref"]

_NEG_INF = -1e30


def decode_attention_ref(q, k, v, pos, *, scale, window=None):
    """q [BKV, G, hd]; k/v [BKV, Sk, hd]; pos scalar -> [BKV, G, hd]."""
    Sk = k.shape[1]
    s = jnp.einsum("bgh,bsh->bgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kpos = jnp.arange(Sk, dtype=jnp.int32)
    valid = kpos <= pos
    if window is not None:
        valid = jnp.logical_and(valid, kpos > pos - window)
    s = jnp.where(valid[None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgs,bsh->bgh", p,
                      v.astype(jnp.float32)).astype(q.dtype)
