"""jit'd public wrapper for decode attention.

Maps the model convention (q [B,1,H,hd], caches [B,S,KV,hd], GQA) onto
the kernel convention ([B*KV, G, hd] / [B*KV, S, hd]), pads head_dim to
the 128-lane MXU width and the cache length to the block size (padded
positions are masked via ``pos``), and broadcasts KV heads.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import decode_attention_bkv

__all__ = ["decode_attention"]

_LANES = 128


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def decode_attention(
    q: jax.Array,               # [B, 1, H, hd]
    k_cache: jax.Array,         # [B, S_max, KV, hd]
    v_cache: jax.Array,         # [B, S_max, KV, hd]
    pos: jax.Array,             # scalar int32
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    blk_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    B, _, H, hd = q.shape
    S_max, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if scale is None:
        scale = 1.0 / math.sqrt(hd)

    qg = q[:, 0].reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kk = k_cache.transpose(0, 2, 1, 3).reshape(B * KV, S_max, hd)
    vv = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, S_max, hd)

    qg = _pad_axis(qg, 2, _LANES)
    kk = _pad_axis(_pad_axis(kk, 2, _LANES), 1, blk_k)
    vv = _pad_axis(_pad_axis(vv, 2, _LANES), 1, blk_k)

    out = decode_attention_bkv(
        qg, kk, vv, pos, scale=scale, window=window, blk_k=blk_k,
        interpret=interpret)
    out = out[:, :, :hd].reshape(B, KV, G, hd).reshape(B, 1, H, hd)
    return out.astype(q.dtype)
