"""Pallas TPU decode attention: one new token vs a long KV cache (GQA).

The decode hot loop is pure HBM streaming: every step reads the whole
valid cache once.  The kernel tiles the cache over k-blocks and keeps the
online-softmax state for the G query heads of one (batch, kv-head) pair
in VMEM scratch:

  q tile   [G_pad, hd]        VMEM (all query heads of this kv head)
  k,v tile [blk_k, hd]        VMEM (streamed)
  acc      [G_pad, hd]  f32   VMEM scratch
  m, l     [G_pad, 128] f32   VMEM scratch (row stats, lane-replicated)

grid = (B*KV, Sk/blk_k) with the k axis innermost (sequential on TPU, so
scratch persists across k steps).  The current position arrives via
scalar prefetch; blocks entirely past ``pos`` (or, with a sliding
window, entirely before ``pos - window``) skip their work with
``pl.when`` — for gemma3's window=1024 against a 32k cache that is 97%
of blocks skipped, turning O(S_max) streaming into O(window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["decode_attention_bkv"]

_NEG_INF = -1e30
_LANES = 128


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, window, blk_k, n_k):
    ki = pl.program_id(1)
    pos = pos_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * blk_k
    run = k_start <= pos
    if window is not None:
        run = jnp.logical_and(run, k_start + blk_k > pos - (window - 1))

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # [G, hd]
        k = k_ref[0].astype(jnp.float32)                 # [blk_k, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [G, blk_k]

        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos <= pos
        if window is not None:
            valid = jnp.logical_and(valid, kpos > pos - window)
        s = jnp.where(valid, s, _NEG_INF)

        m_prev = m_ref[:, 0:1]                           # [G, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                  # [G, 1]
        p = jnp.exp(s - m_new)                           # [G, blk_k]
        l_new = l_ref[:, 0:1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                 # [blk_k, hd]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # [G, hd]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_k - 1)
    def _fini():
        l = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "blk_k", "interpret"),
)
def decode_attention_bkv(
    q: jax.Array,            # [BKV, G, hd]   (B*KV flattened)
    k: jax.Array,            # [BKV, Sk, hd]
    v: jax.Array,            # [BKV, Sk, hd]
    pos: jax.Array,          # scalar int32: index of the newest token
    *,
    scale: float,
    window: int | None = None,
    blk_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    BKV, G, hd = q.shape
    Sk = k.shape[1]
    assert Sk % blk_k == 0, (Sk, blk_k)
    n_k = Sk // blk_k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BKV, n_k),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, j, pos_ref: (b, 0, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, j, pos_ref: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, j, pos_ref: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j, pos_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, _LANES), jnp.float32),   # m
            pltpu.VMEM((G, _LANES), jnp.float32),   # l
            pltpu.VMEM((G, hd), jnp.float32),       # acc
        ],
    )
    kernel = functools.partial(
        _kernel, scale=scale, window=window, blk_k=blk_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BKV, G, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos.reshape(1).astype(jnp.int32), q, k, v)
