"""Version-compatibility shims over drifting JAX APIs.

The repo pins one JAX, but these symbols moved across nearby releases and
the code is written against the newest spelling.  Each shim prefers the
new name and falls back to the old one, so the same source runs on either
side of the rename:

* ``pltpu.CompilerParams`` (new) vs ``pltpu.TPUCompilerParams`` (old) —
  :func:`tpu_compiler_params`.
* ``jax.shard_map`` with ``axis_names=`` (new) vs
  ``jax.experimental.shard_map.shard_map`` with ``auto=`` (old) —
  :func:`shard_map`.
* ``Compiled.cost_analysis()`` returning a dict (new) vs a one-element
  list of dicts (old) — :func:`cost_analysis_dict`.
"""

from __future__ import annotations

from typing import Any, Optional

import jax

__all__ = ["tpu_compiler_params", "shard_map", "cost_analysis_dict",
           "any_axis_bound", "axis_size"]


def axis_size(axis_name) -> Any:
    """``jax.lax.axis_size`` (new) or the bound-axis env lookup (old)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax._src import core as _core
    return _core.get_axis_env().axis_size(axis_name)


def any_axis_bound(axis_names) -> bool:
    """True when tracing inside a region where any of ``axis_names`` is a
    bound mapped axis (shard_map / pmap body).

    Old-JAX stand-in for the ``jax.typeof(x).vma`` manual-region check:
    versions without varying-manual-axes typing still record bound axis
    sizes in the trace-local axis env, which this inspects.
    """
    try:
        from jax._src import core as _core
        env = _core.get_axis_env()
        return any(env.axis_exists(a) for a in axis_names)
    except Exception:
        return False


def tpu_compiler_params(**kwargs) -> Any:
    """Build Pallas-TPU compiler params under either class name."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def shard_map(f, mesh=None, in_specs=None, out_specs=None,
              axis_names: Optional[set] = None, check_rep=None, **kwargs):
    """``jax.shard_map`` if present, else the experimental spelling.

    The new API expresses partial-manual mode as ``axis_names={...}``; the
    old one as ``auto=<complement>``.  ``check_rep`` defaults to False on
    the fallback because the old implementation cannot verify replication
    under ``auto``.
    """
    if hasattr(jax, "shard_map"):
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if check_rep is not None:
            kwargs["check_rep"] = check_rep
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map

    # Partial-auto mode (``auto=``) exists in old JAX but lowers
    # ``axis_index`` to a PartitionId op XLA's SPMD partitioner rejects, so
    # fall back to FULL manual: mesh axes outside ``axis_names`` are simply
    # not mentioned in the specs → replicated instead of auto-sharded.
    # Numerically identical; XLA loses the auto axes' sharding inside the
    # region, which only costs memory/collectives, not correctness.
    kwargs["check_rep"] = bool(check_rep) if check_rep is not None else False
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def cost_analysis_dict(compiled) -> dict:
    """Flat cost dict from ``Compiled.cost_analysis()`` on any version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})
