"""Training-data pipeline: deterministic sampling + MDTP multi-source fetch.

Layout: a dataset is a flat token stream packed into ``tokens.bin``
(uint32) plus ``index.json`` ({"n_tokens": N}).  The stream is replicated
on R mirror stores.  Global batch for step ``s`` is rows
``[(s*B + i) * S, ... + S + 1)`` (wrap-around) — a pure function of the
step, so:

* resume-after-failure needs NO pipeline state (checkpoint stores only the
  step counter),
* every host can compute exactly which byte ranges it needs and fetch them
  from all mirrors at once with MDTP adaptive chunking,
* a slow mirror degrades throughput proportionally instead of stalling the
  step (the paper's §VII-D claim, now as an input pipeline property).

``MultiSourcePipeline`` prefetches ``depth`` steps ahead on a background
thread (transfer hides behind compute — straggler mitigation for the input
plane).
"""

from __future__ import annotations

import asyncio
import json
import queue
import threading
import time
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.chunking import ChunkParams
from repro.transfer.client import MDTPClient, Replica

__all__ = ["write_token_dataset", "TokenDatasetSpec", "MultiSourcePipeline",
           "synthetic_tokens"]

_TOKENS = "tokens.bin"
_INDEX = "index.json"
_ITEM = 4  # uint32


def synthetic_tokens(n_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=n_tokens, dtype=np.uint32)


def write_token_dataset(path_prefix, tokens: np.ndarray) -> dict:
    """Returns {name: bytes} blobs for RangeServer mirrors (or write to disk
    by passing a directory path)."""
    blob = tokens.astype(np.uint32).tobytes()
    index = json.dumps({"n_tokens": int(tokens.size)}).encode()
    blobs = {_TOKENS: blob, _INDEX: index}
    if path_prefix is not None:
        import os
        os.makedirs(path_prefix, exist_ok=True)
        for name, data in blobs.items():
            with open(os.path.join(path_prefix, name), "wb") as f:
                f.write(data)
    return blobs


@dataclass(frozen=True)
class TokenDatasetSpec:
    n_tokens: int
    seq_len: int
    global_batch: int

    def ranges_for_step(self, step: int, host: int = 0,
                        n_hosts: int = 1) -> list[tuple[int, int]]:
        """Byte ranges (start, length) of this host's rows at ``step``."""
        B, S = self.global_batch, self.seq_len
        assert B % n_hosts == 0
        rows = range(host * (B // n_hosts), (host + 1) * (B // n_hosts))
        out = []
        for i in rows:
            tok_start = ((step * B + i) * S) % max(self.n_tokens - S - 1, 1)
            out.append((tok_start * _ITEM, (S + 1) * _ITEM))
        return out


class MultiSourcePipeline:
    """Prefetching input pipeline over replicated mirrors.

    Each ``get_batch(step)`` returns tokens [B_host, S+1] uint32 (callers
    slice inputs/labels).  Fetches ride MDTP: the per-step ranges are
    coalesced into one logical transfer split across mirrors by observed
    throughput.
    """

    def __init__(
        self,
        replicas: Sequence[Replica],
        spec: TokenDatasetSpec,
        host: int = 0,
        n_hosts: int = 1,
        depth: int = 2,
        params: Optional[ChunkParams] = None,
    ):
        self.replicas = [Replica(r.host, r.port,
                                 r.path.rstrip("/") + "/" + _TOKENS)
                         for r in replicas]
        self.spec = spec
        self.host = host
        self.n_hosts = n_hosts
        self.params = params
        self.depth = depth
        self._results: dict[int, np.ndarray] = {}
        self._errors: dict[int, Exception] = {}
        self._lock = threading.Condition()
        self._want = queue.Queue()
        self._stop = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        self._next_prefetch = 0

    # ------------------------------------------------------------------
    def _fetch_step(self, step: int) -> np.ndarray:
        ranges = self.spec.ranges_for_step(step, self.host, self.n_hosts)
        B_host = len(ranges)
        S1 = self.spec.seq_len + 1
        out = np.empty((B_host, S1), np.uint32)

        async def run():
            # Coalesce the step's rows into one MDTP transfer: a virtual
            # blob of concatenated row-ranges, written through a sink that
            # scatters into the batch array.
            total = sum(l for _, l in ranges)
            row_starts = np.cumsum([0] + [l for _, l in ranges])

            # map virtual offset -> (row, within)
            def sink(voff: int, data: bytes):
                pos = voff
                dview = memoryview(data)
                while dview:
                    row = int(np.searchsorted(row_starts, pos, "right") - 1)
                    within = pos - row_starts[row]
                    take = min(len(dview), int(row_starts[row + 1] - pos))
                    raw = out[row].view(np.uint8)
                    raw[within:within + take] = np.frombuffer(
                        dview[:take], np.uint8)
                    pos += take
                    dview = dview[take:]

            client = _VirtualRangeClient(self.replicas, ranges, self.params)
            await client.fetch(total, sink)

        asyncio.run(run())
        return out

    def _worker(self):
        while not self._stop:
            try:
                step = self._want.get(timeout=0.2)
            except queue.Empty:
                continue
            if step is None:
                return
            try:
                batch = self._fetch_step(step)
                with self._lock:
                    self._results[step] = batch
                    self._lock.notify_all()
            except Exception as e:                       # pragma: no cover
                with self._lock:
                    self._errors[step] = e
                    self._lock.notify_all()

    def get_batch(self, step: int, timeout: float = 120.0) -> np.ndarray:
        # keep the prefetch window ahead of the consumer
        while self._next_prefetch <= step + self.depth:
            self._want.put(self._next_prefetch)
            self._next_prefetch += 1
        with self._lock:
            ok = self._lock.wait_for(
                lambda: step in self._results or step in self._errors,
                timeout=timeout)
            if not ok:
                raise TimeoutError(f"batch for step {step} not ready")
            if step in self._errors:
                raise self._errors.pop(step)
            batch = self._results.pop(step)
        return batch

    def close(self):
        self._stop = True
        self._want.put(None)
        self._thread.join(timeout=2.0)


class _VirtualRangeClient(MDTPClient):
    """MDTPClient over a *virtual* blob made of scattered file ranges.

    The allocator sees one contiguous [0, total) space; fetch_range calls
    are translated to the real file offsets (splitting requests that span
    row boundaries — each piece is still one HTTP range on the same
    persistent session).
    """

    def __init__(self, replicas, ranges, params=None):
        super().__init__(replicas, params=params)
        self._ranges = ranges
        self._starts = np.cumsum([0] + [l for _, l in ranges])

    def _make_conn(self, replica):
        from repro.transfer.client import _Conn, _RangeReply
        outer = self

        class _VConn(_Conn):
            async def fetch_range(conn_self, start, end, into=None,
                                  progress=None):
                parts = []
                nbytes, elapsed, rtt_inc = 0, 0.0, False
                if progress is not None and len(progress) > 1:
                    # wire-send stamp (see _Conn.fetch_range): the first
                    # piece's request goes out immediately below
                    progress[1] = time.monotonic()
                pos = start
                while pos <= end:
                    row = int(np.searchsorted(outer._starts, pos, "right") - 1)
                    row_off = pos - outer._starts[row]
                    real_start = outer._ranges[row][0] + row_off
                    take = min(end - pos + 1,
                               int(outer._starts[row + 1] - pos))
                    sub = (into[nbytes:nbytes + take]
                           if into is not None else None)
                    reply = await _Conn.fetch_range(
                        conn_self, int(real_start),
                        int(real_start + take - 1), into=sub)
                    if into is None:
                        parts.append(reply.data)
                    nbytes += reply.nbytes
                    if progress is not None:
                        # piece-grained: good enough for the hedging
                        # layer's landed-fraction check
                        progress[0] = nbytes
                    elapsed += reply.elapsed
                    rtt_inc = rtt_inc or reply.rtt_included
                    if reply.nbytes < take:
                        break   # short piece: stop — later pieces would
                        # land at the wrong virtual offsets
                    pos += take
                data = (into[:nbytes] if into is not None
                        else b"".join(parts))
                return _RangeReply(data, nbytes, elapsed, rtt_inc)

        return _VConn(replica, request_latency=self.request_latency)
