"""Data plane: deterministic token datasets + MDTP multi-source pipeline."""

from .pipeline import (MultiSourcePipeline, TokenDatasetSpec, synthetic_tokens,
                       write_token_dataset)

__all__ = ["MultiSourcePipeline", "TokenDatasetSpec", "synthetic_tokens",
           "write_token_dataset"]
