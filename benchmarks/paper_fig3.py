"""Paper Fig. 3: +0.5 s latency on the fastest server (64 GB).

The paper reports near-zero deltas (+2.42 s MDTP, +2.0 s Aria2, +6.75 s
static, disk excluded).  Per-packet 0.5 s latency is physically inconsistent
with those numbers given ~200 sequential range requests (each request turn
costs >= 1 RTT on a non-pipelined HTTP session), so we report BOTH
interpretations:

* ``connect`` — latency charged once per session (paper-scale deltas);
* ``request`` — latency charged per request turn (physics; deltas larger,
  but the paper's *ordering* — MDTP least affected, static chunking most —
  is what the figure demonstrates and what we assert).

See EXPERIMENTS.md §Reproduction for the full analysis.
"""

from __future__ import annotations

import argparse

from .common import GB, emit, run_cells
from repro.core.scenarios import paper_baseline, with_added_latency
from repro.core.simulator import ServerSpec


def _with_connect_latency(servers, extra: float):
    fastest = max(range(len(servers)), key=lambda i: servers[i].bandwidth)
    return [
        ServerSpec(name=s.name, bandwidth=s.bandwidth, rtt=s.rtt,
                   connect_latency=(extra if i == fastest else 0.0),
                   profile=s.profile, jitter=s.jitter)
        for i, s in enumerate(servers)
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-gb", type=int, default=64)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--latency", type=float, default=0.5)
    args = ap.parse_args(argv)

    base = paper_baseline()
    size = args.size_gb * GB
    protos = ("mdtp", "static", "aria2")

    baseline = {}
    for proto in protos:
        baseline[proto], _ = run_cells(
            f"fig3/base/{proto}/{args.size_gb}GB", proto, base, size, args.reps
        )

    for label, servers in (
        ("connect", _with_connect_latency(base, args.latency)),
        ("request", with_added_latency(base, args.latency)),
    ):
        for proto in protos:
            mean, _ = run_cells(
                f"fig3/+{args.latency}s_{label}/{proto}/{args.size_gb}GB",
                proto, servers, size, args.reps,
            )
            emit(
                f"fig3/delta_{label}/{proto}/{args.size_gb}GB", 0.0,
                f"{mean - baseline[proto]:+.2f}",
            )


if __name__ == "__main__":
    main()
