"""Fault-recovery economics: managed re-fetch vs restart-from-zero.

The robustness layer's headline claim is that per-chunk integrity
verification plus banned-range re-pooling makes corruption CHEAP: only
the corrupt ranges are re-fetched (from an alternate mirror), so a
chronically corrupting path costs a few chunks of overhead, not a
restart.  This bench measures that claim on real loopback sockets:

``faults/corruption/clean``
    Reference: the same fleet and geometry with no fault injection.

``faults/corruption/managed``
    Two deterministic token-bucket mirrors, one corrupting 5% of bodies
    (``FaultPolicy(corrupt_rate=0.05)``, fixed seed).  The client
    verifies per-chunk CRCs and re-pools mismatches banned-for-that-
    replica — one transfer, integrity-checked end to end.

``faults/corruption/restart``
    The naive baseline: integrity checked only at the END (whole-file
    hash), and any mismatch restarts the ENTIRE transfer — what a
    single-source client with a trailing checksum does.  Wall time
    accumulates across attempts until a clean run lands.

Derived column = goodput in MB/s (delivered bytes / total wall).  Every
server uses a fixed fault seed and deterministic pacing, so rows are
load-independent perf signal: ``benchmarks/run.py --check`` guards them
at 3x and additionally requires managed goodput >= restart goodput (the
corruption win-guard).  Rows land in ``BENCH_online.json``.
"""

from __future__ import annotations

import argparse
import hashlib
import time

import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.chunking import ChunkParams
from repro.transfer import (FaultPolicy, RangeServer, Replica, Throttle,
                            fetch_blob)

MB = 1024 * 1024

#: per-body corruption probability on the tainted mirror.
CORRUPT_RATE = 0.05
#: restart-from-zero safety valve — deterministic seeds land a clean run
#: long before this, but a bound keeps a misconfigured run finite.
MAX_RESTARTS = 25


def _blob(size: int) -> bytes:
    rng = np.random.default_rng(13)
    return rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()


def _fleet(blob, *, corrupt: bool, seed: int):
    """Two 30 MiB/s deterministic mirrors; the first optionally corrupts
    ``CORRUPT_RATE`` of its bodies.  Fresh servers per measurement so the
    fault RNG replays the same draw sequence every rep."""
    servers = []
    for i in range(2):
        faults = (FaultPolicy(corrupt_rate=CORRUPT_RATE, seed=seed)
                  if corrupt and i == 0 else None)
        s = RangeServer(
            throttle=Throttle(bytes_per_s=30 * MB, deterministic=True),
            faults=faults).start()
        s.add_blob("/data", blob)
        servers.append(s)
    return servers


def _params() -> ChunkParams:
    return ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)


def _managed(blob, *, corrupt: bool, seed: int) -> float:
    """One verified transfer; corrupt ranges re-fetch from the clean
    mirror in-flight.  Returns wall seconds."""
    servers = _fleet(blob, corrupt=corrupt, seed=seed)
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        t0 = time.perf_counter()
        data, report = fetch_blob(replicas, len(blob), params=_params(),
                                  max_failures=50)
        wall = time.perf_counter() - t0
        assert hashlib.sha256(bytes(data)).hexdigest() == \
            hashlib.sha256(blob).hexdigest(), "integrity"
        if corrupt:
            assert report.refetched_ranges >= 1 or \
                sum(report.corrupt_ranges.values()) >= 1
        return wall
    finally:
        for s in servers:
            s.stop()


def _restart_from_zero(blob, *, seed: int) -> float:
    """Trailing-checksum baseline: no per-chunk verification, whole-file
    hash at the end, full restart on mismatch.  Returns cumulative wall
    seconds until a clean attempt."""
    servers = _fleet(blob, corrupt=True, seed=seed)
    want = hashlib.sha256(blob).hexdigest()
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        t0 = time.perf_counter()
        for _ in range(MAX_RESTARTS):
            data, _ = fetch_blob(replicas, len(blob), params=_params(),
                                 verify_integrity=False, max_failures=50)
            if hashlib.sha256(bytes(data)).hexdigest() == want:
                return time.perf_counter() - t0
        raise RuntimeError(f"no clean run in {MAX_RESTARTS} restarts")
    finally:
        for s in servers:
            s.stop()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes/reps (CI check mode)")
    args = ap.parse_args(argv)

    size = 16 * MB if args.quick else 64 * MB
    reps = 2 if args.quick else 5
    blob = _blob(size)

    for name, fn in (
        ("faults/corruption/clean",
         lambda s: _managed(blob, corrupt=False, seed=s)),
        ("faults/corruption/managed",
         lambda s: _managed(blob, corrupt=True, seed=s)),
        ("faults/corruption/restart",
         lambda s: _restart_from_zero(blob, seed=s)),
    ):
        walls = [fn(17) for _ in range(reps)]
        mean = float(np.mean(walls))
        emit(name, mean * 1e6, size / mean / MB)


if __name__ == "__main__":
    main()
