"""Data-plane goodput: pipelined vs serial, zero-copy vs copy, on real
loopback sockets.

Measures the actual ``MDTPClient`` runtime (raw-socket HTTP/1.1 against
in-process ``RangeServer`` mirrors), not the simulator:

``dataplane/loopback/{1rep,3rep}/*``
    Unthrottled loopback assembly goodput for the three receive paths —
    ``copy_serial`` (depth-1, legacy ``bytes``-materializing path),
    ``zerocopy_serial`` (depth-1, ``sock_recv_into`` the destination
    buffer), ``zerocopy_pipelined`` (depth-4).  Loopback has no RTT, so
    these rows isolate the per-chunk memcpy cost; wall time is CPU-bound
    and machine-dependent (informational, not perf-guarded).

``dataplane/highrtt/{serial,pipelined,duplex}``
    The headline: a WAN-like trace — deterministic token-bucket mirrors
    plus an emulated 30 ms request-path latency
    (``MDTPClient(request_latency=...)``; loopback itself has none).
    Serial pays the latency once per chunk; the pipelined
    (half-duplex, ``duplex=False``) client keeps depth requests in
    flight but each request write still waits its turn behind in-flight
    bodies on the shared connection; the duplex client's independent
    writer coroutine puts successors' requests on the wire while bodies
    stream.  Deterministic pacing makes these wall times
    load-independent, so the rows ARE stable perf signal:
    ``benchmarks/run.py --check`` guards them at 3x and additionally
    requires pipelined goodput >= serial AND duplex >= pipelined (the
    win-guards).

``dataplane/compressed/{raw,zblock,wire_ratio}``
    The compressed-range dataplane on a wire-limited trace: the same
    compressible blob served identity vs block-compressed
    (``RangeServer.add_compressed_blob``) over identically throttled
    mirrors.  The throttle meters WIRE bytes, so the zblock goodput win
    is the compression ratio, to framing overhead.  ``wire_ratio``'s
    derived column is decoded/wire bytes (``us_per_call`` = wire bytes,
    informational); ``--check`` guards it >= 1.3x — the
    goodput-per-wire-byte win on compressible payloads.

Derived column = goodput in MB/s (assembled bytes / transfer wall time);
``us_per_call`` = mean wall per transfer.  Rows land in
``BENCH_dataplane.json`` via ``python -m benchmarks.run --skip ...
--json BENCH_dataplane.json``.
"""

from __future__ import annotations

import argparse
import asyncio
import hashlib

import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.chunking import ChunkParams
from repro.transfer import MDTPClient, RangeServer, Replica, Throttle

MB = 1024 * 1024

#: emulated request-path propagation delay for the high-RTT trace (s).
HIGH_RTT = 0.03


def _blob(size: int) -> bytes:
    rng = np.random.default_rng(7)
    return rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()


def _measure(servers, blob, *, depth, zero_copy, latency, params, reps,
             duplex=True):
    """Mean (goodput_MBps, wall_us) over ``reps`` transfers; verifies
    integrity on the first rep (a fast wrong answer is no answer)."""
    replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
    elapsed = []
    for rep in range(reps):
        client = MDTPClient(
            replicas, params=params, pipeline_depth=depth,
            zero_copy=zero_copy, request_latency=latency, duplex=duplex)
        buf, report = asyncio.run(client.fetch(len(blob)))
        if rep == 0:
            assert hashlib.sha256(bytes(buf)).hexdigest() == \
                hashlib.sha256(blob).hexdigest(), "integrity"
        elapsed.append(report.elapsed)
    mean = float(np.mean(elapsed))
    return len(blob) / mean / MB, mean * 1e6


def _loopback_section(blob, params, reps, n_replicas: int):
    servers = [RangeServer().start() for _ in range(n_replicas)]
    for s in servers:
        s.add_blob("/data", blob)
    try:
        base = f"dataplane/loopback/{n_replicas}rep"
        modes = (("copy_serial", 1, False),
                 ("zerocopy_serial", 1, True),
                 ("zerocopy_pipelined", 4, True))
        serial_goodput = None
        for name, depth, zc in modes:
            goodput, us = _measure(
                servers, blob, depth=depth, zero_copy=zc, latency=0.0,
                params=params, reps=reps)
            extra = []
            if name == "zerocopy_serial" and serial_goodput:
                extra = [f"vs_copy={goodput / serial_goodput:.2f}x"]
            if name == "copy_serial":
                serial_goodput = goodput
            emit(f"{base}/{name}", us, f"{goodput:.1f}", *extra)
    finally:
        for s in servers:
            s.stop()


def _highrtt_section(blob, params, reps, depth: int):
    servers = [RangeServer(
        throttle=Throttle(bytes_per_s=40 * MB, deterministic=True)).start()
        for _ in range(2)]
    for s in servers:
        s.add_blob("/data", blob)
    try:
        serial, s_us = _measure(
            servers, blob, depth=1, zero_copy=True, latency=HIGH_RTT,
            params=params, reps=reps)
        emit("dataplane/highrtt/serial", s_us, f"{serial:.1f}",
             f"rtt={HIGH_RTT:g}")
        piped, p_us = _measure(
            servers, blob, depth=depth, zero_copy=True, latency=HIGH_RTT,
            params=params, reps=reps, duplex=False)
        emit("dataplane/highrtt/pipelined", p_us, f"{piped:.1f}",
             f"rtt={HIGH_RTT:g}", f"depth={depth}",
             f"vs_serial={piped / serial:.2f}x")
        dup, d_us = _measure(
            servers, blob, depth=depth, zero_copy=True, latency=HIGH_RTT,
            params=params, reps=reps)
        emit("dataplane/highrtt/duplex", d_us, f"{dup:.1f}",
             f"rtt={HIGH_RTT:g}", f"depth={depth}",
             f"vs_pipelined={dup / piped:.2f}x")
    finally:
        for s in servers:
            s.stop()


def _compressible_blob(size: int) -> bytes:
    """Half-entropy bytes (4 random bits each): zlib lands ~2x, the
    regime of real fp16/bf16 checkpoint payloads — compressible, but
    far from the degenerate all-zeros case."""
    rng = np.random.default_rng(11)
    return rng.integers(0, 16, size=size, dtype=np.uint8).tobytes()


def _compressed_section(size, params, reps):
    from repro.transfer import codec

    blob = _compressible_blob(size)
    # 64 KB blocks: unaligned chunk requests re-send whole covering
    # blocks, so smaller blocks keep that wire overhead marginal
    block = 64 * 1024
    store = codec.compress_blocks(blob, block)
    ratio = len(blob) / store.wire_total
    rate = 20 * MB                       # wire pace per mirror

    def mirrors(compressed: bool):
        servers = [RangeServer(throttle=Throttle(
            bytes_per_s=rate, deterministic=True)).start()
            for _ in range(2)]
        for s in servers:
            if compressed:
                s.add_compressed_blob("/data", blob, block_size=block)
            else:
                s.add_blob("/data", blob)
        return servers

    servers = mirrors(compressed=False)
    try:
        raw, r_us = _measure(servers, blob, depth=4, zero_copy=True,
                             latency=0.0, params=params, reps=reps)
    finally:
        for s in servers:
            s.stop()
    emit("dataplane/compressed/raw", r_us, f"{raw:.1f}",
         f"wire={rate // MB}MBps")
    servers = mirrors(compressed=True)
    try:
        zb, z_us = _measure(servers, blob, depth=4, zero_copy=True,
                            latency=0.0, params=params, reps=reps)
    finally:
        for s in servers:
            s.stop()
    emit("dataplane/compressed/zblock", z_us, f"{zb:.1f}",
         f"wire={rate // MB}MBps", f"vs_raw={zb / raw:.2f}x")
    emit("dataplane/compressed/wire_ratio", float(store.wire_total),
         f"{ratio:.2f}", f"decoded={len(blob)}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes (CI / tests)")
    ap.add_argument("--depth", type=int, default=4,
                    help="pipeline depth for the pipelined rows")
    args = ap.parse_args(argv)

    size = 8 * MB if args.quick else 32 * MB
    reps = 2 if args.quick else 5
    blob = _blob(size)
    params = ChunkParams(initial_chunk=512 * 1024, large_chunk=2 * MB)

    for n in (1, 3):
        _loopback_section(blob, params, reps, n)
    # the high-RTT trace needs enough bytes for a steady-state pipeline
    # (probe + endgame phases amortized); pacing-dominated, so a fixed
    # size keeps --full minutes, not tens of minutes
    _highrtt_section(_blob(24 * MB), params, reps, args.depth)
    # wire-limited compressed vs identity: also pacing-dominated; a
    # bigger blob than the RTT trace so the ramp/endgame overhead
    # (fixed cost) doesn't eat the shorter compressed transfer's win
    _compressed_section(48 * MB, params, reps)


if __name__ == "__main__":
    main()
