"""Autotuner fusion benchmark: one compiled sweep vs the per-point loop.

Measures the tentpole claim of the traced-chunk-params refactor: the whole
(C, L) × Monte-Carlo-seed grid evaluates in ONE jit-compiled device call
(`repro.core.autotune._fused_sweep`), where the old implementation paid a
fresh ``jax.jit`` trace per grid point because ``ChunkParams`` was a static
argument.  The per-point baseline below reproduces that old cost model
exactly — chunk sizes as static jit args, one compile per distinct (C, L).

Also micro-benchmarks the Python discrete-event simulator's optimized
inner loops (bisect profile/downtime lookup, heap-based reclaim pool)
against naive reference implementations kept inline here.

Rows: ``name,us_per_call,derived[,extra...]`` like every other section.
"""

from __future__ import annotations

import argparse
import functools
import heapq
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.autotune import (
    _fused_sweep, autotune_chunk_params, autotune_batch, default_grid)
from repro.core.jax_alloc import ChunkArrays
from repro.core.jax_sim import SimConfig, simulate_core
from repro.core.scenarios import GB, paper_baseline
from repro.core.simulator import ServerSpec, TransferState, simulate
from repro.core.mdtp import MDTPPolicy

MB = 1024 * 1024


# --------------------------------------------------------------------------
# Section 1: fused sweep vs per-point static-params loop
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("c", "l", "m", "mode", "config"))
def _per_point_static(bw, rtt, throttle_t, throttle_bw, seeds, file_size,
                      *, c, l, m, mode, config):
    """The OLD cost model: chunk geometry baked into the jaxpr, so every
    distinct (C, L) is its own trace + compile (seeds still vmapped)."""
    chunk = ChunkArrays(jnp.float32(c), jnp.float32(l), jnp.float32(m))

    def one(seed):
        return simulate_core(bw, rtt, throttle_t, throttle_bw, seed, chunk,
                             file_size, mode=mode, config=config).total_time

    return jax.vmap(one)(seeds)


def tuner_sweep(n_seeds: int = 8, file_gb: int = 2, n_scenarios: int = 32,
                scenario_seeds: int = 2) -> None:
    servers = paper_baseline()
    bw = jnp.asarray([s.bandwidth for s in servers], jnp.float32)
    n = bw.shape[0]
    rtt = jnp.full((n,), 0.03, jnp.float32)
    throttle_t = jnp.full((n,), jnp.inf, jnp.float32)
    throttle_bw = bw
    grid = default_grid()
    cfg = SimConfig(jitter=0.1)
    seeds = jnp.arange(n_seeds)
    file_size = jnp.float32(file_gb * GB)

    # -- baseline: per-point compile (fresh cache, like the old tuner) ----
    jax.clear_caches()
    t0 = time.perf_counter()
    base_times = []
    for c, l in grid:
        ts = _per_point_static(
            bw, rtt, throttle_t, throttle_bw, seeds, file_size,
            c=c, l=l, m=64 * 1024, mode="proportional", config=cfg)
        base_times.append(float(jnp.mean(ts)))
    t_base = time.perf_counter() - t0
    emit(f"autotune/per_point/{file_gb}GB", t_base * 1e6 / len(grid),
         f"{t_base:.3f}", f"grid={len(grid)}", f"n_seeds={n_seeds}")

    # -- fused: one compile for the whole lattice -------------------------
    jax.clear_caches()
    grid_c = jnp.asarray([c for c, _ in grid], jnp.float32)
    grid_l = jnp.asarray([l for _, l in grid], jnp.float32)
    grid_m = jnp.full((len(grid),), 64 * 1024, jnp.float32)
    t0 = time.perf_counter()
    fused = _fused_sweep(bw, rtt, throttle_t, throttle_bw, file_size,
                         grid_c, grid_l, grid_m, seeds,
                         mode="proportional", config=cfg)
    fused.block_until_ready()
    t_fused_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    fused = _fused_sweep(bw, rtt, throttle_t, throttle_bw, file_size,
                         grid_c, grid_l, grid_m, seeds,
                         mode="proportional", config=cfg)
    fused.block_until_ready()
    t_fused_warm = time.perf_counter() - t0

    emit(f"autotune/fused_cold/{file_gb}GB", t_fused_cold * 1e6 / len(grid),
         f"{t_fused_cold:.3f}", f"speedup={t_base / t_fused_cold:.1f}x")
    emit(f"autotune/fused_warm/{file_gb}GB", t_fused_warm * 1e6 / len(grid),
         f"{t_fused_warm:.3f}", f"speedup={t_base / t_fused_warm:.1f}x")

    fused_mean = np.asarray(jnp.mean(fused, axis=1))
    agree = int(np.argmin(fused_mean)) == int(np.argmin(base_times))
    emit(f"autotune/argmin_agree/{file_gb}GB", 0.0, agree)

    # -- end-to-end public API + scenario batch ---------------------------
    t0 = time.perf_counter()
    res = autotune_chunk_params([float(b) for b in bw], 0.03, file_gb * GB,
                                jitter=0.1, n_seeds=n_seeds)
    t_api = time.perf_counter() - t0
    emit(f"autotune/api/{file_gb}GB", t_api * 1e6, f"{res.predicted_time:.2f}",
         f"C={res.params.initial_chunk // MB}MB",
         f"L={res.params.large_chunk // MB}MB")

    rng = np.random.default_rng(0)
    scen = rng.uniform(5, 100, size=(n_scenarios, n)) * MB
    t0 = time.perf_counter()
    batch = autotune_batch(scen, 0.03, file_gb * GB,
                           n_seeds=scenario_seeds, jitter=0.1)
    t_batch = time.perf_counter() - t0
    cells = scen.shape[0] * len(grid) * scenario_seeds
    emit(f"autotune/batch{n_scenarios}", t_batch * 1e6 / cells,
         f"{t_batch:.3f}", f"cells={cells}",
         f"distinct_winners={len({r.params.as_triple() for r in batch})}")


# --------------------------------------------------------------------------
# Section 2: Python simulator inner-loop micro-benchmarks
# --------------------------------------------------------------------------

class _NaivePool:
    """The pre-optimization reclaim pool: list.pop(0) + full re-sort."""

    def __init__(self):
        self._pool = []

    def reclaim(self, start, length):
        self._pool.append((start, length))
        self._pool.sort()

    def allocate(self, nbytes):
        if self._pool:
            start, length = self._pool[0]
            take = min(length, nbytes)
            if take == length:
                self._pool.pop(0)
            else:
                self._pool[0] = (start + take, length - take)
            return start, take
        return 0, 0


def _pool_workload(pool_reclaim, pool_allocate, n_ops: int) -> float:
    rng = np.random.default_rng(0)
    starts = rng.integers(0, 1 << 40, size=n_ops)
    t0 = time.perf_counter()
    for i in range(n_ops):
        pool_reclaim(int(starts[i]), 1 << 20)
        if i % 2:
            pool_allocate(1 << 19)
    return time.perf_counter() - t0


def pysim_micro(n_ops: int = 20_000) -> None:
    # reclaim-pool: heap vs naive sorted list
    naive = _NaivePool()
    t_naive = _pool_workload(naive.reclaim, naive.allocate, n_ops)
    state = TransferState(file_size=1 << 50, n_servers=1)
    t_heap = _pool_workload(state.reclaim, state.allocate, n_ops)
    emit("pysim/pool_naive", t_naive * 1e6 / n_ops, f"{t_naive:.3f}")
    emit("pysim/pool_heap", t_heap * 1e6 / n_ops, f"{t_heap:.3f}",
         f"speedup={t_naive / max(t_heap, 1e-9):.1f}x")

    # profile/downtime lookup: a many-breakpoint throttled+flapping server
    profile = tuple((float(t), (50 + (t % 7) * 10) * MB)
                    for t in range(1, 200))
    spec = ServerSpec(name="s0", bandwidth=100 * MB, rtt=0.005,
                      profile=profile, avail_up=30.0, avail_down=0.2)
    peers = [ServerSpec(name=f"p{i}", bandwidth=40 * MB, rtt=0.005)
             for i in range(3)]
    t0 = time.perf_counter()
    res = simulate(MDTPPolicy(), [spec] + peers, 4 * GB, seed=0)
    t_sim = time.perf_counter() - t0
    emit("pysim/throttled_flap_4GB", t_sim * 1e6, f"{res.total_time:.2f}",
         f"chunks={len(res.chunks)}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-seeds", type=int, default=8)
    ap.add_argument("--file-gb", type=int, default=2,
                    help="Table II small-file regime by default; compile "
                         "cost is file-size independent (size is traced)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller scenario batch / micro-bench op counts")
    args = ap.parse_args(argv)
    tuner_sweep(n_seeds=args.n_seeds, file_gb=args.file_gb,
                n_scenarios=8 if args.quick else 32,
                scenario_seeds=1 if args.quick else 2)
    pysim_micro(n_ops=5_000 if args.quick else 20_000)


if __name__ == "__main__":
    main()
