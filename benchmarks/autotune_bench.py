"""Autotuner benchmarks: sweep fusion, simulator engines, Python micro.

Section 1 measures the PR-1 claim: the whole (C, L) × Monte-Carlo-seed
grid evaluates in ONE jit-compiled device call
(`repro.core.autotune._fused_sweep`), where the old implementation paid a
fresh ``jax.jit`` trace per grid point because ``ChunkParams`` was a static
argument.  The per-point baseline below reproduces that old cost model
exactly — chunk sizes as static jit args, one compile per distinct (C, L).
Both sides run the event engine so the comparison isolates fusion.

Section 2 measures the PR-2 claim: the round-synchronous engines retire a
whole round per device step instead of one chunk, so the default Table II
sweep at N=8 replicas / 1 GB runs ≥5× faster steady-state on
``engine="round"`` (and ``engine="scan"`` with a right-sized trip bound)
than on ``engine="event"``.  A regret row quantifies the approximation:
the event-engine time of the round engine's chosen (C, L) vs the event
engine's own best.

Section 3 micro-benchmarks the Python discrete-event simulator's
optimized inner loops (bisect profile/downtime lookup, heap-based reclaim
pool) against naive reference implementations kept inline here.

Rows: ``name,us_per_call,derived[,extra...]`` like every other section.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.autotune import (
    _fused_sweep, autotune_chunk_params, autotune_batch, default_grid)
from repro.core.jax_alloc import ChunkArrays
from repro.core.jax_sim import SimConfig, simulate_core
from repro.core.scenarios import GB, paper_baseline
from repro.core.simulator import ServerSpec, TransferState, simulate
from repro.core.mdtp import MDTPPolicy

MB = 1024 * 1024


# --------------------------------------------------------------------------
# Section 1: fused sweep vs per-point static-params loop
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("c", "l", "m", "mode", "config"))
def _per_point_static(bw, rtt, throttle_t, throttle_bw, seeds, file_size,
                      *, c, l, m, mode, config):
    """The OLD cost model: chunk geometry baked into the jaxpr, so every
    distinct (C, L) is its own trace + compile (seeds still vmapped)."""
    chunk = ChunkArrays(jnp.float32(c), jnp.float32(l), jnp.float32(m))

    def one(seed):
        return simulate_core(bw, rtt, throttle_t, throttle_bw, seed, chunk,
                             file_size, mode=mode, config=config).total_time

    return jax.vmap(one)(seeds)


def tuner_sweep(n_seeds: int = 8, file_gb: int = 2, n_scenarios: int = 32,
                scenario_seeds: int = 2) -> None:
    servers = paper_baseline()
    bw = jnp.asarray([s.bandwidth for s in servers], jnp.float32)
    n = bw.shape[0]
    rtt = jnp.full((n,), 0.03, jnp.float32)
    throttle_t = jnp.full((n,), jnp.inf, jnp.float32)
    throttle_bw = bw
    grid = default_grid()
    cfg = SimConfig(jitter=0.1)
    seeds = jnp.arange(n_seeds)
    file_size = jnp.float32(file_gb * GB)

    # -- baseline: per-point compile (fresh cache, like the old tuner) ----
    jax.clear_caches()
    t0 = time.perf_counter()
    base_times = []
    for c, l in grid:
        ts = _per_point_static(
            bw, rtt, throttle_t, throttle_bw, seeds, file_size,
            c=c, l=l, m=64 * 1024, mode="proportional", config=cfg)
        base_times.append(float(jnp.mean(ts)))
    t_base = time.perf_counter() - t0
    emit(f"autotune/per_point/{file_gb}GB", t_base * 1e6 / len(grid),
         f"{t_base:.3f}", f"grid={len(grid)}", f"n_seeds={n_seeds}")

    # -- fused: one compile for the whole lattice (same event engine as
    # the per-point baseline, so this isolates the fusion win) ------------
    jax.clear_caches()
    grid_c = jnp.asarray([c for c, _ in grid], jnp.float32)
    grid_l = jnp.asarray([l for _, l in grid], jnp.float32)
    grid_m = jnp.full((len(grid),), 64 * 1024, jnp.float32)
    t0 = time.perf_counter()
    fused = _fused_sweep(bw, rtt, throttle_t, throttle_bw, file_size,
                         grid_c, grid_l, grid_m, seeds,
                         mode="proportional", config=cfg, engine="event")
    fused.block_until_ready()
    t_fused_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    fused = _fused_sweep(bw, rtt, throttle_t, throttle_bw, file_size,
                         grid_c, grid_l, grid_m, seeds,
                         mode="proportional", config=cfg, engine="event")
    fused.block_until_ready()
    t_fused_warm = time.perf_counter() - t0

    emit(f"autotune/fused_cold/{file_gb}GB", t_fused_cold * 1e6 / len(grid),
         f"{t_fused_cold:.3f}", f"speedup={t_base / t_fused_cold:.1f}x")
    emit(f"autotune/fused_warm/{file_gb}GB", t_fused_warm * 1e6 / len(grid),
         f"{t_fused_warm:.3f}", f"speedup={t_base / t_fused_warm:.1f}x")

    fused_mean = np.asarray(jnp.mean(fused, axis=1))
    agree = int(np.argmin(fused_mean)) == int(np.argmin(base_times))
    emit(f"autotune/argmin_agree/{file_gb}GB", 0.0, agree)

    # -- end-to-end public API + scenario batch ---------------------------
    t0 = time.perf_counter()
    res = autotune_chunk_params([float(b) for b in bw], 0.03, file_gb * GB,
                                jitter=0.1, n_seeds=n_seeds)
    t_api = time.perf_counter() - t0
    emit(f"autotune/api/{file_gb}GB", t_api * 1e6, f"{res.predicted_time:.2f}",
         f"C={res.params.initial_chunk // MB}MB",
         f"L={res.params.large_chunk // MB}MB")

    rng = np.random.default_rng(0)
    scen = rng.uniform(5, 100, size=(n_scenarios, n)) * MB
    t0 = time.perf_counter()
    batch = autotune_batch(scen, 0.03, file_gb * GB,
                           n_seeds=scenario_seeds, jitter=0.1)
    t_batch = time.perf_counter() - t0
    cells = scen.shape[0] * len(grid) * scenario_seeds
    emit(f"autotune/batch{n_scenarios}", t_batch * 1e6 / cells,
         f"{t_batch:.3f}", f"cells={cells}",
         f"distinct_winners={len({r.params.as_triple() for r in batch})}")


# --------------------------------------------------------------------------
# Section 2: simulator engine comparison (event vs round vs scan)
# --------------------------------------------------------------------------

def engine_compare(n_replicas: int = 8, file_gb: int = 1, n_seeds: int = 8,
                   reps: int = 3) -> None:
    """Steady-state cost of the default Table II fused sweep per engine.

    The acceptance configuration of the round-synchronous-core PR: N=8
    replicas, 1 GB file, full Table II grid × ``n_seeds`` Monte-Carlo
    seeds.  All engines compute the same lattice; ``round`` retires one
    round per device step instead of one chunk (O(#rounds) trip count)
    and ``scan`` runs a fixed right-sized trip count (the vmap-friendly,
    differentiable variant).
    """
    # paper_baseline's six rates plus two mid-band paths -> N=8
    rates = [12, 14, 15, 16, 18, 25, 40, 70][:n_replicas]
    bw = jnp.asarray([r * MB for r in rates], jnp.float32)
    n = bw.shape[0]
    rtt = jnp.full((n,), 0.03, jnp.float32)
    throttle_t = jnp.full((n,), jnp.inf, jnp.float32)
    throttle_bw = bw
    grid = default_grid()
    grid_c = jnp.asarray([c for c, _ in grid], jnp.float32)
    grid_l = jnp.asarray([l for _, l in grid], jnp.float32)
    grid_m = jnp.full((len(grid),), 64 * 1024, jnp.float32)
    seeds = jnp.arange(n_seeds)
    file_size = jnp.float32(file_gb * GB)
    # scan bound: ceil(max file / min L) + 2 (every round moves >= L bytes)
    scan_rounds = int(np.ceil(file_gb * GB / min(l for _, l in grid))) + 2

    warm = {}
    for engine in ("event", "round", "scan"):
        cfg = SimConfig(jitter=0.1,
                        max_rounds=scan_rounds if engine == "scan" else 1024)

        def sweep():
            out = _fused_sweep(
                bw, rtt, throttle_t, throttle_bw, file_size,
                grid_c, grid_l, grid_m, seeds,
                mode="proportional", config=cfg, engine=engine)
            out.block_until_ready()
            return out

        t0 = time.perf_counter()
        out = sweep()                              # compile + first run
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(reps):
            out = sweep()
        warm[engine] = (time.perf_counter() - t0) / reps
        extras = [f"cold={t_cold:.3f}s", f"n={n}", f"grid={len(grid)}",
                  f"n_seeds={n_seeds}"]
        if engine != "event":
            extras.append(f"speedup={warm['event'] / warm[engine]:.1f}x")
        if engine == "scan":
            extras.append(f"max_rounds={scan_rounds}")
        emit(f"autotune/engine_{engine}/{file_gb}GBx{n}",
             warm[engine] * 1e6, f"{warm[engine] * 1e3:.1f}ms", *extras)

    # approximation quality: event-engine time of the round engine's pick
    # vs the event engine's own best (jitter-free, single seed)
    cfg0 = SimConfig()
    ev = np.asarray(_fused_sweep(
        bw, rtt, throttle_t, throttle_bw, file_size, grid_c, grid_l,
        grid_m, jnp.arange(1), mode="proportional", config=cfg0,
        engine="event"))[:, 0]
    rd = np.asarray(_fused_sweep(
        bw, rtt, throttle_t, throttle_bw, file_size, grid_c, grid_l,
        grid_m, jnp.arange(1), mode="proportional", config=cfg0,
        engine="round"))[:, 0]
    regret = (ev[int(rd.argmin())] - ev.min()) / ev.min()
    emit(f"autotune/engine_regret/{file_gb}GBx{n}", 0.0,
         f"{regret:.4f}",
         f"event_pick={grid[int(ev.argmin())][1] // MB}MB",
         f"round_pick={grid[int(rd.argmin())][1] // MB}MB",
         f"max_grid_dev={float(np.max(np.abs(ev - rd) / ev)):.4f}")


# --------------------------------------------------------------------------
# Section 3: Python simulator inner-loop micro-benchmarks
# --------------------------------------------------------------------------

class _NaivePool:
    """The pre-optimization reclaim pool: list.pop(0) + full re-sort."""

    def __init__(self):
        self._pool = []

    def reclaim(self, start, length):
        self._pool.append((start, length))
        self._pool.sort()

    def allocate(self, nbytes):
        if self._pool:
            start, length = self._pool[0]
            take = min(length, nbytes)
            if take == length:
                self._pool.pop(0)
            else:
                self._pool[0] = (start + take, length - take)
            return start, take
        return 0, 0


def _pool_workload(pool_reclaim, pool_allocate, n_ops: int) -> float:
    rng = np.random.default_rng(0)
    starts = rng.integers(0, 1 << 40, size=n_ops)
    t0 = time.perf_counter()
    for i in range(n_ops):
        pool_reclaim(int(starts[i]), 1 << 20)
        if i % 2:
            pool_allocate(1 << 19)
    return time.perf_counter() - t0


def pysim_micro(n_ops: int = 20_000) -> None:
    # reclaim-pool: heap vs naive sorted list
    naive = _NaivePool()
    t_naive = _pool_workload(naive.reclaim, naive.allocate, n_ops)
    state = TransferState(file_size=1 << 50, n_servers=1)
    t_heap = _pool_workload(state.reclaim, state.allocate, n_ops)
    emit("pysim/pool_naive", t_naive * 1e6 / n_ops, f"{t_naive:.3f}")
    emit("pysim/pool_heap", t_heap * 1e6 / n_ops, f"{t_heap:.3f}",
         f"speedup={t_naive / max(t_heap, 1e-9):.1f}x")

    # profile/downtime lookup: a many-breakpoint throttled+flapping server
    profile = tuple((float(t), (50 + (t % 7) * 10) * MB)
                    for t in range(1, 200))
    spec = ServerSpec(name="s0", bandwidth=100 * MB, rtt=0.005,
                      profile=profile, avail_up=30.0, avail_down=0.2)
    peers = [ServerSpec(name=f"p{i}", bandwidth=40 * MB, rtt=0.005)
             for i in range(3)]
    t0 = time.perf_counter()
    res = simulate(MDTPPolicy(), [spec] + peers, 4 * GB, seed=0)
    t_sim = time.perf_counter() - t0
    emit("pysim/throttled_flap_4GB", t_sim * 1e6, f"{res.total_time:.2f}",
         f"chunks={len(res.chunks)}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-seeds", type=int, default=8)
    ap.add_argument("--file-gb", type=int, default=2,
                    help="Table II small-file regime by default; compile "
                         "cost is file-size independent (size is traced)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller scenario batch / micro-bench op counts")
    args = ap.parse_args(argv)
    tuner_sweep(n_seeds=args.n_seeds, file_gb=args.file_gb,
                n_scenarios=8 if args.quick else 32,
                scenario_seeds=1 if args.quick else 2)
    engine_compare(n_seeds=4 if args.quick else 8,
                   reps=2 if args.quick else 3)
    pysim_micro(n_ops=5_000 if args.quick else 20_000)


if __name__ == "__main__":
    main()
