"""Sharded, work-stealing restore: K hosts splitting one checkpoint.

Broadcast (``broadcast_bench``) measures N hosts that all want the
WHOLE blob; this bench measures the complement — K hosts each own a
contiguous span (``plan_shards``) and the restore is done when the
slowest host has its span.  With independent shards a single straggling
origin sets the makespan; cross-host work stealing
(:func:`repro.transfer.shard.fetch_sharded`) lets the hosts that finish
early fetch tails of the straggler's span through their own fast
origins and re-serve them over peer mirrors, so the straggler drains
from its fast siblings instead of its slow origin.  Measured on real
loopback sockets, straggler regime: host 0's origin paces at 1/8 of the
others.

``shard/independent/k4``
    ``steal=False``: every host fetches exactly its own span from its
    own origin.  Peer mirrors are mounted but useless — nobody else
    holds the straggler's span, so coverage gating keeps them idle and
    the slow origin sets the makespan.

``shard/workstealing/k4``
    ``steal=True``: same fleet, same throttles, shared
    :class:`StealLedger`.  Fast hosts claim uncovered tails of the
    straggler's span and the straggler's coverage-gated client drains
    them from the thieves' mirrors.

``shard/workstealing/stolen_x``
    Bytes fetched outside their owner's span over the blob size — the
    theft witness and its price: stolen bytes are duplicated traffic
    (they land in both the thief's and the victim's buffers).

``us_per_call`` is the restore makespan (to each host holding its own
span) in microseconds; ``derived`` is seconds (for ``stolen_x``: the
ratio).  All pacing is deterministic token buckets, so the rows are
load-independent perf signal: ``benchmarks/run.py --check`` guards
them at 3x and enforces the shard win-guard (workstealing makespan <=
independent, stolen bytes > 0 on the straggler regime; see
``_check_shard_wins``).  Rows land in ``BENCH_online.json``.
"""

from __future__ import annotations

import argparse
import asyncio
import hashlib

import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.chunking import ChunkParams
from repro.transfer import PeerMirror, RangeServer, Replica, Throttle
from repro.transfer.shard import fetch_sharded, plan_shards

MB = 1024 * 1024

#: healthy-origin pacing; the straggler's origin gets RATE / STRAGGLE_X.
RATE = 8 * MB
STRAGGLE_X = 8
#: shard count the win-guard is stated at.
K = 4
#: swarm-scale geometry (same reasoning as ``broadcast_bench``): stolen
#: spans are traded mid-transfer, so no single grab may outlive the
#: thieves' ramp-up.
PARAMS = ChunkParams(initial_chunk=128 * 1024, large_chunk=256 * 1024,
                     min_chunk=32 * 1024)
COVERAGE_REFRESH_S = 0.01


def _blob(size: int) -> bytes:
    rng = np.random.default_rng(1)
    return rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()


def _throttle(rate: float) -> Throttle:
    return Throttle(bytes_per_s=rate, shared=True, deterministic=True)


def _origins(blob: bytes) -> list[RangeServer]:
    """K origin servers, one per host; host 0's paces at 1/STRAGGLE_X."""
    out = []
    for h in range(K):
        rate = RATE / STRAGGLE_X if h == 0 else RATE
        s = RangeServer(throttle=_throttle(rate)).start()
        s.add_blob("/data", blob)
        out.append(s)
    return out


def _run(blob: bytes, steal: bool) -> tuple[float, int]:
    """One K-host sharded restore.  Returns (makespan_s, stolen_bytes)."""
    plan = plan_shards(len(blob), K)
    servers = _origins(blob)
    # thieves re-serve stolen bytes over their mirrors at the healthy
    # rate — the uplink a victim drains from must itself be paced
    mirrors = [PeerMirror(path=f"/shard{h}", throttle=_throttle(RATE))
               for h in range(K)]
    try:
        origins = [[Replica("127.0.0.1", servers[h].port, "/data")]
                   for h in range(K)]
        res = asyncio.run(fetch_sharded(
            len(blob), plan, origins, steal=steal, mirrors=mirrors,
            client_kw=dict(params=PARAMS,
                           coverage_refresh_s=COVERAGE_REFRESH_S)))
    finally:
        for s in servers:
            s.stop()
        for m in mirrors:
            m.stop()
    for h in range(K):
        s, e = plan.span_of(h)
        want = hashlib.sha256(blob[s:e]).hexdigest()
        got = hashlib.sha256(bytes(res.sinks[h])[s:e]).hexdigest()
        assert got == want, f"host {h} span integrity"
    return res.makespan, res.stolen_bytes


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes (CI check mode)")
    args = ap.parse_args(argv)

    size = 4 * MB if args.quick else 8 * MB
    blob = _blob(size)

    wall_i, stolen_i = _run(blob, steal=False)
    assert stolen_i == 0, "steal=False must not duplicate traffic"
    emit(f"shard/independent/k{K}", wall_i * 1e6, f"{wall_i:.2f}",
         f"straggle_x={STRAGGLE_X}")

    wall_s, stolen_s = _run(blob, steal=True)
    emit(f"shard/workstealing/k{K}", wall_s * 1e6, f"{wall_s:.2f}",
         f"stolen_mb={stolen_s / MB:.1f}")
    emit("shard/workstealing/stolen_x", float(stolen_s),
         f"{stolen_s / size:.3f}", f"blob_mb={size / MB:g}")


if __name__ == "__main__":
    main()
