"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived[,extra...]`` CSV rows.

Default is a *quick* pass (reduced reps/sizes, everything still paper-shaped)
so ``python -m benchmarks.run`` finishes in a few minutes on one CPU core;
``--full`` matches the paper's 10 repetitions and full size ladder.
Framework-layer benchmarks (roofline, restore) appear as sections when their
artifacts are available.

``--json PATH`` serializes the emitted rows.  An existing file is MERGED,
not clobbered: rows re-emitted this run replace their previous versions,
rows from skipped sections survive — so ``BENCH_autotune.json`` and
``BENCH_online.json`` each accumulate a per-PR trajectory no matter which
section subset a given invocation ran.

``--check [PATH]`` is the CI perf guard: re-run the smoke-sized autotune
sweep and compare its steady-state rows against the committed bench JSON
(default ``BENCH_autotune.json``); any row slower than ``3x`` the
committed number exits nonzero.  The tolerance is deliberately generous —
CI machines differ from the machines that produced the artifact — so only
an order-of-magnitude-class regression (a lost fusion, a retrace per grid
point, an accidentally-eager loop) trips it.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import traceback

#: perf-guard tolerance: fail only on > 3x the committed steady-state cost.
CHECK_TOLERANCE = 3.0

#: row-name prefixes the guard compares — jit-compiled steady-state (warm)
#: numbers only: they are stable across machines at the tens-of-ms scale.
#: Cold-compile rows, correctness/derived rows, and the pure-Python
#: microsecond micros (pysim/*) are machine noise, not perf signal.
CHECK_ROW_PREFIXES = (
    "autotune/fused_warm/",
    "autotune/engine_round/",
    "autotune/engine_scan/",
)

#: everything ``--check`` guards: per committed artifact, the smoke bench
#: that regenerates comparable rows and the steady-state prefixes to
#:  compare.  ``contention/*`` rows time a WARM full-policy replay
#: (fused sweeps + round-core sims, all jit-cached), so they are
#: steady-state signal like the autotune rows.  ``dataplane/highrtt/*``
#: rows are deterministic-token-bucket + emulated-RTT transfers, so
#: their wall times are pacing-dominated and machine-stable (the raw
#: ``dataplane/loopback/*`` rows are CPU-bound like the pysim micros and
#: deliberately excluded); the dataplane suite ALSO enforces the
#: win-guard: pipelined goodput must stay >= serial on the high-RTT
#: trace (see ``_check_dataplane_wins``).
#: ``faults/*`` rows are deterministic-token-bucket transfers with a
#: seeded fault policy, so they are pacing-dominated and machine-stable
#: too; the suite ALSO enforces the corruption win-guard: managed
#: per-chunk re-fetch must beat restart-from-zero on goodput (see
#: ``_check_fault_wins``).
#: ``flashcrowd/*`` p95-makespan rows are pacing-dominated storm replays;
#: the waste row (``flashcrowd/gray/waste``, an absolute byte count) is
#: deliberately NOT in the 3x comparison — the win-guard bounds it as a
#: percentage instead (see ``_check_flashcrowd_wins``).
#: ``broadcast/*`` makespan rows are pacing-dominated swarm replays
#: (every uplink a deterministic shared token bucket); the
#: ``origin_x`` row (an absolute byte count) is deliberately NOT in the
#: 3x comparison — the win-guard bounds it as an egress ratio instead
#: (see ``_check_broadcast_wins``).
#: ``shard/*`` makespan rows are pacing-dominated sharded-restore
#: replays (one slow origin, deterministic buckets); the ``stolen_x``
#: row (an absolute byte count) is NOT in the 3x comparison — the
#: win-guard uses it as the theft witness instead (see
#: ``_check_shard_wins``).
CHECK_SUITES = (
    ("BENCH_autotune.json", "autotune", CHECK_ROW_PREFIXES),
    ("BENCH_online.json", "contention", ("contention/",)),
    ("BENCH_dataplane.json", "dataplane",
     ("dataplane/highrtt/", "dataplane/compressed/raw",
      "dataplane/compressed/zblock")),
    ("BENCH_online.json", "faults", ("faults/",)),
    ("BENCH_online.json", "flashcrowd",
     ("flashcrowd/burst/", "flashcrowd/gray/plain",
      "flashcrowd/gray/robust")),
    ("BENCH_online.json", "broadcast",
     ("broadcast/independent/", "broadcast/swarm/n")),
    ("BENCH_online.json", "shard",
     ("shard/independent/", "shard/workstealing/k")),
)


def _check_dataplane_wins(rows) -> int:
    """The data-plane win-guards, on the freshly-run traces:

    - High-RTT trace: the pipelined (half-duplex) client's goodput
      (derived column, MB/s) must not fall below the serial client's,
      and the duplex client's must not fall below the pipelined one's —
      a lost-overlap regression (broken request splitting, a writer
      coroutine that serializes behind bodies again) shows up here long
      before the 3x wall-time tolerance trips.
    - Compressed trace: decoded/wire bytes on the compressible payload
      (the ``wire_ratio`` row's derived column) must stay >= 1.3x —
      the goodput-per-wire-byte win the zblock codec exists for.
    """
    by_name = {r["name"]: float(r["derived"]) for r in rows
               if r["name"].startswith("dataplane/")}
    serial = by_name.get("dataplane/highrtt/serial", 0.0)
    piped = by_name.get("dataplane/highrtt/pipelined", 0.0)
    duplex = by_name.get("dataplane/highrtt/duplex", 0.0)
    ratio = by_name.get("dataplane/compressed/wire_ratio", 0.0)
    if serial <= 0.0 or piped <= 0.0 or duplex <= 0.0 or ratio <= 0.0:
        print("# check: dataplane win-guard rows missing", file=sys.stderr)
        return 1
    rc = 0
    verdict = "ok" if piped >= serial else "REGRESSION"
    print(f"# check dataplane win-guard: pipelined {piped:.1f} MB/s vs "
          f"serial {serial:.1f} MB/s {verdict}", flush=True)
    if piped < serial:
        print("# check FAILED: pipelined goodput fell below serial on "
              "the high-RTT trace", file=sys.stderr)
        rc = 1
    verdict = "ok" if duplex >= piped else "REGRESSION"
    print(f"# check dataplane duplex win-guard: duplex {duplex:.1f} MB/s "
          f"vs pipelined {piped:.1f} MB/s {verdict}", flush=True)
    if duplex < piped:
        print("# check FAILED: duplex goodput fell below half-duplex "
              "pipelined on the high-RTT trace", file=sys.stderr)
        rc = 1
    verdict = "ok" if ratio >= 1.3 else "REGRESSION"
    print(f"# check dataplane compression-guard: {ratio:.2f}x decoded/"
          f"wire bytes (bar 1.3x) {verdict}", flush=True)
    if ratio < 1.3:
        print("# check FAILED: compressed goodput-per-wire-byte fell "
              "below 1.3x raw on the compressible payload",
              file=sys.stderr)
        rc = 1
    return rc


def _check_fault_wins(rows) -> int:
    """The corruption win-guard: on the freshly-run seeded-fault trace,
    the managed client's goodput (derived column, MB/s — per-chunk CRC
    verify + banned re-pool) must beat the restart-from-zero baseline.
    A verification regression that silently re-fetches everything, or a
    re-pool bug that restarts work, shows up here long before the 3x
    wall-time tolerance trips."""
    by_name = {r["name"]: float(r["derived"]) for r in rows
               if r["name"].startswith("faults/corruption/")}
    managed = by_name.get("faults/corruption/managed", 0.0)
    restart = by_name.get("faults/corruption/restart", 0.0)
    if managed <= 0.0 or restart <= 0.0:
        print("# check: corruption win-guard rows missing", file=sys.stderr)
        return 1
    verdict = "ok" if managed >= restart else "REGRESSION"
    print(f"# check corruption win-guard: managed {managed:.1f} MB/s vs "
          f"restart {restart:.1f} MB/s {verdict}", flush=True)
    if managed < restart:
        print("# check FAILED: managed re-fetch goodput fell below "
              "restart-from-zero under corruption", file=sys.stderr)
        return 1
    return 0


def _check_flashcrowd_wins(rows) -> int:
    """The flash-crowd win-guard, on the freshly-run storm replays:

    - GRAY storm: the robust manager's p95 makespan (us_per_call) must
      not exceed the plain manager's — hedging + probation + admission
      exist precisely to cut this tail, and a regression here means one
      of the three quietly stopped working.
    - CLEAN burst: robust p95 may not exceed 1.25x plain — the
      robustness machinery must be near-free when nothing is wrong
      (a tie is expected; a blowup means hedges or probation are firing
      on a healthy fleet).
    - Hedge waste on the gray storm (derived column of the waste row,
      a percentage) must stay <= 5% of the delivered bytes.
    """
    by_name = {r["name"]: r for r in rows
               if r["name"].startswith("flashcrowd/")}

    def p95(name: str) -> float:
        row = by_name.get(name)
        return float(row["us_per_call"]) if row else 0.0

    gray_plain = p95("flashcrowd/gray/plain")
    gray_robust = p95("flashcrowd/gray/robust")
    burst_plain = p95("flashcrowd/burst/plain")
    burst_robust = p95("flashcrowd/burst/robust")
    waste_row = by_name.get("flashcrowd/gray/waste")
    if 0.0 in (gray_plain, gray_robust, burst_plain, burst_robust) \
            or waste_row is None:
        print("# check: flash-crowd win-guard rows missing",
              file=sys.stderr)
        return 1
    rc = 0
    verdict = "ok" if gray_robust <= gray_plain else "REGRESSION"
    print(f"# check flash-crowd gray win-guard: robust p95 "
          f"{gray_robust / 1e6:.2f}s vs plain {gray_plain / 1e6:.2f}s "
          f"{verdict}", flush=True)
    if gray_robust > gray_plain:
        print("# check FAILED: robust p95 makespan exceeded plain on the "
              "gray storm", file=sys.stderr)
        rc = 1
    burst_bar = 1.25 * burst_plain
    verdict = "ok" if burst_robust <= burst_bar else "REGRESSION"
    print(f"# check flash-crowd burst overhead-guard: robust p95 "
          f"{burst_robust / 1e6:.2f}s vs plain {burst_plain / 1e6:.2f}s "
          f"(bar 1.25x) {verdict}", flush=True)
    if burst_robust > burst_bar:
        print("# check FAILED: robustness overhead exceeded 1.25x plain "
              "p95 on the clean burst", file=sys.stderr)
        rc = 1
    waste_pct = float(waste_row["derived"])
    verdict = "ok" if waste_pct <= 5.0 else "REGRESSION"
    print(f"# check flash-crowd waste-guard: hedge waste {waste_pct:.2f}% "
          f"of delivered bytes (bar 5%) {verdict}", flush=True)
    if waste_pct > 5.0:
        print("# check FAILED: hedge waste exceeded 5% of delivered bytes "
              "on the gray storm", file=sys.stderr)
        rc = 1
    return rc


def _check_broadcast_wins(rows) -> int:
    """The peer-assisted broadcast win-guard, on the freshly-run N=4
    swarm replay:

    - Swarm makespan (us_per_call) must not exceed the N-independent
      baseline's — peers serving each other must at least match N
      clients splitting the origin's uplink, or striping/coverage/
      offload quietly stopped working.
    - Origin egress on the swarm run (derived column of the
      ``origin_x`` row, bytes served over blob size) must stay <= 1.5x
      — the dissemination bound is ~1 copy; N independent clients pay
      N.  A coverage-polling or origin-offload regression shows up here
      as the origin re-serving every stripe.
    """
    by_name = {r["name"]: r for r in rows
               if r["name"].startswith("broadcast/")}
    swarm = by_name.get("broadcast/swarm/n4")
    indep = by_name.get("broadcast/independent/n4")
    origin = by_name.get("broadcast/swarm/origin_x")
    if swarm is None or indep is None or origin is None:
        print("# check: broadcast win-guard rows missing", file=sys.stderr)
        return 1
    rc = 0
    swarm_s = float(swarm["us_per_call"]) / 1e6
    indep_s = float(indep["us_per_call"]) / 1e6
    verdict = "ok" if swarm_s <= indep_s else "REGRESSION"
    print(f"# check broadcast makespan win-guard: swarm {swarm_s:.2f}s vs "
          f"independent {indep_s:.2f}s {verdict}", flush=True)
    if swarm_s > indep_s:
        print("# check FAILED: swarm makespan exceeded the N-independent "
              "baseline", file=sys.stderr)
        rc = 1
    ratio = float(origin["derived"])
    verdict = "ok" if ratio <= 1.5 else "REGRESSION"
    print(f"# check broadcast egress-guard: origin served {ratio:.2f}x the "
          f"blob at N=4 (bar 1.5x) {verdict}", flush=True)
    if ratio > 1.5:
        print("# check FAILED: origin egress exceeded 1.5x the blob on the "
              "swarm run", file=sys.stderr)
        rc = 1
    return rc


def _check_shard_wins(rows) -> int:
    """The sharded-restore win-guard, on the freshly-run K=4 straggler
    replay:

    - Work-stealing makespan (us_per_call) must not exceed the
      independent-shards baseline's — the fast hosts draining the
      straggler's span through their mirrors is the whole point, and a
      regression here means stealing, mirror advertisement, or the
      victim's coverage-gated drain quietly stopped working.
    - Stolen bytes (the ``stolen_x`` row) must be > 0 — a ledger that
      never grants a steal makes the makespan comparison vacuous (both
      runs degenerate to independent and the guard would pass while the
      feature is dead).
    """
    by_name = {r["name"]: r for r in rows
               if r["name"].startswith("shard/")}
    ws = by_name.get("shard/workstealing/k4")
    indep = by_name.get("shard/independent/k4")
    stolen = by_name.get("shard/workstealing/stolen_x")
    if ws is None or indep is None or stolen is None:
        print("# check: shard win-guard rows missing", file=sys.stderr)
        return 1
    rc = 0
    ws_s = float(ws["us_per_call"]) / 1e6
    indep_s = float(indep["us_per_call"]) / 1e6
    verdict = "ok" if ws_s <= indep_s else "REGRESSION"
    print(f"# check shard makespan win-guard: workstealing {ws_s:.2f}s vs "
          f"independent {indep_s:.2f}s {verdict}", flush=True)
    if ws_s > indep_s:
        print("# check FAILED: work-stealing makespan exceeded the "
              "independent-shards baseline", file=sys.stderr)
        rc = 1
    stolen_b = float(stolen["us_per_call"])
    verdict = "ok" if stolen_b > 0 else "REGRESSION"
    print(f"# check shard theft witness: {stolen_b / (1024 * 1024):.1f} MB "
          f"stolen on the straggler regime {verdict}", flush=True)
    if stolen_b <= 0:
        print("# check FAILED: no bytes were stolen — the ledger never "
              "granted a steal on the straggler regime", file=sys.stderr)
        rc = 1
    return rc


def _section(title: str) -> None:
    print(f"# === {title} ===", flush=True)


def _merged_rows(path: str, new_rows: list[dict]) -> list[dict]:
    """Merge this run's rows into an existing bench file's rows: re-emitted
    names are replaced in place, absent ones survive, brand-new ones
    append — a partial (``--skip``-heavy) run can't erase history."""
    try:
        with open(path) as f:
            old_rows = json.load(f).get("rows", [])
    except (OSError, ValueError):
        return new_rows
    by_name = {r["name"]: r for r in new_rows}
    merged = [by_name.pop(r["name"], r) for r in old_rows]
    return merged + [r for r in new_rows if r["name"] in by_name]


def _run_check_suite(path: str, section: str, prefixes) -> int:
    """One guard suite: re-run ``section``'s smoke bench and compare its
    steady-state rows (by ``prefixes``) against the artifact at ``path``."""
    from .common import emitted_rows, reset_rows

    try:
        with open(path) as f:
            committed = json.load(f)
    except (OSError, ValueError) as e:
        print(f"# check: cannot read {path}: {e}", file=sys.stderr)
        return 1
    base = {r["name"]: float(r["us_per_call"]) for r in committed["rows"]}

    reset_rows()
    _section(f"perf-check smoke sweep ({section})")
    if section == "autotune":
        from . import autotune_bench
        autotune_bench.main(["--quick"])
    elif section == "contention":
        from . import contention_bench
        contention_bench.main(["--quick"])
    elif section == "dataplane":
        from . import dataplane_bench
        dataplane_bench.main(["--quick"])
    elif section == "faults":
        from . import faults_bench
        faults_bench.main(["--quick"])
    elif section == "flashcrowd":
        from . import flashcrowd_bench
        flashcrowd_bench.main(["--quick"])
    elif section == "broadcast":
        from . import broadcast_bench
        broadcast_bench.main(["--quick"])
    elif section == "shard":
        from . import shard_bench
        shard_bench.main(["--quick"])
    else:
        raise ValueError(f"unknown check section: {section!r}")

    rc_extra = 0
    if section == "dataplane":
        rc_extra = _check_dataplane_wins(emitted_rows())
        if rc_extra:
            # The high-RTT trace races wall clocks like the storm
            # replays: a host-load spike can shave the duplex margin
            # without a code regression.  One replay decides.
            print("# check dataplane: guard failed, replaying the trace "
                  "once to rule out host load", flush=True)
            reset_rows()
            from . import dataplane_bench
            dataplane_bench.main(["--quick"])
            rc_extra = _check_dataplane_wins(emitted_rows())
    elif section == "faults":
        rc_extra = _check_fault_wins(emitted_rows())
    elif section == "broadcast":
        rc_extra = _check_broadcast_wins(emitted_rows())
        if rc_extra:
            # Same wall-clock-race caveat as the flash-crowd storm: a
            # host-load spike can push the swarm makespan past the
            # baseline without a code regression.  One replay decides.
            print("# check broadcast: guard failed, replaying the swarm "
                  "once to rule out host load", flush=True)
            reset_rows()
            from . import broadcast_bench
            broadcast_bench.main(["--quick"])
            rc_extra = _check_broadcast_wins(emitted_rows())
    elif section == "shard":
        rc_extra = _check_shard_wins(emitted_rows())
        if rc_extra:
            # Same wall-clock-race caveat: a host-load spike during the
            # replay can push the work-stealing makespan past the
            # baseline without a code regression.  One replay decides.
            print("# check shard: guard failed, replaying the sharded "
                  "restore once to rule out host load", flush=True)
            reset_rows()
            from . import shard_bench
            shard_bench.main(["--quick"])
            rc_extra = _check_shard_wins(emitted_rows())
    elif section == "flashcrowd":
        rc_extra = _check_flashcrowd_wins(emitted_rows())
        if rc_extra:
            # The storm replay races real wall clocks; a host-load spike
            # during the run can push the p95s or the hedge-waste pct
            # over their bars without any code regression.  One full
            # replay decides: a genuine regression fails both runs.
            print("# check flash-crowd: guard failed, replaying the "
                  "storm once to rule out host load", flush=True)
            reset_rows()
            flashcrowd_bench.main(["--quick"])
            rc_extra = _check_flashcrowd_wins(emitted_rows())

    compared, failures = 0, []
    for row in emitted_rows():
        name = row["name"]
        if not any(name.startswith(p) for p in prefixes):
            continue
        ref = base.get(name, 0.0)
        if ref <= 0.0:
            continue
        ratio = row["us_per_call"] / ref
        compared += 1
        verdict = "ok" if ratio <= CHECK_TOLERANCE else "REGRESSION"
        print(f"# check {name}: {row['us_per_call']:.0f}us vs committed "
              f"{ref:.0f}us ({ratio:.2f}x) {verdict}", flush=True)
        if ratio > CHECK_TOLERANCE:
            failures.append(name)
    if compared == 0:
        print(f"# check: no comparable steady-state rows found in {path}",
              file=sys.stderr)
        return 1
    if failures:
        print(f"# check FAILED (>{CHECK_TOLERANCE:g}x): {failures}",
              file=sys.stderr)
        return 1
    print(f"# check passed: {compared} rows within "
          f"{CHECK_TOLERANCE:g}x of {path}", flush=True)
    return rc_extra


def perf_check(path: str) -> int:
    """CI perf guard over every suite in ``CHECK_SUITES``.

    ``path`` overrides the FIRST suite's artifact (the historical
    ``--check [PATH]`` contract); the remaining suites guard their
    default artifacts.  Any suite failing (regressed row, unreadable
    artifact, or no comparable rows) fails the whole check.
    """
    rc = 0
    for i, (default_path, section, prefixes) in enumerate(CHECK_SUITES):
        suite_path = path if i == 0 else default_path
        rc |= _run_check_suite(suite_path, section, prefixes)
    return rc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-fidelity reps/sizes (slow)")
    ap.add_argument("--skip", nargs="*", default=[],
                    help="section names to skip (fig2 fig3 fig4 fig5 table2 "
                         "autotune online contention dataplane faults "
                         "flashcrowd broadcast shard restore roofline)")
    ap.add_argument("--json", nargs="?", const="BENCH_autotune.json",
                    default=None, metavar="PATH",
                    help="also dump every emitted row as machine-readable "
                         "JSON (default path: BENCH_autotune.json); an "
                         "existing file is merged, not clobbered, so the "
                         "perf trajectory accumulates across PRs")
    ap.add_argument("--check", nargs="?", const="BENCH_autotune.json",
                    default=None, metavar="PATH",
                    help="CI perf guard: compare a smoke sweep against the "
                         "committed bench JSON; exit nonzero on any "
                         f"steady-state row regressing past "
                         f"{CHECK_TOLERANCE:g}x")
    args = ap.parse_args(argv)

    if args.check:
        sys.exit(perf_check(args.check))

    from .common import reset_rows
    reset_rows()

    reps = 10 if args.full else 2
    sizes = [1, 2, 4, 8, 16, 32, 64] if args.full else [1, 4, 16, 64]
    failures = []

    def run(name, fn):
        if name in args.skip:
            return
        _section(name)
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()

    from . import paper_fig2, paper_fig3, paper_fig4, paper_fig5, paper_table2

    run("fig2", lambda: (
        paper_fig2.seeder_trace(reps=min(reps, 5)),
        paper_fig2.transfer_times(sizes, reps),
    ))
    run("fig3", lambda: paper_fig3.main(["--reps", str(reps)]))
    run("fig4", lambda: paper_fig4.main(["--reps", str(reps)]))
    run("fig5", lambda: paper_fig5.main(["--reps", str(reps)]))
    run("table2", lambda: paper_table2.main(
        ["--reps", str(max(reps // 2, 1))]
        + (["--sizes", "2", "32"] if not args.full
           else ["--sizes", "2", "8", "32", "64"])
    ))

    from . import autotune_bench
    run("autotune", lambda: autotune_bench.main(
        [] if args.full else ["--quick"]))

    from . import online_bench
    run("online", lambda: online_bench.main(
        [] if args.full else ["--quick"]))

    from . import contention_bench
    run("contention", lambda: contention_bench.main(
        [] if args.full else ["--quick"]))

    from . import dataplane_bench
    run("dataplane", lambda: dataplane_bench.main(
        [] if args.full else ["--quick"]))

    from . import faults_bench
    run("faults", lambda: faults_bench.main(
        [] if args.full else ["--quick"]))

    from . import flashcrowd_bench
    run("flashcrowd", lambda: flashcrowd_bench.main(
        [] if args.full else ["--quick"]))

    from . import broadcast_bench
    run("broadcast", lambda: broadcast_bench.main(
        [] if args.full else ["--quick"]))

    from . import shard_bench
    run("shard", lambda: shard_bench.main(
        [] if args.full else ["--quick"]))

    # Framework-layer benches (present once the substrates land).
    try:
        from . import restore_bench
        run("restore", lambda: restore_bench.main(["--quick"] if not args.full else []))
    except ImportError:
        pass
    try:
        from . import roofline
        run("roofline", lambda: roofline.report_main([]))
    except ImportError:
        pass

    if args.json:
        from .common import emitted_rows
        rows = emitted_rows()
        if os.path.exists(args.json):
            rows = _merged_rows(args.json, rows)
        payload = {
            "schema": 1,
            "driver": "benchmarks.run",
            "args": {"full": args.full, "skip": args.skip},
            "platform": {
                "python": platform.python_version(),
                "machine": platform.machine(),
            },
            "failed_sections": failures,
            "rows": rows,
        }
        try:
            import jax
            payload["platform"]["jax"] = jax.__version__
            payload["platform"]["backend"] = jax.default_backend()
        except Exception:
            pass
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")       # keep the committed artifact newline-
            # terminated (tools/format_check.py gates this repo-wide)
        print(f"# wrote {args.json} ({len(payload['rows'])} rows)",
              flush=True)

    if failures:
        print(f"# FAILED sections: {failures}", file=sys.stderr)
        sys.exit(1)
    print("# benchmarks complete", flush=True)


if __name__ == "__main__":
    main()
