"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived[,extra...]`` CSV rows.

Default is a *quick* pass (reduced reps/sizes, everything still paper-shaped)
so ``python -m benchmarks.run`` finishes in a few minutes on one CPU core;
``--full`` matches the paper's 10 repetitions and full size ladder.
Framework-layer benchmarks (roofline, restore) appear as sections when their
artifacts are available.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import traceback


def _section(title: str) -> None:
    print(f"# === {title} ===", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-fidelity reps/sizes (slow)")
    ap.add_argument("--skip", nargs="*", default=[],
                    help="section names to skip (fig2 fig3 fig4 fig5 table2 "
                         "autotune restore roofline)")
    ap.add_argument("--json", nargs="?", const="BENCH_autotune.json",
                    default=None, metavar="PATH",
                    help="also dump every emitted row as machine-readable "
                         "JSON (default path: BENCH_autotune.json) so the "
                         "perf trajectory is tracked across PRs")
    args = ap.parse_args(argv)

    from .common import reset_rows
    reset_rows()

    reps = 10 if args.full else 2
    sizes = [1, 2, 4, 8, 16, 32, 64] if args.full else [1, 4, 16, 64]
    failures = []

    def run(name, fn):
        if name in args.skip:
            return
        _section(name)
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()

    from . import paper_fig2, paper_fig3, paper_fig4, paper_fig5, paper_table2

    run("fig2", lambda: (
        paper_fig2.seeder_trace(reps=min(reps, 5)),
        paper_fig2.transfer_times(sizes, reps),
    ))
    run("fig3", lambda: paper_fig3.main(["--reps", str(reps)]))
    run("fig4", lambda: paper_fig4.main(["--reps", str(reps)]))
    run("fig5", lambda: paper_fig5.main(["--reps", str(reps)]))
    run("table2", lambda: paper_table2.main(
        ["--reps", str(max(reps // 2, 1))]
        + (["--sizes", "2", "32"] if not args.full
           else ["--sizes", "2", "8", "32", "64"])
    ))

    from . import autotune_bench
    run("autotune", lambda: autotune_bench.main(
        [] if args.full else ["--quick"]))

    # Framework-layer benches (present once the substrates land).
    try:
        from . import restore_bench
        run("restore", lambda: restore_bench.main(["--quick"] if not args.full else []))
    except ImportError:
        pass
    try:
        from . import roofline
        run("roofline", lambda: roofline.report_main([]))
    except ImportError:
        pass

    if args.json:
        from .common import emitted_rows
        payload = {
            "schema": 1,
            "driver": "benchmarks.run",
            "args": {"full": args.full, "skip": args.skip},
            "platform": {
                "python": platform.python_version(),
                "machine": platform.machine(),
            },
            "failed_sections": failures,
            "rows": emitted_rows(),
        }
        try:
            import jax
            payload["platform"]["jax"] = jax.__version__
            payload["platform"]["backend"] = jax.default_backend()
        except Exception:
            pass
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json} ({len(payload['rows'])} rows)",
              flush=True)

    if failures:
        print(f"# FAILED sections: {failures}", file=sys.stderr)
        sys.exit(1)
    print("# benchmarks complete", flush=True)


if __name__ == "__main__":
    main()
