"""Attribute HLO-walk bytes/flops to individual ops (hillclimb diagnostic).

Usage:
  PYTHONPATH=src python -m benchmarks.hlo_breakdown results/hlo/<cell>.hlo.gz [-n 25]

Prints the top-N ops by HBM bytes (trip-count weighted) and a per-opcode
rollup — the "profile" the §Perf loop reasons from, since there is no
wall-clock trace on a CPU-only container.  Charges come from the SAME
``_op_hbm_bytes`` the roofline walker uses, so totals always match
``analyze_hlo`` (modulo memoized-vs-exact while multipliers).
"""

from __future__ import annotations

import argparse
import gzip
import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in (os.path.abspath(p) for p in sys.path):
    sys.path.insert(0, os.path.abspath(_SRC))

from repro.launch import hlo_analysis as H  # noqa: E402


def breakdown(hlo_text: str):
    comps, entry = H._parse_computations(hlo_text)
    conv_maps = H._build_convert_maps(comps)
    ctxs = {}  # comp name -> (conv_map, half_set)
    per_op: dict[tuple[str, str], dict] = {}
    per_opcode: dict[str, dict] = {}

    def _sig(op):
        m = H._SHAPE_RE.search(op.type_str)
        return m.group(0) if m else op.type_str[:40]

    def charge(op, key_suffix, b, flops, mult, line):
        key = (op.opcode + key_suffix, _sig(op))
        d = per_op.setdefault(key, {"bytes": 0.0, "flops": 0.0, "count": 0.0,
                                    "line": line.strip()[:160]})
        d["bytes"] += b * mult
        d["flops"] += flops * mult
        d["count"] += mult
        d2 = per_opcode.setdefault(op.opcode + key_suffix,
                                   {"bytes": 0.0, "flops": 0.0, "count": 0.0})
        d2["bytes"] += b * mult
        d2["flops"] += flops * mult
        d2["count"] += mult

    def visit(comp_name: str, mult: float, stack: tuple):
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        if comp_name not in ctxs:
            ctxs[comp_name] = H._comp_ctx(comp, conv_maps)
        conv_map, half_set = ctxs[comp_name]
        for op in comp.ops:
            oc = op.opcode
            if oc.endswith("-done"):
                continue
            if oc == "while":
                trip = H._while_trip_count(op, comps) or 1
                for mm in H._CALL_REFS.finditer(op.line):
                    visit(mm.group(1), mult * trip, stack + (comp_name,))
                continue
            if oc in ("call", "conditional", "fusion", "reduce", "sort",
                      "scatter", "map", "reduce-window", "select-and-scatter",
                      "async-start", "custom-call"):
                for mm in H._CALL_REFS.finditer(op.line):
                    visit_flops_only(mm.group(1), mult, stack + (comp_name,))
            flops = 0.0
            if oc in ("dot", "convolution"):
                flops = H._dot_flops(op, comp, comps)
            if any(oc.startswith(c) for c in H._COLLECTIVES):
                cb, _ = H._coll_bytes(op, comp, conv_map, half_set)
                charge(op, "", cb, 0.0, mult, op.line)
                continue
            if oc in H._FREE_OPS:
                if flops:
                    charge(op, "", 0.0, flops, mult, op.line)
                continue
            b, _el, _cp = H._op_hbm_bytes(op, comp, comps, conv_map, half_set)
            charge(op, "", b, flops, mult, op.line)

    def visit_flops_only(comp_name: str, mult: float, stack: tuple):
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                flops = H._dot_flops(op, comp, comps)
                charge(op, "(fused)", 0.0, flops, mult, op.line)
            for mm in H._CALL_REFS.finditer(op.line):
                visit_flops_only(mm.group(1), mult, stack + (comp_name,))

    visit(entry, 1.0, ())
    return per_op, per_opcode


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo", help="path to .hlo.gz or .hlo")
    ap.add_argument("-n", type=int, default=25)
    args = ap.parse_args(argv)
    opener = gzip.open if args.hlo.endswith(".gz") else open
    with opener(args.hlo, "rt") as f:
        text = f.read()
    per_op, per_opcode = breakdown(text)

    tot_b = sum(d["bytes"] for d in per_opcode.values())
    tot_f = sum(d["flops"] for d in per_opcode.values())
    print(f"total bytes (walk): {tot_b/1e9:.2f} GB   "
          f"flops: {tot_f/1e12:.3f} TF")
    print("\n== per-opcode rollup (by bytes) ==")
    for oc, d in sorted(per_opcode.items(), key=lambda kv: -kv[1]["bytes"])[:15]:
        print(f"{oc:28s} {d['bytes']/1e9:10.2f} GB  {d['flops']/1e12:8.3f} TF"
              f"  x{d['count']:.0f}")
    print(f"\n== top {args.n} ops by bytes ==")
    for (oc, sig), d in sorted(per_op.items(),
                               key=lambda kv: -kv[1]["bytes"])[:args.n]:
        print(f"{d['bytes']/1e9:9.2f} GB x{d['count']:6.0f} {oc:20s} {sig}")
        print(f"          {d['line'][:150]}")
    print(f"\n== top {args.n} ops by flops ==")
    for (oc, sig), d in sorted(per_op.items(),
                               key=lambda kv: -kv[1]["flops"])[:args.n]:
        if d["flops"] <= 0:
            break
        print(f"{d['flops']/1e12:9.3f} TF x{d['count']:6.0f} {oc:20s} {sig}")


if __name__ == "__main__":
    main()
